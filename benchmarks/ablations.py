"""Ablations of DAG-AFL's tip-selection components (beyond the paper).

The paper motivates three dimensions (freshness / reachability / accuracy
via signature filtering) but reports no component ablation.  We toggle each:

  full          the paper's method (lambda=0.5, alpha=0.1, p-filter on)
  no_freshness  Eq. 2 weight off (rank reachable tips by accuracy alone)
  no_similarity signature pre-filter off (validate every unreachable tip)
  literal_eq2   the paper's PRINTED Eq. 2 (increases with dwell; see DESIGN)
  lambda_0      unreachable-only selection (no reachability exploitation)
  lambda_1      reachable-only selection (no distribution exploration)
"""
from __future__ import annotations

import json
import os
from typing import Dict

from repro.configs.cnn import vgg_for
from repro.core.simulator import CostModel, make_profiles
from repro.core.tip_selection import TipSelectionConfig
from repro.data import make_benchmark_dataset, partition_dirichlet, split_811
from repro.fl import CNNBackend, FLConfig
from repro.fl.baselines import run_dagafl

VARIANTS = {
    "full": TipSelectionConfig(),
    "no_freshness": TipSelectionConfig(use_freshness=False),
    "no_similarity": TipSelectionConfig(use_similarity=False, p_similar=99),
    "literal_eq2": TipSelectionConfig(literal_eq2=True),
    "lambda_0": TipSelectionConfig(lam=0.0),
    "lambda_1": TipSelectionConfig(lam=1.0),
}


def run_ablations(dataset: str = "mnist", beta: float = 0.1, n_clients: int = 5,
                  max_rounds: int = 8, n_samples: int = 1500, seed: int = 0,
                  out_dir: str = "experiments/fl") -> Dict[str, Dict]:
    ds = make_benchmark_dataset(dataset, n_samples=n_samples, seed=seed)
    splits = split_811(ds, seed=seed)
    parts = partition_dirichlet(splits["train"], n_clients, beta, seed)
    client_data = []
    for p in parts:
        s = split_811(p, seed=seed + 1)
        client_data.append({"train": s["train"], "val": s["val"],
                            "test": s["test"]})
    backend = CNNBackend(vgg_for(dataset), local_epochs=1, batch_size=32)
    cfg = FLConfig(n_clients=n_clients, max_rounds=max_rounds,
                   local_epochs=1, seed=seed, heterogeneity=1.0)
    cost = CostModel()
    profiles = make_profiles(n_clients, 1.0, seed)
    results = {}
    for name, tip_cfg in VARIANTS.items():
        res = run_dagafl(backend, client_data, splits["test"], cfg,
                         cost, profiles, tip_cfg=tip_cfg)
        results[name] = {"accuracy": res.final_accuracy,
                         "sim_time": res.sim_time,
                         "tip_evaluations": res.extra["tip_evaluations"],
                         "rounds": res.rounds}
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "ablations.json"), "w") as f:
        json.dump(results, f, indent=2)
    return results


def rows(results):
    return [f"ablation[{name}],{r['sim_time']*1e6:.0f},{r['accuracy']*100:.2f}"
            for name, r in results.items()]


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    for name, r in run_ablations().items():
        print(f"{name:14s} acc={r['accuracy']*100:6.2f}% "
              f"time={r['sim_time']:7.1f}s evals={r['tip_evaluations']}")
