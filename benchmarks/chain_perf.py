"""Paper Fig. 3: ledger throughput (TPS) and latency vs client count —
plus the cohort execution engine's wall-clock speedup benchmark.

Ledger micro-benchmarks exercise the actual DAG implementation: 'upload' =
append a metadata transaction + tip-set maintenance; 'query' = tip listing +
BFS reachability + metadata fetch.  A linear-chain ledger with FULL-MODEL
payloads (BlockFL-style) is the comparison — the paper's point is that
metadata-only DAG uploads dominate it.

``--cohort-size K`` instead measures the vectorized cohort engine: one
DAG-AFL run with the sequential per-client execution path vs the same run
with K-client vmapped cohort dispatch (see ``repro/fl/cohort.py``), same
simulated-time semantics, wall-clock compared.  Both paths get a one-round
warm-up so XLA compilation is excluded from the measurement (steady-state
throughput is the quantity of interest — a production simulator is
long-running).
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import time
from typing import Dict

import numpy as np

from repro.core.dag import DAGLedger, TxMetadata


def _meta(cid, epoch):
    return TxMetadata(client_id=cid, signature=tuple([0.1] * 16),
                      model_accuracy=0.5, current_epoch=epoch,
                      validation_node_id=cid)


def bench_dag_ledger(n_clients: int, n_tx: int = 300) -> Dict[str, float]:
    rng = np.random.default_rng(0)
    led = DAGLedger()
    led.add_genesis(_meta(-1, 0))
    t0 = time.perf_counter()
    for i in range(n_tx):
        tips = led.tips()
        k = min(2, len(tips))
        parents = list(rng.choice(tips, size=k, replace=False))
        led.add_transaction(_meta(i % n_clients, i), parents, float(i))
    t_upload = time.perf_counter() - t0

    t0 = time.perf_counter()
    n_queries = 200
    for i in range(n_queries):
        start = led.latest_of(i % n_clients)
        led.reachable_tips(start)
    t_query = time.perf_counter() - t0
    return {
        "upload_tps": n_tx / t_upload,
        "query_tps": n_queries / t_query,
        "upload_latency_ms": 1e3 * t_upload / n_tx,
        "query_latency_ms": 1e3 * t_query / n_queries,
    }


def bench_linear_chain(n_clients: int, n_tx: int = 300,
                       model_bytes: int = 1_000_000) -> Dict[str, float]:
    """BlockFL-style: every block carries the full serialized model and the
    chain is sequential (one head)."""
    payload = b"x" * model_bytes
    chain = [hashlib.sha256(b"genesis").hexdigest()]
    t0 = time.perf_counter()
    for i in range(n_tx):
        h = hashlib.sha256()
        h.update(chain[-1].encode())
        h.update(payload)                       # full model on chain
        chain.append(h.hexdigest())
    t_upload = time.perf_counter() - t0
    t0 = time.perf_counter()
    n_queries = 200
    for i in range(n_queries):
        _ = chain[-1]
        _ = hashlib.sha256(payload).hexdigest()  # model re-validation
    t_query = time.perf_counter() - t0
    return {
        "upload_tps": n_tx / t_upload,
        "query_tps": n_queries / t_query,
        "upload_latency_ms": 1e3 * t_upload / n_tx,
        "query_latency_ms": 1e3 * t_query / n_queries,
    }


def _make_cnn_world(n_clients: int, n_samples: int, local_epochs: int,
                    seed: int):
    """The paper-faithful VGG world: dirichlet-partitioned image shards."""
    from repro.configs.cnn import vgg_for
    from repro.data import (make_benchmark_dataset, partition_dirichlet,
                            split_811)
    from repro.fl.backend import CNNBackend

    ds = make_benchmark_dataset("mnist", n_samples=n_samples, seed=seed)
    splits = split_811(ds)
    parts = partition_dirichlet(splits["train"], n_clients, beta=1.0,
                                seed=seed)
    client_data = []
    for p in parts:
        s = split_811(p, seed=seed + 1)
        client_data.append({"train": s["train"], "val": s["val"],
                            "test": s["test"]})
    backend = CNNBackend(vgg_for("mnist"), local_epochs=local_epochs,
                         batch_size=32)
    return backend, client_data, splits["test"]


def _make_lm_world(n_clients: int, n_samples: int, local_epochs: int,
                   seed: int):
    """The framework-scale transformer world: per-client Markov token
    dialects (``n_samples`` = tokens per client stream)."""
    import dataclasses

    from repro.configs import get_config, reduced
    from repro.data import make_lm_dataset
    from repro.fl.backend import LMBackend

    cfg = dataclasses.replace(reduced(get_config("internlm2-1.8b"),
                                      d_model=64), vocab_size=128)
    backend = LMBackend(cfg, lr=5e-3, local_steps=local_epochs,
                        batch_size=8, seq_len=64)
    n_tokens = max(int(n_samples), backend.seq_len * 4)
    client_data = []
    for c in range(n_clients):
        stream = make_lm_dataset(vocab=cfg.vocab_size, n_tokens=n_tokens,
                                 order=2.0, seed=seed + c)
        client_data.append({"train": stream, "val": stream, "test": stream})
    global_test = make_lm_dataset(vocab=cfg.vocab_size, n_tokens=n_tokens,
                                  order=2.0, seed=seed + 10_000)
    return backend, client_data, global_test


_WORLDS = {"cnn": _make_cnn_world, "lm": _make_lm_world}


def _tip_decisions(coord) -> list:
    """The run's full publish trace: for every transaction (in global
    append order) the publishing ``(client, epoch)`` and the sorted
    ``(client, epoch)`` set of the parents its tip selection approved.
    Signature drift changes which tips win Eq. 4/5 scoring, so two runs
    agree on this trace iff their Eq. 3 signatures were bit-identical."""
    txs = sorted(coord.ledger.transactions(), key=lambda t: t.seq)
    who = {t.tx_id: (t.metadata.client_id, t.metadata.current_epoch)
           for t in txs}
    return [(who[t.tx_id],
             tuple(sorted(who.get(p, p) for p in t.parents)))
            for t in txs]


def bench_cohort_speedup(n_clients: int = 16, cohort_size: int = 8,
                         n_samples: int = 6000, max_rounds: int = 2,
                         local_epochs: int = 2, cohort_window: float = 2.0,
                         seed: int = 0, warmup: bool = True,
                         mesh_shape=(0, 1),
                         clients_axis: str = "clients",
                         backend_kind: str = "cnn",
                         repeats: int = 1,
                         overlap: bool = True,
                         kernels: bool = False,
                         kernel_policy: str = "auto") -> Dict[str, float]:
    """Wall-clock: sequential DAG-AFL vs the K-client cohort engine.

    Same backend, same data, same simulated-cost model and seed; the only
    difference is the execution engine.  Reports wall seconds, speedup, and
    both runs' final accuracy (the engines must agree on learning outcome,
    not just on speed).  ``backend_kind`` selects the cohort program suite
    under test: ``"cnn"`` (paper VGG path) or ``"lm"`` (transformer path,
    ``n_samples`` = tokens per client stream).

    ``mesh_shape=(C, D)`` with ``C*D > 1`` additionally measures the
    mesh-sharded SPMD engine (``shard_map`` over a ``clients`` axis of C
    devices, times a ``data`` axis of D sharding each client group's batch
    — clamped to what the host has; use ``XLA_FLAGS=--xla_force_host_
    platform_device_count=N`` on CPU): a third run on the same data reports
    the sharded wall clock, its speedup vs sequential, and its accuracy gap
    vs the single-device cohort path (``mesh_accuracy_gap`` — numerics must
    agree across partitionings, not just engines).  ``overlap`` toggles the
    double-buffered host batch-assembly pipeline on every engine.

    ``kernels=True`` adds the Pallas-dispatch A/B: a fourth run on the
    same data with the cohort programs' ``kernel_policy`` set (Eq. 3
    signatures and LM attention through ``repro.kernels.ops``) instead of
    the jnp reference math.  The kernels are bit-stable by contract, so
    the A/B reports an EXACT accuracy gap (gated at 0.0) and whether the
    two runs' tip-selection traces are identical transaction for
    transaction (signature drift changes DAG topology — see
    ``_tip_decisions``).
    """
    import jax  # noqa: F401  (ensures backend selected before timing)

    from repro.core.coordinator import DagAflConfig, DagAflCoordinator
    from repro.core.simulator import CostModel, make_profiles
    from repro.core.tip_selection import TipSelectionConfig
    from repro.fl.cohort import CohortBackend

    backend, client_data, global_test = _WORLDS[backend_kind](
        n_clients, n_samples, local_epochs, seed)
    # reference-client cost of one unit of local work: a CNN epoch is a
    # full shard pass; an LM "epoch" is ONE SGD step, ~1/8 the work — the
    # simulated round durations (and so the cohort windows' fill dynamics)
    # should reflect that
    cost = CostModel(local_epoch=2.0 if backend_kind == "cnn" else 0.25)
    engine = CohortBackend(backend, capacity=cohort_size, overlap=overlap)
    engine_kernels = None
    if kernels:
        engine_kernels = CohortBackend(backend, capacity=cohort_size,
                                       overlap=overlap,
                                       kernel_policy=kernel_policy)
    engine_sharded = None
    mesh_c, mesh_d = mesh_shape
    if mesh_c * max(mesh_d, 1) > 1:
        from repro.launch.mesh import make_cohort_mesh
        mesh = make_cohort_mesh(mesh_c, axis=clients_axis, data=mesh_d)
        engine_sharded = CohortBackend(backend, capacity=cohort_size,
                                       mesh=mesh, clients_axis=clients_axis,
                                       overlap=overlap)
        if engine_sharded.mesh is None:       # host clamped to one device
            engine_sharded = None
    profiles = make_profiles(n_clients, 0.5, seed)

    def run_once(csize, rounds, eng):
        cfg = DagAflConfig(n_clients=n_clients, max_rounds=rounds,
                           local_epochs=local_epochs,
                           tip=TipSelectionConfig(n_select=2), seed=seed,
                           cohort_size=csize, cohort_window=cohort_window)
        coord = DagAflCoordinator(backend, client_data, global_test, cfg,
                                  cost, profiles, cohort_engine=eng)
        t0 = time.perf_counter()
        res = coord.run()
        return time.perf_counter() - t0, res, coord

    def run(csize, rounds, eng):
        """Best-of-``repeats`` wall clock (the runs are deterministic, so
        min strips scheduler noise on shared containers); result and
        coordinator from the last run."""
        best, res, coord = float("inf"), None, None
        for _ in range(max(repeats, 1)):
            t, res, coord = run_once(csize, rounds, eng)
            best = min(best, t)
        return best, res, coord

    if warmup:
        # compile every measured path out of the timing with full-geometry
        # clones (ONE run each — repeats only apply to the measurement): a
        # shorter warm-up run forms different cohort-size buckets and
        # leaves some programs to compile inside the measured region
        run_once(1, max_rounds, None)
        run_once(cohort_size, max_rounds, engine)
        if engine_kernels is not None:
            run_once(cohort_size, max_rounds, engine_kernels)
        if engine_sharded is not None:
            run_once(cohort_size, max_rounds, engine_sharded)

    t_seq, res_seq, _ = run(1, max_rounds, None)
    t_coh, res_coh, coord_coh = run(cohort_size, max_rounds, engine)
    out = {
        "backend": backend_kind,
        "overlap": bool(overlap),
        "seq_wall_s": t_seq,
        "cohort_wall_s": t_coh,
        "speedup": t_seq / max(t_coh, 1e-9),
        "seq_accuracy": res_seq.final_accuracy,
        "cohort_accuracy": res_coh.final_accuracy,
        "accuracy_gap": abs(res_seq.final_accuracy
                            - res_coh.final_accuracy),
        "seq_sim_time": res_seq.sim_time,
        "cohort_sim_time": res_coh.sim_time,
        "rounds": res_coh.rounds,
        "cohorts_dispatched": res_coh.extra["cohorts_dispatched"],
    }
    if engine_kernels is not None:
        t_ker, res_ker, coord_ker = run(cohort_size, max_rounds,
                                        engine_kernels)
        out.update({
            "kernels_policy": engine_kernels.programs.kernel_policy,
            "kernels_wall_s": t_ker,
            # on-vs-off: >1 means the kernel path was faster than jnp
            "kernels_speedup": t_coh / max(t_ker, 1e-9),
            "kernels_rel_wall": t_ker / max(t_coh, 1e-9),
            "kernels_accuracy": res_ker.final_accuracy,
            # bit-stability contract: EXACT agreement, gated at 0.0
            "kernels_accuracy_gap": abs(res_ker.final_accuracy
                                        - res_coh.final_accuracy),
            "kernels_tip_decisions_identical": (
                _tip_decisions(coord_ker) == _tip_decisions(coord_coh)),
        })
    if engine_sharded is not None:
        t_sh, res_sh, _ = run(cohort_size, max_rounds, engine_sharded)
        out.update({
            "mesh_devices": int(
                dict(engine_sharded.mesh.shape)[clients_axis]),
            "mesh_data_devices": int(engine_sharded._n_data),
            "mesh_shape": f"{dict(engine_sharded.mesh.shape)[clients_axis]}"
                          f"x{engine_sharded._n_data}",
            "sharded_wall_s": t_sh,
            "sharded_speedup": t_seq / max(t_sh, 1e-9),
            "sharded_vs_cohort_speedup": t_coh / max(t_sh, 1e-9),
            "sharded_accuracy": res_sh.final_accuracy,
            # numerics contract: mesh partitioning must not change learning
            "mesh_accuracy_gap": abs(res_sh.final_accuracy
                                     - res_coh.final_accuracy),
        })
    return out


def cohort_rows(result: Dict[str, float], n_clients: int,
                cohort_size: int) -> list:
    tag = f"n{n_clients}_k{cohort_size}"
    if result.get("backend", "cnn") != "cnn":
        tag = f"{result['backend']}_{tag}"
    rows = [
        f"cohort_speedup[{tag}],"
        f"{result['cohort_wall_s']*1e6:.0f},{result['speedup']:.2f}",
        f"cohort_acc_gap[{tag}],"
        f"{result['seq_wall_s']*1e6:.0f},{result['accuracy_gap']*100:.2f}",
    ]
    if "kernels_wall_s" in result:
        ktag = f"{tag}_{result['kernels_policy']}"
        rows += [
            f"cohort_kernels_speedup[{ktag}],"
            f"{result['kernels_wall_s']*1e6:.0f},"
            f"{result['kernels_speedup']:.2f}",
            f"cohort_kernels_acc_gap[{ktag}],"
            f"{result['kernels_wall_s']*1e6:.0f},"
            f"{result['kernels_accuracy_gap']*100:.4f}",
            f"cohort_kernels_tips_identical[{ktag}],"
            f"{result['kernels_wall_s']*1e6:.0f},"
            f"{int(result['kernels_tip_decisions_identical'])}",
        ]
    if "sharded_wall_s" in result:
        mtag = f"{tag}_m{result.get('mesh_shape', result['mesh_devices'])}"
        rows += [
            f"cohort_sharded_speedup[{mtag}],"
            f"{result['sharded_wall_s']*1e6:.0f},"
            f"{result['sharded_speedup']:.2f}",
            f"cohort_mesh_acc_gap[{mtag}],"
            f"{result['sharded_wall_s']*1e6:.0f},"
            f"{result['mesh_accuracy_gap']*100:.2f}",
        ]
    return rows


def run_chain_perf(out_dir: str = "experiments/fl"):
    os.makedirs(out_dir, exist_ok=True)
    results = {}
    for n_clients in (10, 20, 30):
        results[f"dag_afl[{n_clients}]"] = bench_dag_ledger(n_clients)
        results[f"blockfl_like[{n_clients}]"] = bench_linear_chain(n_clients)
    with open(os.path.join(out_dir, "chain_perf.json"), "w") as f:
        json.dump(results, f, indent=2)
    return results


def rows(results):
    out = []
    for name, r in results.items():
        out.append(f"fig3_upload_tps[{name}],"
                   f"{r['upload_latency_ms']*1e3:.1f},{r['upload_tps']:.0f}")
        out.append(f"fig3_query_tps[{name}],"
                   f"{r['query_latency_ms']*1e3:.1f},{r['query_tps']:.0f}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--cohort-size", type=int, default=0,
                    help="measure the cohort engine at this batch size "
                         "(0 = ledger micro-benchmarks only)")
    ap.add_argument("--n-clients", type=int, default=16)
    ap.add_argument("--backend", choices=sorted(_WORLDS), default="cnn",
                    help="cohort program suite under test: the paper VGG "
                         "path (cnn) or the transformer path (lm)")
    ap.add_argument("--mesh", default="0",
                    help="also measure the shard_map SPMD engine on this "
                         "mesh: N (1-D clients axis) or CxD (2-D clients x "
                         "data, e.g. 4x2 — the data axis shards each client "
                         "group's batch), clamped to the host; 0/1 = "
                         "single-device only")
    ap.add_argument("--clients-axis", default="clients",
                    help="mesh axis name the cohort programs shard over")
    ap.add_argument("--overlap", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="double-buffered host batch assembly (--no-overlap "
                         "= inline assembly; results are bit-identical, "
                         "only wall clock moves)")
    ap.add_argument("--kernels", choices=["on", "off"], default="off",
                    help="on = add the Pallas-dispatch A/B leg: rerun the "
                         "cohort smoke with kernel_policy set and report "
                         "the exact accuracy gap + tip-decision identity "
                         "vs the jnp run (writes cohort_speedup_kernels"
                         "[_lm].json)")
    ap.add_argument("--kernel-policy", default="auto",
                    choices=["auto", "compiled", "interpret", "reference"],
                    help="dispatch policy for the --kernels on leg "
                         "(auto resolves per platform: compiled on TPU, "
                         "interpret elsewhere)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke geometry (small data, one round)")
    ap.add_argument("--repeats", type=int, default=2,
                    help="best-of-N wall-clock per engine (noise floor on "
                         "shared containers)")
    ap.add_argument("--out-dir", default="experiments/fl")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    if args.cohort_size:
        kw = dict(n_samples=1500, max_rounds=1, local_epochs=1) \
            if args.quick else {}
        if args.backend == "lm":
            # an LM "epoch" is ONE SGD step (LMBackend.local_steps, default
            # 8), where a CNN epoch is a full shard pass (~9 batches): scale
            # so both worlds run comparable local work per round, and widen
            # the window so the cheaper LM rounds still fill their cohorts
            kw["local_epochs"] = 4 * (1 if args.quick else 2)
            kw["cohort_window"] = 4.0
        from repro.fl.cohort import parse_mesh_spec
        mesh_c, mesh_d = parse_mesh_spec(args.mesh)
        if mesh_c == "auto":
            mesh_c = args.cohort_size
        res = bench_cohort_speedup(n_clients=args.n_clients,
                                   cohort_size=args.cohort_size,
                                   mesh_shape=(mesh_c, mesh_d),
                                   clients_axis=args.clients_axis,
                                   backend_kind=args.backend,
                                   repeats=args.repeats,
                                   overlap=args.overlap,
                                   kernels=args.kernels == "on",
                                   kernel_policy=args.kernel_policy, **kw)
        for r in cohort_rows(res, args.n_clients, args.cohort_size):
            print(r)
        print(f"# sequential {res['seq_wall_s']:.1f}s "
              f"(acc {res['seq_accuracy']:.3f}) vs cohort "
              f"{res['cohort_wall_s']:.1f}s (acc {res['cohort_accuracy']:.3f})"
              f" -> {res['speedup']:.2f}x, "
              f"{res['cohorts_dispatched']} cohorts")
        if "kernels_wall_s" in res:
            print(f"# kernels ({res['kernels_policy']}) "
                  f"{res['kernels_wall_s']:.1f}s "
                  f"(acc {res['kernels_accuracy']:.3f}) -> "
                  f"x{res['kernels_rel_wall']:.2f} wall vs jnp cohort, "
                  f"acc gap {res['kernels_accuracy_gap']:.6f}, "
                  f"tip decisions identical: "
                  f"{res['kernels_tip_decisions_identical']}")
        if "sharded_wall_s" in res:
            print(f"# sharded (mesh {res['mesh_shape']}) "
                  f"{res['sharded_wall_s']:.1f}s "
                  f"(acc {res['sharded_accuracy']:.3f}) -> "
                  f"{res['sharded_speedup']:.2f}x vs sequential, "
                  f"mesh acc gap {res['mesh_accuracy_gap']*100:.2f} pts")
        elif mesh_c * max(mesh_d, 1) > 1:
            print("# mesh requested but host has one device; sharded run "
                  "skipped (set XLA_FLAGS=--xla_force_host_platform_"
                  "device_count=N)")
        os.makedirs(args.out_dir, exist_ok=True)
        # the LM smoke writes its own file so the CNN gate baseline and the
        # LM gate baseline can be checked independently in CI; the kernels
        # A/B likewise, so the plain smoke's baseline artifact never gains
        # or loses fields depending on which CI leg wrote it last
        fname = ("cohort_speedup.json" if args.backend == "cnn"
                 else f"cohort_speedup_{args.backend}.json")
        if args.kernels == "on":
            fname = fname.replace("cohort_speedup",
                                  "cohort_speedup_kernels", 1)
        with open(os.path.join(args.out_dir, fname), "w") as f:
            json.dump(res, f, indent=2)
    else:
        for r in rows(run_chain_perf(args.out_dir)):
            print(r)


if __name__ == "__main__":
    main()
