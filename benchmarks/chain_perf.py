"""Paper Fig. 3: ledger throughput (TPS) and latency vs client count.

Micro-benchmarks the actual DAG ledger implementation: 'upload' = append a
metadata transaction + tip-set maintenance; 'query' = tip listing + BFS
reachability + metadata fetch.  A linear-chain ledger with FULL-MODEL
payloads (BlockFL-style) is the comparison — the paper's point is that
metadata-only DAG uploads dominate it.
"""
from __future__ import annotations

import hashlib
import json
import os
import time
from typing import Dict

import numpy as np

from repro.core.dag import DAGLedger, TxMetadata


def _meta(cid, epoch):
    return TxMetadata(client_id=cid, signature=tuple([0.1] * 16),
                      model_accuracy=0.5, current_epoch=epoch,
                      validation_node_id=cid)


def bench_dag_ledger(n_clients: int, n_tx: int = 300) -> Dict[str, float]:
    rng = np.random.default_rng(0)
    led = DAGLedger()
    led.add_genesis(_meta(-1, 0))
    t0 = time.perf_counter()
    for i in range(n_tx):
        tips = led.tips()
        k = min(2, len(tips))
        parents = list(rng.choice(tips, size=k, replace=False))
        led.add_transaction(_meta(i % n_clients, i), parents, float(i))
    t_upload = time.perf_counter() - t0

    t0 = time.perf_counter()
    n_queries = 200
    for i in range(n_queries):
        start = led.latest_of(i % n_clients)
        led.reachable_tips(start)
    t_query = time.perf_counter() - t0
    return {
        "upload_tps": n_tx / t_upload,
        "query_tps": n_queries / t_query,
        "upload_latency_ms": 1e3 * t_upload / n_tx,
        "query_latency_ms": 1e3 * t_query / n_queries,
    }


def bench_linear_chain(n_clients: int, n_tx: int = 300,
                       model_bytes: int = 1_000_000) -> Dict[str, float]:
    """BlockFL-style: every block carries the full serialized model and the
    chain is sequential (one head)."""
    payload = b"x" * model_bytes
    chain = [hashlib.sha256(b"genesis").hexdigest()]
    t0 = time.perf_counter()
    for i in range(n_tx):
        h = hashlib.sha256()
        h.update(chain[-1].encode())
        h.update(payload)                       # full model on chain
        chain.append(h.hexdigest())
    t_upload = time.perf_counter() - t0
    t0 = time.perf_counter()
    n_queries = 200
    for i in range(n_queries):
        _ = chain[-1]
        _ = hashlib.sha256(payload).hexdigest()  # model re-validation
    t_query = time.perf_counter() - t0
    return {
        "upload_tps": n_tx / t_upload,
        "query_tps": n_queries / t_query,
        "upload_latency_ms": 1e3 * t_upload / n_tx,
        "query_latency_ms": 1e3 * t_query / n_queries,
    }


def run_chain_perf(out_dir: str = "experiments/fl"):
    os.makedirs(out_dir, exist_ok=True)
    results = {}
    for n_clients in (10, 20, 30):
        results[f"dag_afl[{n_clients}]"] = bench_dag_ledger(n_clients)
        results[f"blockfl_like[{n_clients}]"] = bench_linear_chain(n_clients)
    with open(os.path.join(out_dir, "chain_perf.json"), "w") as f:
        json.dump(results, f, indent=2)
    return results


def rows(results):
    out = []
    for name, r in results.items():
        out.append(f"fig3_upload_tps[{name}],"
                   f"{r['upload_latency_ms']*1e3:.1f},{r['upload_tps']:.0f}")
        out.append(f"fig3_query_tps[{name}],"
                   f"{r['query_latency_ms']*1e3:.1f},{r['query_tps']:.0f}")
    return out
