"""CI perf-regression gate for the cohort engine and the bounded ledger.

Dispatches on the results file's ``kind`` field: ``ledger_day`` results
(written by ``benchmarks/ledger_perf.py``) are gated on the bounded-frontier
invariants under the ``ledger_day`` thresholds sub-dict; ``robustness``
results (``benchmarks/robustness.py``) on fault-event counts and accuracy
deltas; ``serve`` results (``benchmarks/serve_perf.py``) on deterministic
serving counters (replica versions, queries, seq-staleness) plus exact
replica-vs-direct Eq. 6 parity flags; ``kernel_perf`` results on analytic
memory-footprint ratios; everything else is a cohort smoke (written by
``benchmarks/chain_perf.py --cohort-size K``).
Both compare against the checked-in floors in
``benchmarks/baseline_thresholds.json`` and exit non-zero on regression.

Cohort smoke:

  * ``speedup``            — vectorized cohort engine vs the sequential
                             path; must stay above ``cohort_speedup_min``
                             (times ``quick_speedup_factor`` under
                             ``--quick``, matching the smaller CI geometry).
  * ``accuracy_gap``       — cohort vs sequential final accuracy; the
                             engines must agree on learning outcome.
  * ``mesh_accuracy_gap``  — (only present when the smoke ran with
                             ``--mesh``) sharded SPMD vs single-device
                             cohort accuracy; mesh partitioning must not
                             change numerics.  This covers the 2-D
                             (clients, data) mesh too: a ``--mesh CxD``
                             smoke's gap compares data-sharded gradients
                             against the single-device path, and
                             ``--require-data-axis`` pins CI to actually
                             exercising it.

The sharded wall-clock is reported but NOT gated: on CI's 2-core runners a
forced 8-device host mesh oversubscribes cores, so its speedup measures the
runner, not the code.  Correctness of the sharded path is gated through
``mesh_accuracy_gap`` and the test suite instead.

A cohort smoke run with ``--kernels on`` additionally carries a kernel-path
A/B leg (Pallas dispatch vs the incumbent jnp math, same engine, same
seed), gated under the same sub-dict:

  * ``kernels_accuracy_gap`` — must stay within
    ``kernels_accuracy_gap_max`` (0.0: Eq. 3 signatures are bit-stable by
    contract, so the kernel path must reproduce the jnp run's learning
    outcome EXACTLY, not approximately).
  * ``kernels_tip_decisions_identical`` — the two runs' full publish
    traces (per-transaction ``(client, epoch)`` plus the sorted parent
    set each tip selection chose) must match transaction for transaction;
    signature drift changes DAG topology, and this is the field that
    catches it.  ``--require-kernels`` pins a CI leg to having run the
    A/B at all.

Kernel micro-benchmarks (``kind: kernel_perf``, written by
``benchmarks/kernel_perf.py``) are gated under the ``kernel_perf``
thresholds sub-dict:

  * ``<name>_intermediate_ratio_max`` — ANALYTIC kernel-vs-jnp
    intermediate-footprint ratio per op (derived from shapes, so it is
    deterministic on any runner).  The signature ceilings assert the
    core claim of the swap: the kernel must NOT materialize the (T, d)
    flag tensor the jnp path does.
  * ``signature_rel_time_max``  — generous wall-clock parity ceiling for
    the Eq. 3 bucket kernel vs jnp.  CI runs the INTERPRETER (an
    emulation), so this only catches order-of-magnitude pathologies;
    the ratio ceilings above carry the real gate.  Other ops' wall-clock
    is reported, never gated (the per-channel interpreter emulation is
    legitimately slower than fused XLA on tiny CPU shapes).
  * the records must cover all three swapped hot-path ops.

Ledger day-in-the-life (``kind: ledger_day``):

  * ``peak_live_frac``     — peak live-transaction count as a fraction of
                             all published transactions; must stay under
                             ``peak_live_frac_max`` — memory is bounded by
                             the consensus frontier, not by history.
  * ``peak_store_frac``    — same bound for ModelStore entries: pruning
                             must evict model bodies, not just metadata.
  * ``pruned_frac``        — at least ``pruned_frac_min`` of history must
                             actually have been folded into checkpoints.
  * ``select_work_vs_history`` — deterministic per-selection ledger work
                             (reachability log entries + BFS visits +
                             tip-heap pops) over the last quarter of
                             rounds, as a fraction of total transactions;
                             must stay under
                             ``select_work_vs_history_max``.  A
                             linear-in-history implementation (whole-DAG
                             BFS, all-tips scan) scores ~1; index-backed
                             selection sits orders of magnitude below.
  * ``audit_tx_ratio``     — the incremental verifier must have re-derived
                             every transaction's Eq. 7 hash at least once
                             (``audit_tx_ratio_min``).
  * ``verify_ok``          — every incremental audit plus the final full
                             verification passed.

The ledger gate is wall-clock-free by construction — every gated quantity
is an event count, so a loaded CI runner cannot flake it.

Robustness suite (``kind: robustness``, written by
``benchmarks/robustness.py``), per scenario under the ``robustness``
thresholds sub-dict:

  * deterministic event counts — the scenario's primary fault counter must
    be nonzero AND the same-seed rerun must report identical counts and
    tamper-detection sets (``determinism`` leg).
  * ``accuracy_delta_max``    — DAG-AFL's honest-vs-attacked accuracy drop
                                (honest clients' models) must stay under the
                                per-scenario floor.
  * ``poison_advantage_min``  — (poison only) fedavg's AND fedasync's
                                accuracy delta must exceed DAG-AFL's by at
                                least this margin: the DAG defense must
                                demonstrably beat the defenseless baselines.
  * ``poisoned_tip_approval_rate_max`` — (poison only) how often honest tip
                                selection approved a malicious tx.
  * tamper detection          — (poison only) nonzero tampered txs, every
                                one detected by the Eq. 7 sweep, and the
                                incremental verifier flagged the ledger.

Accuracy-DELTA floors are gated (a run-to-run borderline flip moves both
legs of the subtraction together at fixed seeds); wall-clock never is.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_THRESHOLDS = os.path.join(os.path.dirname(__file__),
                                  "baseline_thresholds.json")


def active_thresholds(thresholds: dict, results: dict) -> dict:
    """Per-backend floors: the top-level keys gate the default (cnn) smoke;
    a sub-dict keyed by the results' ``backend`` field (e.g. ``"lm"``)
    overrides them for that suite's smoke."""
    sub = thresholds.get(results.get("backend", "cnn"))
    if isinstance(sub, dict):
        merged = {k: v for k, v in thresholds.items()
                  if not isinstance(v, dict)}
        merged.update(sub)
        return merged
    return thresholds


def check_ledger(results: dict, thresholds: dict) -> list:
    """Gate a ``ledger_day`` results file (see module docstring)."""
    failures = []
    t = thresholds.get("ledger_day", {})

    def over(key, limit_key):
        limit = t[limit_key]
        val = results.get(key)
        if val is None:
            failures.append(f"results carry no '{key}' field")
        elif val > limit:
            failures.append(f"{key} {val:.4f} above {limit:.4f}")

    over("peak_live_frac", "peak_live_frac_max")
    over("peak_store_frac", "peak_store_frac_max")
    over("select_work_vs_history", "select_work_vs_history_max")
    pruned = results.get("pruned_frac", 0.0)
    if pruned < t["pruned_frac_min"]:
        failures.append(f"pruned_frac {pruned:.4f} below "
                        f"{t['pruned_frac_min']:.4f}")
    audited = results.get("audit_tx_ratio", 0.0)
    if audited < t["audit_tx_ratio_min"]:
        failures.append(f"audit_tx_ratio {audited:.4f} below "
                        f"{t['audit_tx_ratio_min']:.4f} — the incremental "
                        "verifier did not cover every append")
    if not results.get("verify_ok", False):
        failures.append("verify_ok is false — an incremental audit or the "
                        "final full verification failed")
    return failures


# each scenario's primary fault counter (mirrors
# benchmarks/robustness.py EVENT_KEYS; duplicated so the gate stays
# importable without the repro package)
ROBUSTNESS_EVENT_KEYS = {
    "poison": "updates_scaled", "lazy": "updates_lazy",
    "dp": "updates_noised", "straggler": "straggler_draws",
    "dropout": "publishes_dropped",
}


def check_robustness(results: dict, thresholds: dict) -> list:
    """Gate a ``kind=robustness`` results file (see module docstring)."""
    failures = []
    t = thresholds.get("robustness", {})
    for name, s in results.get("scenarios", {}).items():
        st = t.get(name, {})
        counts = s.get("counts", {})
        event_key = ROBUSTNESS_EVENT_KEYS.get(name)
        if event_key and counts.get(event_key, 0) < 1:
            failures.append(f"{name}: no fault events injected "
                            f"({event_key}=0) — the scenario did nothing")
        det = s.get("determinism")
        if t.get("determinism_required", True):
            if det is None:
                failures.append(f"{name}: no determinism leg (run without "
                                "--no-determinism)")
            elif not (det.get("counts_match")
                      and det.get("detections_match")):
                failures.append(f"{name}: same-seed rerun diverged "
                                f"(counts_match={det.get('counts_match')}, "
                                f"detections_match="
                                f"{det.get('detections_match')})")
        dag_delta = s["methods"]["dagafl"]["accuracy_delta"]
        delta_max = st.get("accuracy_delta_max")
        if delta_max is not None and dag_delta > delta_max:
            failures.append(f"{name}: dagafl honest-vs-attacked delta "
                            f"{dag_delta:.4f} above {delta_max:.4f}")
        adv_min = st.get("poison_advantage_min")
        if adv_min is not None:
            for algo in ("fedavg", "fedasync"):
                m = s["methods"].get(algo)
                if m is None:
                    failures.append(f"{name}: no {algo} comparison leg")
                    continue
                adv = m["accuracy_delta"] - dag_delta
                if adv < adv_min:
                    failures.append(
                        f"{name}: dagafl advantage over {algo} "
                        f"{adv:.4f} below {adv_min:.4f} (the DAG defense "
                        f"must beat the defenseless baseline)")
        dag = s.get("dag", {})
        rate_max = st.get("poisoned_tip_approval_rate_max")
        if rate_max is not None:
            rate = dag.get("poisoned_tip_approval_rate", 1.0)
            if rate > rate_max:
                failures.append(f"{name}: poisoned-tip approval rate "
                                f"{rate:.4f} above {rate_max:.4f}")
        if st.get("require_tamper_detection"):
            if dag.get("txs_tampered", 0) < 1:
                failures.append(f"{name}: no txs were tampered — the Eq. 7 "
                                "audit was never exercised")
            if not dag.get("detections_exact"):
                failures.append(f"{name}: Eq. 7 sweep did not return "
                                f"exactly the tampered set "
                                f"(tampered={dag.get('txs_tampered')}, "
                                f"detected={dag.get('tamper_detections')})")
            if not dag.get("incremental_audit_flagged"):
                failures.append(f"{name}: IncrementalVerifier did not flag "
                                "the tampered ledger")
    if not results.get("scenarios"):
        failures.append("results carry no scenarios")
    return failures


def check_serve(results: dict, thresholds: dict) -> list:
    """Gate a ``kind=serve`` results file (benchmarks/serve_perf.py).

    Everything gated is a deterministic event count (replica versions,
    queries served, staleness in ledger append seqs) or an exact-parity
    flag; wall-clock throughput is reported, never gated.  Per-backend
    floors live under the ``serve`` thresholds sub-dict, keyed by backend.
    """
    failures = []
    t = thresholds.get("serve", {})
    backends = results.get("backends", {})
    if not backends:
        failures.append("results carry no backends")
    for name, b in backends.items():
        bt = {k: v for k, v in t.items() if not isinstance(v, dict)}
        bt.update(t.get(name, {}))
        s = b.get("serving", {})

        def floor(key, floor_key):
            limit = bt.get(floor_key)
            if limit is not None and s.get(key, 0) < limit:
                failures.append(f"{name}: {key} {s.get(key, 0)} below "
                                f"{limit} — serving never got going")

        def ceiling(key, ceil_key):
            limit = bt.get(ceil_key)
            if limit is not None and s.get(key, 0) > limit:
                failures.append(f"{name}: {key} {s.get(key, 0)} above "
                                f"{limit} — replicas went stale past the "
                                "publish-cadence budget")

        floor("replica_versions", "replica_versions_min")
        floor("queries", "queries_min")
        floor("distinct_versions_served", "distinct_versions_min")
        ceiling("max_seq_lag", "max_seq_lag_max")
        ceiling("mean_seq_lag", "mean_seq_lag_max")
        if s.get("skipped", 0) != 0:
            failures.append(f"{name}: {s['skipped']} queries arrived before "
                            "any replica existed — the publisher must "
                            "publish v0 at start")
        par = b.get("parity", {})
        for flag in ("params_bitwise", "direct_bitwise", "output_parity",
                     "pinned_resident"):
            if not par.get(flag, False):
                failures.append(
                    f"{name}: parity flag '{flag}' is false — the replica "
                    "is not bit-identical to direct Eq. 6 aggregation over "
                    "its frontier (probe: "
                    f"{par.get('parity_probe', '?')})")
        if bt.get("require_pruning") and b.get("n_pruned", 0) < 1:
            failures.append(f"{name}: bounded-ledger leg pruned nothing — "
                            "eviction protection was never exercised")
        det = b.get("determinism")
        if t.get("determinism_required", True):
            if det is None:
                failures.append(f"{name}: no determinism leg (run without "
                                "--no-determinism)")
            elif not det.get("counters_match"):
                failures.append(
                    f"{name}: same-seed rerun diverged on counters "
                    f"{det.get('mismatched_keys')}")
    return failures


# the three hot-path swaps kernel_perf.py must cover (ISSUE 9 tentpole)
KERNEL_PERF_OPS = ("signature", "signature_per_channel", "flash_attention")


def check_kernel_perf(results: dict, thresholds: dict) -> list:
    """Gate a ``kind=kernel_perf`` results file (see module docstring)."""
    failures = []
    t = thresholds.get("kernel_perf", {})
    kernels = results.get("kernels") or []
    if not kernels:
        failures.append("results carry no kernel records")
    seen = {r.get("name") for r in kernels}
    for op in KERNEL_PERF_OPS:
        if op not in seen:
            failures.append(f"no '{op}' records — the micro-bench must "
                            "cover every swapped hot-path op")
    for r in kernels:
        name = r.get("name", "?")
        tag = f"{name}{r.get('shape')}"
        ratio_max = t.get(f"{name}_intermediate_ratio_max")
        if ratio_max is not None:
            ratio = r.get("intermediate_ratio")
            if ratio is None:
                failures.append(f"{tag}: no intermediate_ratio field")
            elif ratio > ratio_max:
                failures.append(
                    f"{tag}: kernel-vs-jnp intermediate footprint ratio "
                    f"{ratio:.4f} above {ratio_max:.4f} — the kernel path "
                    "materializes an intermediate it promised to stream")
        rel_max = t.get(f"{name}_rel_time_max")
        if rel_max is not None:
            rel = r.get("rel_time")
            if rel is None:
                failures.append(f"{tag}: no rel_time field")
            elif rel > rel_max:
                failures.append(f"{tag}: kernel wall-clock {rel:.2f}x jnp, "
                                f"above the {rel_max:.2f}x parity ceiling")
    return failures


def check_kernels_ab(results: dict, thresholds: dict) -> list:
    """Gate the cohort smoke's ``--kernels on`` A/B fields when present."""
    failures = []
    kgap = results.get("kernels_accuracy_gap")
    if kgap is None:
        return failures
    kmax = thresholds.get("kernels_accuracy_gap_max", 0.0)
    if kgap > kmax:
        failures.append(f"kernel-vs-jnp accuracy gap {kgap:.6f} above "
                        f"{kmax:.6f} — Eq. 3 signatures must be bit-stable "
                        "across dispatch policies")
    if not results.get("kernels_tip_decisions_identical", False):
        failures.append("kernel-path run made different tip-selection "
                        "decisions than the jnp run — signature drift "
                        "changed the DAG topology")
    return failures


def check(results: dict, thresholds: dict, quick: bool = False) -> list:
    """Returns a list of failure strings (empty = gate passes)."""
    if results.get("kind") == "ledger_day":
        return check_ledger(results, thresholds)
    if results.get("kind") == "robustness":
        return check_robustness(results, thresholds)
    if results.get("kind") == "kernel_perf":
        return check_kernel_perf(results, thresholds)
    if results.get("kind") == "serve":
        return check_serve(results, thresholds)
    failures = []
    thresholds = active_thresholds(thresholds, results)
    floor = thresholds["cohort_speedup_min"]
    if quick:
        floor *= thresholds.get("quick_speedup_factor", 1.0)
    speedup = results.get("speedup")
    if speedup is None:
        failures.append("results carry no 'speedup' field — did the smoke "
                        "run with --cohort-size?")
    elif speedup < floor:
        failures.append(f"cohort speedup {speedup:.2f}x below floor "
                        f"{floor:.2f}x")

    gap = results.get("accuracy_gap")
    gap_max = thresholds["accuracy_gap_max"]
    if gap is not None and gap > gap_max:
        failures.append(f"cohort-vs-sequential accuracy gap {gap:.4f} above "
                        f"{gap_max:.4f}")

    mesh_gap = results.get("mesh_accuracy_gap")
    if mesh_gap is not None:
        mesh_max = thresholds["mesh_accuracy_gap_max"]
        if mesh_gap > mesh_max:
            failures.append(f"sharded-vs-single-device accuracy gap "
                            f"{mesh_gap:.4f} above {mesh_max:.4f}")
    failures += check_kernels_ab(results, thresholds)
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("results", nargs="?",
                    default="experiments/fl/cohort_speedup.json",
                    help="cohort smoke results json")
    ap.add_argument("--thresholds", default=DEFAULT_THRESHOLDS)
    ap.add_argument("--quick", action="store_true",
                    help="apply the quick-mode speedup tolerance")
    ap.add_argument("--require-mesh", action="store_true",
                    help="fail unless the results carry the sharded-engine "
                         "fields (the smoke must have run with --mesh on a "
                         "multi-device host)")
    ap.add_argument("--require-data-axis", action="store_true",
                    help="fail unless the sharded run used a 2-D (clients, "
                         "data) mesh with data > 1 (the smoke must have run "
                         "with --mesh CxD, D >= 2, on a host with enough "
                         "devices)")
    ap.add_argument("--require-kernels", action="store_true",
                    help="fail unless the cohort smoke carries the kernel "
                         "A/B fields (it must have run with --kernels on)")
    args = ap.parse_args()

    with open(args.results) as f:
        results = json.load(f)
    with open(args.thresholds) as f:
        thresholds = json.load(f)

    failures = check(results, thresholds, quick=args.quick)
    if results.get("kind") == "ledger_day":
        print(f"perf gate[ledger_day, n={results.get('n_clients')}]: "
              f"peak_live_frac="
              f"{results.get('peak_live_frac', float('nan')):.3f} "
              f"peak_store_frac="
              f"{results.get('peak_store_frac', float('nan')):.3f} "
              f"pruned_frac={results.get('pruned_frac', float('nan')):.3f} "
              f"work_vs_history="
              f"{results.get('select_work_vs_history', float('nan')):.4f} "
              f"audit_tx_ratio="
              f"{results.get('audit_tx_ratio', float('nan')):.2f} "
              f"verify_ok={results.get('verify_ok')}")
        if failures:
            for msg in failures:
                print(f"PERF GATE FAIL: {msg}", file=sys.stderr)
            sys.exit(1)
        print("perf gate: PASS")
        return
    if results.get("kind") == "robustness":
        for name, s in results.get("scenarios", {}).items():
            dagafl = s["methods"]["dagafl"]
            det = s.get("determinism", {})
            dag = s.get("dag", {})
            print(f"perf gate[robustness/{name}]: "
                  f"delta={dagafl['accuracy_delta']:+.3f} "
                  f"approval={dag.get('poisoned_tip_approval_rate', 0):.3f} "
                  f"tampered/detected={dag.get('txs_tampered', 0)}/"
                  f"{dag.get('tamper_detections', 0)} "
                  f"deterministic={bool(det.get('counts_match')) and bool(det.get('detections_match'))}")
        if failures:
            for msg in failures:
                print(f"PERF GATE FAIL: {msg}", file=sys.stderr)
            sys.exit(1)
        print("perf gate: PASS")
        return
    if results.get("kind") == "serve":
        for name, b in results.get("backends", {}).items():
            s = b.get("serving", {})
            det = b.get("determinism", {})
            par = b.get("parity", {})
            print(f"perf gate[serve/{name}]: "
                  f"replicas={s.get('replica_versions')} "
                  f"queries={s.get('queries')} "
                  f"seq_lag={s.get('mean_seq_lag', float('nan')):.2f}/"
                  f"{s.get('max_seq_lag')} (mean/max) "
                  f"versions_served={s.get('distinct_versions_served')} "
                  f"parity={par.get('params_bitwise')}/"
                  f"{par.get('output_parity')} "
                  f"deterministic={det.get('counters_match')} "
                  f"[{s.get('queries_per_s', float('nan')):.1f} q/s "
                  "wall, not gated]")
        if failures:
            for msg in failures:
                print(f"PERF GATE FAIL: {msg}", file=sys.stderr)
            sys.exit(1)
        print("perf gate: PASS")
        return
    if results.get("kind") == "kernel_perf":
        print(f"perf gate[kernel_perf, {results.get('policy')} on "
              f"{results.get('platform')}]:")
        for r in results.get("kernels", []):
            print(f"  {r.get('name', '?'):>22} {str(r.get('shape')):>18}: "
                  f"rel_time x{r.get('rel_time', float('nan')):.2f} "
                  f"intermediate_ratio "
                  f"x{r.get('intermediate_ratio', float('nan')):.4f}")
        if failures:
            for msg in failures:
                print(f"PERF GATE FAIL: {msg}", file=sys.stderr)
            sys.exit(1)
        print("perf gate: PASS")
        return
    if args.require_mesh and "mesh_accuracy_gap" not in results:
        failures.append("--require-mesh: no sharded-engine results; the "
                        "multi-device smoke did not exercise shard_map")
    if args.require_data_axis and results.get("mesh_data_devices", 1) < 2:
        failures.append("--require-data-axis: the smoke did not exercise "
                        "the 2-D (clients, data) mesh (mesh_data_devices="
                        f"{results.get('mesh_data_devices', 1)})")
    if args.require_kernels and "kernels_accuracy_gap" not in results:
        failures.append("--require-kernels: no kernel A/B fields; the "
                        "smoke did not run with --kernels on")

    kern = ""
    if "kernels_accuracy_gap" in results:
        kern = (f" kernels[{results.get('kernels_policy')}]: "
                f"acc_gap={results['kernels_accuracy_gap']:.6f} "
                f"tips_identical="
                f"{results.get('kernels_tip_decisions_identical')} "
                f"rel_wall=x{results.get('kernels_rel_wall', float('nan')):.2f}")
    print(f"perf gate[{results.get('backend', 'cnn')}"
          f"{',' + results['mesh_shape'] if 'mesh_shape' in results else ''}"
          f"]: speedup={results.get('speedup', float('nan')):.2f}x "
          f"acc_gap={results.get('accuracy_gap', float('nan')):.4f} "
          f"mesh_acc_gap={results.get('mesh_accuracy_gap', float('nan')):.4f}"
          f" sharded_speedup="
          f"{results.get('sharded_speedup', float('nan')):.2f}x"
          f" (quick={args.quick}){kern}")
    if failures:
        for msg in failures:
            print(f"PERF GATE FAIL: {msg}", file=sys.stderr)
        sys.exit(1)
    print("perf gate: PASS")


if __name__ == "__main__":
    main()
