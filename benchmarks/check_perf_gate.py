"""CI perf-regression gate for the cohort execution engine.

Compares the smoke run's ``experiments/fl/cohort_speedup.json`` (written by
``benchmarks/chain_perf.py --cohort-size K``) against the checked-in floors
in ``benchmarks/baseline_thresholds.json`` and exits non-zero on regression:

  * ``speedup``            — vectorized cohort engine vs the sequential
                             path; must stay above ``cohort_speedup_min``
                             (times ``quick_speedup_factor`` under
                             ``--quick``, matching the smaller CI geometry).
  * ``accuracy_gap``       — cohort vs sequential final accuracy; the
                             engines must agree on learning outcome.
  * ``mesh_accuracy_gap``  — (only present when the smoke ran with
                             ``--mesh``) sharded SPMD vs single-device
                             cohort accuracy; mesh partitioning must not
                             change numerics.  This covers the 2-D
                             (clients, data) mesh too: a ``--mesh CxD``
                             smoke's gap compares data-sharded gradients
                             against the single-device path, and
                             ``--require-data-axis`` pins CI to actually
                             exercising it.

The sharded wall-clock is reported but NOT gated: on CI's 2-core runners a
forced 8-device host mesh oversubscribes cores, so its speedup measures the
runner, not the code.  Correctness of the sharded path is gated through
``mesh_accuracy_gap`` and the test suite instead.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_THRESHOLDS = os.path.join(os.path.dirname(__file__),
                                  "baseline_thresholds.json")


def active_thresholds(thresholds: dict, results: dict) -> dict:
    """Per-backend floors: the top-level keys gate the default (cnn) smoke;
    a sub-dict keyed by the results' ``backend`` field (e.g. ``"lm"``)
    overrides them for that suite's smoke."""
    sub = thresholds.get(results.get("backend", "cnn"))
    if isinstance(sub, dict):
        merged = {k: v for k, v in thresholds.items()
                  if not isinstance(v, dict)}
        merged.update(sub)
        return merged
    return thresholds


def check(results: dict, thresholds: dict, quick: bool = False) -> list:
    """Returns a list of failure strings (empty = gate passes)."""
    failures = []
    thresholds = active_thresholds(thresholds, results)
    floor = thresholds["cohort_speedup_min"]
    if quick:
        floor *= thresholds.get("quick_speedup_factor", 1.0)
    speedup = results.get("speedup")
    if speedup is None:
        failures.append("results carry no 'speedup' field — did the smoke "
                        "run with --cohort-size?")
    elif speedup < floor:
        failures.append(f"cohort speedup {speedup:.2f}x below floor "
                        f"{floor:.2f}x")

    gap = results.get("accuracy_gap")
    gap_max = thresholds["accuracy_gap_max"]
    if gap is not None and gap > gap_max:
        failures.append(f"cohort-vs-sequential accuracy gap {gap:.4f} above "
                        f"{gap_max:.4f}")

    mesh_gap = results.get("mesh_accuracy_gap")
    if mesh_gap is not None:
        mesh_max = thresholds["mesh_accuracy_gap_max"]
        if mesh_gap > mesh_max:
            failures.append(f"sharded-vs-single-device accuracy gap "
                            f"{mesh_gap:.4f} above {mesh_max:.4f}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("results", nargs="?",
                    default="experiments/fl/cohort_speedup.json",
                    help="cohort smoke results json")
    ap.add_argument("--thresholds", default=DEFAULT_THRESHOLDS)
    ap.add_argument("--quick", action="store_true",
                    help="apply the quick-mode speedup tolerance")
    ap.add_argument("--require-mesh", action="store_true",
                    help="fail unless the results carry the sharded-engine "
                         "fields (the smoke must have run with --mesh on a "
                         "multi-device host)")
    ap.add_argument("--require-data-axis", action="store_true",
                    help="fail unless the sharded run used a 2-D (clients, "
                         "data) mesh with data > 1 (the smoke must have run "
                         "with --mesh CxD, D >= 2, on a host with enough "
                         "devices)")
    args = ap.parse_args()

    with open(args.results) as f:
        results = json.load(f)
    with open(args.thresholds) as f:
        thresholds = json.load(f)

    failures = check(results, thresholds, quick=args.quick)
    if args.require_mesh and "mesh_accuracy_gap" not in results:
        failures.append("--require-mesh: no sharded-engine results; the "
                        "multi-device smoke did not exercise shard_map")
    if args.require_data_axis and results.get("mesh_data_devices", 1) < 2:
        failures.append("--require-data-axis: the smoke did not exercise "
                        "the 2-D (clients, data) mesh (mesh_data_devices="
                        f"{results.get('mesh_data_devices', 1)})")

    print(f"perf gate[{results.get('backend', 'cnn')}"
          f"{',' + results['mesh_shape'] if 'mesh_shape' in results else ''}"
          f"]: speedup={results.get('speedup', float('nan')):.2f}x "
          f"acc_gap={results.get('accuracy_gap', float('nan')):.4f} "
          f"mesh_acc_gap={results.get('mesh_accuracy_gap', float('nan')):.4f}"
          f" sharded_speedup="
          f"{results.get('sharded_speedup', float('nan')):.2f}x"
          f" (quick={args.quick})")
    if failures:
        for msg in failures:
            print(f"PERF GATE FAIL: {msg}", file=sys.stderr)
        sys.exit(1)
    print("perf gate: PASS")


if __name__ == "__main__":
    main()
