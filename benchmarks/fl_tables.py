"""Paper Tables II & III: accuracy + convergence time, all 10 methods.

One experiment run yields both tables (accuracy and simulated time come from
the same RunResult).  ``fast=True`` is the CI-sized reproduction (1 dataset x
2 distributions x 10 methods); ``fast=False`` sweeps all 3 datasets x 3
distributions like the paper.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

from repro.configs.cnn import vgg_for
from repro.core.simulator import CostModel, make_profiles
from repro.data import (make_benchmark_dataset, partition_dirichlet,
                        partition_iid, split_811)
from repro.fl import ALGORITHMS, CNNBackend, FLConfig

METHOD_ORDER = ["centralized", "independent", "fedavg", "fedhisyn",
                "scalesfl", "fedasync", "csafl", "fedat", "dagfl", "dagafl"]


def make_clients(train, n_clients: int, dist: str, seed: int = 0):
    if dist == "iid":
        parts = partition_iid(train, n_clients, seed)
    else:
        beta = float(dist.split("=")[1])
        parts = partition_dirichlet(train, n_clients, beta, seed)
    client_data = []
    for p in parts:
        s = split_811(p, seed=seed + 1)
        client_data.append({"train": s["train"], "val": s["val"],
                            "test": s["test"]})
    return client_data


TARGETS = {"mnist": 0.95, "cifar10": 0.75, "cifar100": 0.55}


def run_setting(dataset: str, dist: str, *, n_clients=6, max_rounds=12,
                n_samples=1600, local_epochs=2, methods=None, seed=0,
                heterogeneity=1.0, target_accuracy=None) -> Dict[str, Dict]:
    """The paper's regime: resource-limited edge devices => heterogeneity
    ~1.0 (lognormal sigma), so synchronous barriers pay the straggler tail."""
    ds = make_benchmark_dataset(dataset, n_samples=n_samples, seed=seed)
    splits = split_811(ds, seed=seed)
    client_data = make_clients(splits["train"], n_clients, dist, seed)
    backend = CNNBackend(vgg_for(dataset), local_epochs=local_epochs,
                         batch_size=32)
    # the paper's Table III is time-to-convergence: stop at a target
    # validation accuracy (or patience), so async methods' wall-clock
    # advantage is measured rather than rounds-bounded work
    target = (TARGETS.get(dataset) if target_accuracy is None
              else target_accuracy)
    cfg = FLConfig(n_clients=n_clients, max_rounds=max_rounds,
                   local_epochs=local_epochs, seed=seed,
                   heterogeneity=heterogeneity, target_accuracy=target)
    cost = CostModel(local_epoch=6.0)
    profiles = make_profiles(n_clients, heterogeneity, seed)
    out = {}
    for name in (methods or METHOD_ORDER):
        kw = {"pooled_train": splits["train"]} if name == "centralized" else {}
        t0 = time.time()
        res = ALGORITHMS[name](backend, client_data, splits["test"], cfg,
                               cost, profiles, **kw)
        out[name] = {"accuracy": res.final_accuracy,
                     "best": res.best_accuracy,
                     "sim_time": res.sim_time,
                     "rounds": res.rounds,
                     "wall_s": time.time() - t0,
                     "extra": {k: v for k, v in res.extra.items()
                               if isinstance(v, (int, float))}}
    return out


def run_tables(fast: bool = True, out_dir: str = "experiments/fl",
               seed: int = 0):
    if fast:
        grid = [("mnist", "iid"), ("mnist", "beta=0.1")]
        kw = dict(n_clients=6, max_rounds=12, n_samples=1500, local_epochs=1)
    else:
        grid = [(d, s) for d in ("mnist", "cifar10", "cifar100")
                for s in ("iid", "beta=0.1", "beta=0.05")]
        kw = dict(n_clients=10, max_rounds=10, n_samples=4000, local_epochs=2)
    os.makedirs(out_dir, exist_ok=True)
    results = {}
    for dataset, dist in grid:
        key = f"{dataset}/{dist}"
        results[key] = run_setting(dataset, dist, seed=seed, **kw)
    with open(os.path.join(out_dir, "tables.json"), "w") as f:
        json.dump(results, f, indent=2)
    return results


def rows(results) -> List[str]:
    out = []
    for setting, methods in results.items():
        for m, r in methods.items():
            out.append(f"table2_acc[{setting}][{m}],"
                       f"{r['wall_s']*1e6:.0f},{r['accuracy']*100:.2f}")
            out.append(f"table3_time[{setting}][{m}],"
                       f"{r['wall_s']*1e6:.0f},{r['sim_time']:.1f}")
    return out


def robustness_rows(report) -> List[str]:
    """CSV rows for a ``kind=robustness`` report
    (benchmarks/robustness.py): per scenario x method the attacked accuracy
    and the honest-vs-attacked delta (percentage points), plus the DAG
    quarantine metrics for the dagafl legs."""
    out = []
    for name, s in report["scenarios"].items():
        for m, r in s["methods"].items():
            us = r["wall_s"] * 1e6
            out.append(f"robust_acc[{name}][{m}],"
                       f"{us:.0f},{r['attacked_accuracy']*100:.2f}")
            out.append(f"robust_delta[{name}][{m}],"
                       f"{us:.0f},{r['accuracy_delta']*100:.2f}")
        dag = s.get("dag", {})
        if dag:
            us = s["methods"]["dagafl"]["wall_s"] * 1e6
            out.append(f"robust_approval[{name}][dagafl],{us:.0f},"
                       f"{dag['poisoned_tip_approval_rate']*100:.2f}")
            out.append(f"robust_orphaned[{name}][dagafl],{us:.0f},"
                       f"{dag['orphaned_malicious_frac']*100:.2f}")
            out.append(f"robust_detections[{name}][dagafl],{us:.0f},"
                       f"{dag['tamper_detections']}")
    return out
