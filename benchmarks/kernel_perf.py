"""Per-kernel micro-benchmarks for the dispatch layer's hot-path swaps.

For each swapped op (Eq. 3 signature buckets, Eq. 3 per-channel CNN rows,
LM attention) this times the incumbent jnp math against the kernel path on
the shapes the cohort suites actually emit, and records an ANALYTIC
intermediate-footprint/HBM-traffic estimate for both paths:

* the jnp signature materializes the full (T, d) f32 flag tensor (plus the
  padded reshape copy when ``d % n_sig != 0``) before reducing it;
* the kernel accumulates per-channel counts in a (d,)-scratch across
  block_t-row tiles — the flag tensor never exists outside VMEM.

The byte numbers are derived from shapes, not measured, so they are
deterministic on any runner — that is what lets CI gate on
``signature_intermediate_ratio_max`` (no materialized (T, d) intermediate)
without wall-clock flake.  Wall-clock is measured jitted, synced with
``block_until_ready``, best-of-``--repeats``; the gate only applies the
generous ``signature_rel_time_max`` parity floor in interpret mode (the
interpreter is an emulation, not the product of the swap).

Writes ``experiments/fl/kernel_perf.json`` (``kind: kernel_perf``) for
``check_perf_gate.py`` and ``benchmarks/roofline.py``'s kernel table.
"""
from __future__ import annotations

import argparse
import json
import os
import time

F32 = 4


def _time_best(fn, args, repeats: int) -> float:
    import jax
    jax.block_until_ready(fn(*args))          # compile + warm cache
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _sig_bytes(T: int, d: int, n_sig: int) -> dict:
    """Analytic intermediate/HBM-traffic estimate for one signature call."""
    pad = (-d) % n_sig
    return {
        # materialized between ops: flags (T,d) + padded copy when ragged
        "jnp_intermediate_bytes": T * d * F32 + (T * (d + pad) * F32
                                                 if pad else 0),
        # VMEM accumulator; the flag tile never reaches HBM
        "kernel_intermediate_bytes": d * F32,
        # read x, write flags, re-read flags for the reduce vs read x once
        "jnp_hbm_bytes": 3 * T * d * F32,
        "kernel_hbm_bytes": T * d * F32 + d * F32,
    }


def _attn_bytes(B: int, S: int, H: int, hd: int) -> dict:
    """Dense softmax materializes two (B,H,S,S) score tensors; the flash
    kernel streams K/V tiles against an O(S*hd) accumulator."""
    scores = B * H * S * S * F32
    qkv = 3 * B * S * H * hd * F32
    return {
        "jnp_intermediate_bytes": 2 * scores,
        "kernel_intermediate_bytes": B * S * H * hd * F32,
        "jnp_hbm_bytes": qkv + 4 * scores,
        "kernel_hbm_bytes": qkv + B * S * H * hd * F32,
    }


def _bench_signature(shapes, policy, repeats):
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops as kops
    from repro.models.layers import activation_signature

    out = []
    for T, d, n_sig in shapes:
        x = jax.random.normal(jax.random.PRNGKey(T + d), (T, d))
        x = jnp.where(jnp.abs(x) < 0.2, 0.0, x)
        jnp_fn = jax.jit(lambda a: activation_signature(a, n_sig=n_sig,
                                                        tau=0.05))
        ker_fn = jax.jit(lambda a: kops.signature(a, tau=0.05, n_sig=n_sig,
                                                  policy=policy))
        t_jnp = _time_best(jnp_fn, (x,), repeats)
        t_ker = _time_best(ker_fn, (x,), repeats)
        rec = {"name": "signature", "shape": [T, d], "n_sig": n_sig,
               "jnp_ms": t_jnp * 1e3, "kernel_ms": t_ker * 1e3,
               "rel_time": t_ker / max(t_jnp, 1e-9)}
        rec.update(_sig_bytes(T, d, n_sig))
        rec["intermediate_ratio"] = (rec["kernel_intermediate_bytes"]
                                     / max(rec["jnp_intermediate_bytes"], 1))
        out.append(rec)
    return out


def _bench_signature_per_channel(shapes, policy, repeats):
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops as kops

    out = []
    for N, H, W, C in shapes:
        x = jax.nn.relu(
            jax.random.normal(jax.random.PRNGKey(N + C), (N, H, W, C)) - 0.3)
        jnp_fn = jax.jit(lambda a: jnp.mean((a == 0.0).astype(jnp.float32),
                                            axis=(1, 2)))
        ker_fn = jax.jit(lambda a: kops.signature_per_channel(
            a, tau=0.0, policy=policy))
        t_jnp = _time_best(jnp_fn, (x,), repeats)
        t_ker = _time_best(ker_fn, (x,), repeats)
        rec = {"name": "signature_per_channel", "shape": [N, H, W, C],
               "jnp_ms": t_jnp * 1e3, "kernel_ms": t_ker * 1e3,
               "rel_time": t_ker / max(t_jnp, 1e-9)}
        b = _sig_bytes(H * W, C, C)              # per-sample tile
        rec.update({k: v * N for k, v in b.items()})
        rec["intermediate_ratio"] = (rec["kernel_intermediate_bytes"]
                                     / max(rec["jnp_intermediate_bytes"], 1))
        out.append(rec)
    return out


def _bench_flash_attention(shapes, policy, repeats):
    import jax

    from repro.kernels import ops as kops
    from repro.kernels import ref

    out = []
    for B, S, H, hd in shapes:
        ks = jax.random.split(jax.random.PRNGKey(S + hd), 3)
        q = jax.random.normal(ks[0], (B, S, H, hd))
        k = jax.random.normal(ks[1], (B, S, H, hd))
        v = jax.random.normal(ks[2], (B, S, H, hd))
        jnp_fn = jax.jit(lambda a, b, c: ref.flash_attention_ref(
            a.transpose(0, 2, 1, 3), b.transpose(0, 2, 1, 3),
            c.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3))
        ker_fn = jax.jit(lambda a, b, c: kops.flash_attention(
            a, b, c, policy=policy))
        t_jnp = _time_best(jnp_fn, (q, k, v), repeats)
        t_ker = _time_best(ker_fn, (q, k, v), repeats)
        rec = {"name": "flash_attention", "shape": [B, S, H, hd],
               "jnp_ms": t_jnp * 1e3, "kernel_ms": t_ker * 1e3,
               "rel_time": t_ker / max(t_jnp, 1e-9)}
        rec.update(_attn_bytes(B, S, H, hd))
        rec["intermediate_ratio"] = (rec["kernel_intermediate_bytes"]
                                     / max(rec["jnp_intermediate_bytes"], 1))
        out.append(rec)
    return out


def run(policy=None, quick: bool = False, repeats: int = 5) -> dict:
    import jax

    from repro.kernels.dispatch import resolve_policy
    p = resolve_policy(policy)
    if quick:
        sig_shapes = [(63, 64, 64), (63, 100, 64)]       # LM cohort rows
        chan_shapes = [(32, 16, 16, 16)]                 # vgg-tiny sig maps
        attn_shapes = [(4, 64, 4, 16)]                   # reduced LM eval
    else:
        sig_shapes = [(63, 64, 64), (256, 2048, 64), (512, 1000, 64)]
        chan_shapes = [(32, 16, 16, 16), (64, 28, 28, 32)]
        attn_shapes = [(4, 64, 4, 16), (8, 256, 8, 64)]
    kernels = (_bench_signature(sig_shapes, p, repeats)
               + _bench_signature_per_channel(chan_shapes, p, repeats)
               + _bench_flash_attention(attn_shapes, p, repeats))
    return {"kind": "kernel_perf", "policy": p,
            "platform": jax.default_backend(), "quick": quick,
            "repeats": repeats, "kernels": kernels}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--policy", default=None,
                    choices=[None, "auto", "compiled", "interpret",
                             "reference"],
                    help="kernel policy for the kernel leg (default: "
                         "platform auto-resolution)")
    ap.add_argument("--quick", action="store_true",
                    help="CI geometry: small shapes, fewer repeats")
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--out-dir", default="experiments/fl")
    args = ap.parse_args()

    res = run(policy=args.policy, quick=args.quick,
              repeats=max(2, args.repeats // 2) if args.quick
              else args.repeats)
    os.makedirs(args.out_dir, exist_ok=True)
    out = os.path.join(args.out_dir, "kernel_perf.json")
    with open(out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"kernel_perf [{res['policy']} on {res['platform']}]")
    for r in res["kernels"]:
        print(f"  {r['name']:>22} {str(r['shape']):>18}: "
              f"jnp {r['jnp_ms']:7.2f} ms  kernel {r['kernel_ms']:7.2f} ms "
              f"(x{r['rel_time']:.2f})  intermediates "
              f"{r['jnp_intermediate_bytes']:>10,} -> "
              f"{r['kernel_intermediate_bytes']:>8,} B "
              f"(x{r['intermediate_ratio']:.4f})")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
