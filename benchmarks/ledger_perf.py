"""Day-in-the-life ledger benchmark: a six-figure client population on the
bounded-frontier DAG (see DESIGN.md and benchmarks/check_perf_gate.py).

Simulates one day of DAG-AFL ledger traffic with REAL ledger operations —
tip selection over the freshness index, metadata appends, checkpoint+prune
folds, incremental hash audits — while model training is replaced by the
simulator's cost model (the cohort engine's wall-clock is benchmarked
separately by chain_perf.py; here the LEDGER is the system under test).

Each client wakes ``--rounds`` times at random points of the simulated day,
selects tips through :class:`TipSelector` (freshness-capped candidates),
publishes a metadata transaction, and deposits a stand-in model in the
:class:`ModelStore`.  A maintenance cadence rides the simulated clock:
an anti-orphan sweep approves tips stale enough that freshness-capped
selection would never pick them (otherwise one forgotten tip stalls
confirmation forever), then the ledger folds confirmed ancestry into a
checkpoint and evicts pruned models, and the :class:`IncrementalVerifier`
audits the appends since its last pass.

What the perf gate consumes (all deterministic — event counts, not wall
time, so 2-core CI runners gate the CODE, not the machine):

  * ``peak_live_frac``   — peak live-transaction count / total published;
                           bounded by the consensus frontier, NOT history.
  * ``peak_store_frac``  — peak ModelStore entries / total models; pruning
                           must evict model bodies, not just metadata.
  * ``select_work_vs_history`` — mean per-selection ledger work
                           (reachability log entries + BFS visits +
                           tip-heap pops) over the LAST quarter of rounds,
                           divided by total transactions: ~1 for a
                           linear-in-history implementation (whole-DAG BFS
                           or all-tips scan), orders of magnitude below
                           for index-backed selection.  The Q2-vs-Q4
                           ``select_work_ratio`` is reported for the
                           trajectory artifact but not gated: the frontier
                           legitimately widens as client epochs disperse
                           across the day, which moves the ratio for
                           reasons unrelated to history size.
  * ``pruned_frac``      — fraction of history actually folded away.
  * ``verify_ok``        — every incremental audit + the final full audit
                           (Eq. 7 re-derivation + checkpoint roots) passed.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from functools import partial

import numpy as np

from repro.core.dag import BoundedDAGLedger, ModelStore, TxMetadata
from repro.core.simulator import CostModel, EventLoop, make_profiles
from repro.core.tip_selection import (FnTipEvaluator, TipSelectionConfig,
                                      TipSelectionRequest, TipSelector)
from repro.core.verify import IncrementalVerifier, verify_full_dag

SWEEP_CLIENT = -2          # the maintenance sweep's client id on chain


def _meta(cid: int, epoch: int) -> TxMetadata:
    return TxMetadata(client_id=cid, signature=(0.1, 0.2), model_accuracy=0.5,
                      current_epoch=epoch, validation_node_id=cid)


def _accuracy(cid: int, tx_id: str) -> float:
    """Deterministic stand-in for local validation accuracy.

    Salted per CLIENT: each client ranks candidates differently, like real
    non-IID local validation sets do.  With one global ranking every
    concurrent client approves the same two tips, approvals become
    redundant, and the tangle degenerates into a sweep-fed orphan farm.
    """
    return ((int(tx_id[2:]) * 1_000_003 + cid * 7_919) % 97) / 97.0 + 0.01


class DayInTheLife:
    def __init__(self, args):
        self.args = args
        self.rng = np.random.default_rng(args.seed)
        self.cost = CostModel()
        self.profiles = make_profiles(args.n_clients, seed=args.seed)
        self.loop = EventLoop()
        self.store = ModelStore()
        self.ledger = BoundedDAGLedger(evict_fn=self._evict)
        self.selector = TipSelector(
            self.ledger, None,
            TipSelectionConfig(n_select=args.n_select, lam=0.5,
                               use_similarity=False,
                               max_tip_candidates=args.max_tip_candidates))
        self.verifier = IncrementalVerifier(self.ledger)
        self.epochs = np.zeros(args.n_clients, dtype=np.int64)
        self.total_rounds = args.n_clients * args.rounds
        self.round_work = np.zeros(self.total_rounds, dtype=np.int64)
        self.rounds_done = 0
        self.selects_done = 0
        self.sweeps = 0
        self.ticks = 0
        self.sim_cost_total = 0.0
        self.peak_live = 0
        self.peak_store = 0
        self.peak_tips = 0
        self.verify_ok = True
        self.trajectory = []

    # -- ledger-side hooks ---------------------------------------------------

    def _evict(self, tx) -> None:
        self.store.evict(tx.model_ref)

    def _work(self) -> int:
        led = self.ledger
        return (led.stat_reach_processed + led.stat_reach_bfs
                + led.stat_tip_heap_pops)

    # -- one client round ----------------------------------------------------
    #
    # Two events per round, like a real async client: tips are selected at
    # wake time, the transaction lands after the simulated round duration
    # (training + fetches + publish).  Collapsing both into one instant
    # serialises the tangle into a chain — each tx would approve ALL tips
    # and instantly confirm everything — so tangle width comes from rounds
    # OVERLAPPING in simulated time, exactly as in the deployed system.

    def client_round(self, c: int) -> None:
        led, loop = self.ledger, self.loop
        epoch = int(self.epochs[c])
        self.epochs[c] += 1
        w0 = self._work()
        req = TipSelectionRequest(client_id=c, cur_epoch=epoch, now=loop.now,
                                  round_idx=epoch)
        scores = self.selector.select(
            req, FnTipEvaluator(partial(_accuracy, c)))
        self.round_work[self.selects_done] = self._work() - w0
        self.selects_done += 1
        parents = tuple(s.tx_id for s in scores) or (led.genesis_id,)
        # simulated round duration (the Table III accounting): local
        # training + candidate validation + per-selected-tip model fetch +
        # metadata publish
        prof = self.profiles[c]
        duration = (
            self.cost.train_time(prof, 1, self.rng)
            + self.cost.eval_time(prof, len(scores))
            + len(scores) * self.cost.transfer_time(prof,
                                                    self.cost.model_bytes)
            + self.cost.chain_op * len(scores)
            + self.cost.transfer_time(prof, self.cost.metadata_bytes))
        self.sim_cost_total += duration
        loop.schedule(duration, partial(self.publish, c, epoch, parents))

    def publish(self, c: int, epoch: int, parents: tuple) -> None:
        # a selected tip may have confirmed (and been pruned) while this
        # round trained — the bounded ledger approves pruned parents by
        # their retained hashes, so the publish still lands
        ref = self.store.put(f"m{self.rounds_done:012d}", (c, epoch))
        self.ledger.add_transaction(_meta(c, epoch + 1), parents,
                                    self.loop.now, ref)
        self.rounds_done += 1

    # -- maintenance cadence -------------------------------------------------

    def maintain(self) -> None:
        led, loop, args = self.ledger, self.loop, self.args
        # anti-orphan sweep: freshness-capped selection never approves a tip
        # older than the candidate window, and ONE forgotten tip stalls
        # confirmation (confirmed = common ancestry of ALL tips) — approve
        # stale tips explicitly so the frontier keeps folding
        order = led.tips_by_freshness(None)          # freshest -> stalest
        cutoff = loop.now - args.orphan_age
        stale = []
        for t in reversed(order):
            if led.get_tx(t).timestamp >= cutoff:
                break
            stale.append(t)
        # the sweep tx must rank like a normal fresh tip — published at epoch
        # 0 its Eq. 1 epoch-gap factor makes it unselectable, it orphans in
        # turn, and every sweep spawns the next confirmation blocker
        sweep_epoch = (led.get_tx(order[0]).metadata.current_epoch
                       if order else 0)
        for i in range(0, len(stale), 8):
            led.add_transaction(_meta(SWEEP_CLIENT, sweep_epoch),
                                tuple(stale[i:i + 8]), loop.now)
            self.sweeps += 1
        self.ticks += 1
        if self.ticks % args.audit_every_ticks == 0:
            # audit BEFORE the checkpoint folds: every tx appended since the
            # last tick is still live here, so with the default per-tick
            # cadence each tx gets its Eq. 7 hash re-derived exactly once
            # before its body can be pruned away
            ok, reason = self.verifier.audit()
            if not ok:
                self.verify_ok = False
                print(f"AUDIT FAIL at t={loop.now:.0f}: {reason}")
        led.maybe_checkpoint(now=loop.now)
        self.peak_live = max(self.peak_live, len(led))
        self.peak_store = max(self.peak_store, len(self.store))
        self.peak_tips = max(self.peak_tips, len(led.tips()))
        self.trajectory.append({
            "sim_t": round(loop.now, 1), "rounds": self.rounds_done,
            "live_tx": len(led), "pruned": led.n_pruned,
            "tips": len(led.tips()), "store": len(self.store),
            "work": int(self._work()),
        })

    # -- run -----------------------------------------------------------------

    def run(self) -> dict:
        args = self.args
        self.ledger.add_genesis(_meta(-1, 0), 0.0,
                                self.store.put("genesis", (-1, 0)))
        wake = self.rng.uniform(0.0, args.day,
                                size=(args.n_clients, args.rounds))
        wake.sort(axis=1)
        for c in range(args.n_clients):
            for t in wake[c]:
                self.loop.schedule(float(t), partial(self.client_round, c))
        self.loop.schedule_every(args.maintain_every, self.maintain)

        t0 = time.perf_counter()
        self.loop.run(max_events=10 * self.total_rounds + 100_000)
        # final fold + audit over whatever the day left behind
        self.maintain()
        wall = time.perf_counter() - t0

        ok, reason = self.verifier.audit()
        if not ok:
            self.verify_ok = False
            print(f"FINAL AUDIT FAIL: {reason}")
        ok, reason = verify_full_dag(self.ledger)
        if not ok:
            self.verify_ok = False
            print(f"FULL VERIFY FAIL: {reason}")

        assert self.rounds_done == self.total_rounds, \
            f"dropped rounds: {self.rounds_done}/{self.total_rounds}"
        led = self.ledger
        total_tx = len(led) + led.n_pruned
        # per-select ledger work, second quarter vs last: Q2 is past the
        # warmup ramp (the frontier reaches steady state within the first
        # quarter even in --quick geometry) but has only ~1/3 of the final
        # history behind it — flat work from Q2 to Q4 is the sub-linearity
        # evidence
        q = self.total_rounds // 4
        mid_q = float(np.mean(self.round_work[q:2 * q])) if q else 1.0
        last_q = float(np.mean(self.round_work[-q:])) if q else 1.0
        traj = self.trajectory
        if len(traj) > 200:                  # bound the artifact size
            traj = traj[:: len(traj) // 200 + 1]
        return {
            "kind": "ledger_day",
            "n_clients": args.n_clients, "rounds_per_client": args.rounds,
            "day_seconds": args.day, "maintain_every": args.maintain_every,
            "orphan_age": args.orphan_age,
            "max_tip_candidates": args.max_tip_candidates,
            "total_rounds": self.total_rounds, "sweep_txs": self.sweeps,
            "total_tx": total_tx,
            "checkpoints": len(led.checkpoints),
            "pruned": led.n_pruned,
            "pruned_frac": led.n_pruned / max(total_tx, 1),
            "peak_live_tx": self.peak_live,
            "peak_live_frac": self.peak_live / max(total_tx, 1),
            "peak_store": self.peak_store,
            "peak_store_frac": self.peak_store / max(self.total_rounds + 1,
                                                     1),
            "peak_tips": self.peak_tips,
            "final_live_tx": len(led), "final_store": len(self.store),
            "select_work_mid_quarter": mid_q,
            "select_work_last_quarter": last_q,
            "select_work_ratio": last_q / max(mid_q, 1e-9),
            "select_work_vs_history": last_q / max(total_tx, 1),
            "reach_log_entries": int(led.stat_reach_processed),
            "reach_bfs_visits": int(led.stat_reach_bfs),
            "tip_heap_pops": int(led.stat_tip_heap_pops),
            "audit_txs_checked": self.verifier.txs_checked,
            "audit_tx_ratio": self.verifier.txs_checked / max(total_tx, 1),
            "audit_checkpoints_checked": self.verifier.checkpoints_checked,
            "verify_ok": self.verify_ok,
            "sim_cost_mean_s": self.sim_cost_total / self.total_rounds,
            "wall_seconds": round(wall, 2),
            "rounds_per_wall_second": round(self.total_rounds / wall, 1),
            "trajectory": traj,
        }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n-clients", type=int, default=100_000)
    ap.add_argument("--rounds", type=int, default=3,
                    help="publishes per client over the day")
    ap.add_argument("--day", type=float, default=86_400.0)
    # a 64-candidate window at ~3.5 appends/s turns over in ~20 simulated
    # seconds, so an unselected tip is effectively orphaned within a minute
    # — and ONE live orphan blocks confirmation of everything newer than
    # it.  The sweep cadence must track that window turnover, not the day
    # length: at a 600 s cadence the orphan inventory reaches thousands of
    # tips and the live region inflates ~50x before sweeps catch up.
    ap.add_argument("--maintain-every", type=float, default=120.0,
                    help="sweep/checkpoint/audit cadence (simulated s)")
    ap.add_argument("--orphan-age", type=float, default=360.0)
    ap.add_argument("--max-tip-candidates", type=int, default=64)
    ap.add_argument("--n-select", type=int, default=2)
    ap.add_argument("--audit-every-ticks", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="reduced population for CI (2000 clients); the day "
                         "shrinks too, keeping the arrival rate — and so "
                         "the tangle width — at the full-scale level")
    ap.add_argument("--out-dir", default="experiments/fl")
    args = ap.parse_args()
    if args.quick:
        # same ~3.5 appends / simulated second as the full-scale default
        # (tangle width = arrival rate x round duration, so a slower quick
        # rate would test a thinner, easier tangle), compressed into a
        # shorter day with proportionally faster maintenance
        args.n_clients = min(args.n_clients, 2_000)
        args.rounds = max(args.rounds, 6)
        args.day = min(args.day, 3_600.0)
        args.maintain_every = min(args.maintain_every, 60.0)
        args.orphan_age = min(args.orphan_age, 180.0)

    res = DayInTheLife(args).run()
    os.makedirs(args.out_dir, exist_ok=True)
    out = os.path.join(args.out_dir, "ledger_day.json")
    with open(out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"ledger day-in-the-life: {res['total_tx']} txs "
          f"({res['n_clients']} clients x {res['rounds_per_client']} rounds "
          f"+ {res['sweep_txs']} sweeps), "
          f"peak live {res['peak_live_tx']} "
          f"({100 * res['peak_live_frac']:.1f}% of history), "
          f"peak store {res['peak_store']}, "
          f"pruned {100 * res['pruned_frac']:.1f}%, "
          f"work/select {res['select_work_last_quarter']:.0f} "
          f"({res['select_work_vs_history']:.4f} of history), "
          f"verify_ok={res['verify_ok']}, wall {res['wall_seconds']}s")
    print(f"results -> {out}")


if __name__ == "__main__":
    main()
