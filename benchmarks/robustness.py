"""Robustness benchmark: the adversarial & systems-heterogeneity suite.

Runs the fault-injection scenarios in ``repro.fl.scenarios.SCENARIOS``
against DAG-AFL and (for the poison scenario) the fedavg/fedasync baselines,
and emits a ``kind=robustness`` JSON report gated in CI by
``benchmarks/check_perf_gate.py``.

What each scenario measures
---------------------------
* ``attacked_accuracy`` is the accuracy experienced by the clients NOT
  playing a hostile role: for the server baselines that is the global model
  (honest clients have no choice but to absorb whatever the server
  aggregated), for DAG-AFL it is the mean global-test accuracy of the
  would-be-honest clients' latest published models.  The same client ids
  are excluded from the honest reference run, so the honest-vs-attacked
  delta isolates the attack, not the client subset.  This is exactly the
  quarantine claim: DAG-AFL's tip selection validates candidate tips on
  each client's own data, so poisoned lineages score near zero and honest
  clients route around them, while a synchronous server average has no such
  defense.
* ``dag`` metrics quantify the quarantine structurally
  (``poisoned_tip_approval_rate``, ``orphaned_malicious_frac`` — see
  :func:`repro.fl.scenarios.dag_attack_metrics`) and exercise Eq. 7:
  tampered metadata must be caught, exactly, by
  :func:`repro.core.verify.detect_tampered` and flagged by the
  :class:`repro.core.verify.IncrementalVerifier`.
* ``determinism`` reruns the attacked DAG-AFL leg with a fresh injector at
  the same seed and requires identical fault-event counts and detection
  sets.  Convergence tracking is disabled (patience >> max_rounds), so
  every event count is a pure function of the seed — the gate pins counts,
  never accuracies or wall-clock.

Usage::

  python benchmarks/robustness.py --quick                      # full matrix
  python benchmarks/robustness.py --quick --scenario poison    # one scenario
  python benchmarks/robustness.py --summarize experiments/fl/robustness.json

``--summarize`` prints a GitHub-flavoured markdown table (CI posts it to
``$GITHUB_STEP_SUMMARY``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from dataclasses import asdict, replace
from typing import Dict, List, Optional

import numpy as np

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (REPO_ROOT, os.path.join(REPO_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

from benchmarks.chain_perf import _make_cnn_world  # noqa: E402

SCENARIO_ORDER = ["poison", "lazy", "dp", "straggler", "dropout"]

#: the scenario's primary event counter — the gate requires it nonzero
EVENT_KEYS = {"poison": "updates_scaled", "lazy": "updates_lazy",
              "dp": "updates_noised", "straggler": "straggler_draws",
              "dropout": "publishes_dropped"}


def _geometry(quick: bool) -> Dict:
    if quick:
        return dict(n_clients=8, n_samples=1600, max_rounds=3,
                    local_epochs=1, cohort_size=4, cohort_window=2.0)
    return dict(n_clients=12, n_samples=4000, max_rounds=5,
                local_epochs=2, cohort_size=6, cohort_window=2.0)


class _World:
    """One shared (backend, data, cost, profiles) quintuple per report, so
    every method and scenario sees identical shards and device speeds."""

    def __init__(self, geo: Dict, seed: int):
        from repro.core.simulator import CostModel, make_profiles
        self.geo = geo
        self.seed = seed
        self.backend, self.client_data, self.test = _make_cnn_world(
            geo["n_clients"], geo["n_samples"], geo["local_epochs"], seed)
        self.cost_args = dict(local_epoch=6.0)
        self._cost_cls = CostModel
        self.profiles = make_profiles(geo["n_clients"], 1.0, seed)

    def cost(self):
        # fresh per run: CostModel.model_bytes is mutated by each harness
        return self._cost_cls(**self.cost_args)


def _run_dagafl(world: _World, scenario=None):
    """One coordinator run; convergence tracking disabled so the event
    stream (and every scenario counter) is a pure function of the seed."""
    from repro.core.coordinator import DagAflConfig, DagAflCoordinator
    geo = world.geo
    cfg = DagAflConfig(
        n_clients=geo["n_clients"], max_rounds=geo["max_rounds"],
        local_epochs=geo["local_epochs"], seed=world.seed,
        cohort_size=geo["cohort_size"], cohort_window=geo["cohort_window"],
        target_accuracy=None, patience=10 ** 6, scenario=scenario)
    t0 = time.time()
    coord = DagAflCoordinator(world.backend, world.client_data, world.test,
                              cfg, world.cost(), world.profiles)
    res = coord.run()
    return coord, res, time.time() - t0


def _run_baseline(world: _World, algo: str, scenario=None):
    from repro.fl import ALGORITHMS, FLConfig
    geo = world.geo
    cfg = FLConfig(
        n_clients=geo["n_clients"], max_rounds=geo["max_rounds"],
        local_epochs=geo["local_epochs"], seed=world.seed,
        cohort_size=geo["cohort_size"], cohort_window=geo["cohort_window"],
        target_accuracy=None, patience=10 ** 6, scenario=scenario)
    t0 = time.time()
    res = ALGORITHMS[algo](world.backend, world.client_data, world.test,
                           cfg, world.cost(), world.profiles)
    return res, time.time() - t0


def _honest_client_mean(world: _World, coord, exclude) -> float:
    """Mean global-test accuracy of the NON-excluded clients' latest
    published models — what an honest participant actually ends up with."""
    models = []
    for c in range(world.geo["n_clients"]):
        if c in exclude:
            continue
        tx = coord.ledger.latest_of(c)
        if tx is None or not coord.ledger.has_tx(tx):
            continue
        ref = coord.ledger.get_tx(tx).model_ref
        if ref in coord.store:
            models.append(coord.store.get(ref))
    if not models:
        return 0.0
    if coord.cohort is not None:
        accs = coord.cohort.evaluate_many(models, world.test)
    else:
        accs = [world.backend.evaluate(m, world.test) for m in models]
    return float(np.mean(accs))


def _method_entry(honest_acc, attacked_acc, res, wall) -> Dict:
    return {"honest_accuracy": honest_acc,
            "attacked_accuracy": attacked_acc,
            "accuracy_delta": honest_acc - attacked_acc,
            "sim_time": res.sim_time, "rounds": res.rounds,
            "wall_s": wall}


def _verification_leg(coord, scenario) -> Dict:
    """Eq. 7 audit of the attacked run's ledger: the counting sweep must
    return EXACTLY the tampered set, and the incremental verifier must
    flag the ledger iff tampering happened."""
    from repro.core.verify import IncrementalVerifier, detect_tampered
    detected = detect_tampered(coord.ledger)
    iv_ok, _ = IncrementalVerifier(coord.ledger).audit()
    return {"tamper_detections": len(detected),
            "txs_tampered": len(scenario.tampered),
            "detections_exact": sorted(detected) == sorted(scenario.tampered),
            "incremental_audit_flagged": not iv_ok}


def run_robustness(scenarios: Optional[List[str]] = None, quick: bool = True,
                   seed: int = 0, out_dir: str = "experiments/fl",
                   determinism: bool = True) -> Dict:
    from repro.fl.scenarios import (SCENARIOS, Scenario, dag_attack_metrics)
    names = scenarios or SCENARIO_ORDER
    geo = _geometry(quick)
    world = _World(geo, seed)
    n = geo["n_clients"]

    report = {"kind": "robustness", "quick": quick, "seed": seed, **geo,
              "scenarios": {}}

    # one honest DAG-AFL reference run, shared by every scenario; the
    # baselines' honest runs only matter for poison, run lazily below
    print(f"# robustness: honest dagafl reference "
          f"(n={n}, rounds={geo['max_rounds']})", file=sys.stderr)
    honest_coord, honest_res, honest_wall = _run_dagafl(world)
    honest_baselines: Dict[str, tuple] = {}

    for name in names:
        cfg = replace(SCENARIOS[name], seed=seed)
        sc = Scenario(cfg, n)
        print(f"# robustness: scenario '{name}' "
              f"(malicious={sorted(sc.malicious)}, lazy={sorted(sc.lazy)}, "
              f"stragglers={sorted(sc.stragglers)})", file=sys.stderr)
        coord, res, wall = _run_dagafl(world, scenario=sc)
        honest_acc = _honest_client_mean(world, honest_coord, sc.malicious)
        attacked_acc = _honest_client_mean(world, coord, sc.malicious)
        entry = {
            "config": asdict(cfg),
            "methods": {"dagafl": _method_entry(honest_acc, attacked_acc,
                                                res, wall)},
            "counts": sc.counts(),
            "dag": {**dag_attack_metrics(coord.ledger, sc),
                    **_verification_leg(coord, sc)},
        }

        if name == "poison":
            # the headline comparison: server baselines lack the defense
            for algo in ("fedavg", "fedasync"):
                if algo not in honest_baselines:
                    honest_baselines[algo] = _run_baseline(world, algo)
                hres, hwall = honest_baselines[algo]
                ares, awall = _run_baseline(
                    world, algo, scenario=Scenario(cfg, n))
                entry["methods"][algo] = _method_entry(
                    hres.final_accuracy, ares.final_accuracy, ares, awall)

        if determinism:
            sc2 = Scenario(cfg, n)
            coord2, _, _ = _run_dagafl(world, scenario=sc2)
            ver2 = _verification_leg(coord2, sc2)
            entry["determinism"] = {
                "counts_match": sc.counts() == sc2.counts(),
                "detections_match":
                    ver2["tamper_detections"] == entry["dag"][
                        "tamper_detections"] and ver2["detections_exact"],
                "counts_a": sc.counts(), "counts_b": sc2.counts(),
            }
        report["scenarios"][name] = entry

    os.makedirs(out_dir, exist_ok=True)
    fname = (f"robustness_{names[0]}.json" if len(names) == 1
             else "robustness.json")
    out_path = os.path.join(out_dir, fname)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# robustness report -> {out_path}", file=sys.stderr)
    return report


def summarize_markdown(report: Dict) -> str:
    """GitHub-flavoured markdown scenario table for $GITHUB_STEP_SUMMARY."""
    lines = ["## Robustness scenario suite",
             "",
             f"geometry: {report['n_clients']} clients x "
             f"{report['max_rounds']} rounds, cohort_size="
             f"{report['cohort_size']}, seed={report['seed']}, "
             f"quick={report.get('quick')}",
             "",
             "| scenario | method | honest acc | attacked acc | delta |"
             " approval rate | orphaned mal/honest | tampered/detected |"
             " deterministic |",
             "|---|---|---|---|---|---|---|---|---|"]
    for name, s in report["scenarios"].items():
        dag = s.get("dag", {})
        det = s.get("determinism", {})
        det_ok = ("yes" if det.get("counts_match")
                  and det.get("detections_match") else
                  ("NO" if det else "-"))
        for method, m in s["methods"].items():
            is_dag = method == "dagafl"
            lines.append(
                f"| {name} | {method} "
                f"| {m['honest_accuracy']:.3f} "
                f"| {m['attacked_accuracy']:.3f} "
                f"| {m['accuracy_delta']:+.3f} "
                f"| {dag.get('poisoned_tip_approval_rate', 0):.3f}"
                f"{'' if is_dag else ' (n/a)'} "
                f"| {dag.get('orphaned_malicious_frac', 0):.2f}/"
                f"{dag.get('orphaned_honest_frac', 0):.2f}"
                f"{'' if is_dag else ' (n/a)'} "
                f"| {dag.get('txs_tampered', 0)}/"
                f"{dag.get('tamper_detections', 0)}"
                f"{'' if is_dag else ' (n/a)'} "
                f"| {det_ok if is_dag else '-'} |")
    return "\n".join(lines) + "\n"


def main() -> None:
    from repro.fl.scenarios import SCENARIOS
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized geometry (8 clients x 3 rounds)")
    ap.add_argument("--scenario", action="append", default=None,
                    choices=sorted(SCENARIOS),
                    help="run only this scenario (repeatable; default: all)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out-dir", default="experiments/fl")
    ap.add_argument("--no-determinism", action="store_true",
                    help="skip the same-seed rerun (faster local iteration; "
                         "the CI gate requires the determinism leg)")
    ap.add_argument("--summarize", metavar="JSON", default=None,
                    help="print the markdown summary of an existing report "
                         "and exit")
    args = ap.parse_args()

    if args.summarize:
        with open(args.summarize) as f:
            print(summarize_markdown(json.load(f)), end="")
        return

    report = run_robustness(scenarios=args.scenario, quick=args.quick,
                            seed=args.seed, out_dir=args.out_dir,
                            determinism=not args.no_determinism)
    from benchmarks import fl_tables
    print("name,us_per_call,derived")
    for row in fl_tables.robustness_rows(report):
        print(row)


if __name__ == "__main__":
    main()
