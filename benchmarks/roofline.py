"""§Roofline: format the dry-run artifacts into the per-(arch x shape) table.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and emits
the three-term roofline with dominant-bottleneck classification.  No jax
needed — this is pure artifact post-processing, so it runs in benchmarks.run
without touching device state.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dryrun_dir: str = "experiments/dryrun", multi_pod: bool = False,
         plan: str = "baseline"):
    out = {}
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        r = json.load(open(f))
        if r.get("multi_pod", False) != multi_pod:
            continue
        r_plan = r.get("plan") or "baseline"
        if r_plan != ("auto" if plan == "auto" else "baseline"):
            continue
        out[(r["arch"], r["shape"])] = r
    return out


def table(records: Dict) -> List[str]:
    lines = ["| arch | shape | compute ms | memory ms | collective ms | "
             "dominant | useful-flop ratio | HBM GiB/chip |",
             "|---|---|---|---|---|---|---|---|"]
    for (arch, shape) in sorted(records, key=lambda k: (k[0],
                                                        SHAPE_ORDER.index(k[1]))):
        r = records[(arch, shape)]
        if not r.get("ok"):
            lines.append(f"| {arch} | {shape} | FAILED | | | | | |")
            continue
        t = r["roofline"]
        ratio = r.get("useful_flop_ratio")
        ratio_s = f"{ratio:.3f}" if ratio else "n/a"
        lines.append(
            f"| {arch} | {shape} | {t['compute_s']*1e3:.2f} "
            f"| {t['memory_s']*1e3:.2f} | {t['collective_s']*1e3:.2f} "
            f"| {r['dominant'].replace('_s','')} | {ratio_s} "
            f"| {r.get('hbm_gib_per_chip', 0):.2f} |")
    return lines


def rows(records) -> List[str]:
    out = []
    for (arch, shape), r in sorted(records.items()):
        if not r.get("ok"):
            continue
        bound = max(r["roofline"].values())
        out.append(f"roofline[{arch}][{shape}],"
                   f"{bound*1e6:.0f},{r['dominant'].replace('_s','')}")
    return out


def summary(records) -> Dict[str, int]:
    counts = {"compute_s": 0, "memory_s": 0, "collective_s": 0, "failed": 0}
    for r in records.values():
        if r.get("ok"):
            counts[r["dominant"]] += 1
        else:
            counts["failed"] += 1
    return counts
