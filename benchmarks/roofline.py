"""§Roofline: format the dry-run artifacts into the per-(arch x shape) table.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and emits
the three-term roofline with dominant-bottleneck classification.  No jax
needed — this is pure artifact post-processing, so it runs in benchmarks.run
without touching device state.

``kernel_records``/``kernel_table`` post-process the dispatch layer's
micro-bench artifact (experiments/fl/kernel_perf.json, written by
``benchmarks/kernel_perf.py``) the same way: the swapped hot-path ops are
memory-bound (0/1-flag reductions and softmax-attention at cohort shapes
sit far left of the ridge point), so their runtime floor is HBM traffic
over bandwidth — the table shows how far each Pallas swap moves that floor
by cutting the materialized intermediates out of the traffic term.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dryrun_dir: str = "experiments/dryrun", multi_pod: bool = False,
         plan: str = "baseline"):
    out = {}
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        r = json.load(open(f))
        if r.get("multi_pod", False) != multi_pod:
            continue
        r_plan = r.get("plan") or "baseline"
        if r_plan != ("auto" if plan == "auto" else "baseline"):
            continue
        out[(r["arch"], r["shape"])] = r
    return out


def table(records: Dict) -> List[str]:
    lines = ["| arch | shape | compute ms | memory ms | collective ms | "
             "dominant | useful-flop ratio | HBM GiB/chip |",
             "|---|---|---|---|---|---|---|---|"]
    for (arch, shape) in sorted(records, key=lambda k: (k[0],
                                                        SHAPE_ORDER.index(k[1]))):
        r = records[(arch, shape)]
        if not r.get("ok"):
            lines.append(f"| {arch} | {shape} | FAILED | | | | | |")
            continue
        t = r["roofline"]
        ratio = r.get("useful_flop_ratio")
        ratio_s = f"{ratio:.3f}" if ratio else "n/a"
        lines.append(
            f"| {arch} | {shape} | {t['compute_s']*1e3:.2f} "
            f"| {t['memory_s']*1e3:.2f} | {t['collective_s']*1e3:.2f} "
            f"| {r['dominant'].replace('_s','')} | {ratio_s} "
            f"| {r.get('hbm_gib_per_chip', 0):.2f} |")
    return lines


def rows(records) -> List[str]:
    out = []
    for (arch, shape), r in sorted(records.items()):
        if not r.get("ok"):
            continue
        bound = max(r["roofline"].values())
        out.append(f"roofline[{arch}][{shape}],"
                   f"{bound*1e6:.0f},{r['dominant'].replace('_s','')}")
    return out


def kernel_records(path: str = "experiments/fl/kernel_perf.json"):
    """The kernel micro-bench artifact's per-op records ([] if absent)."""
    if not os.path.exists(path):
        return []
    r = json.load(open(path))
    if r.get("kind") != "kernel_perf":
        return []
    return r.get("kernels", [])


def kernel_table(records: List[Dict]) -> List[str]:
    """Markdown table: per-swap HBM-traffic and intermediate-footprint
    movement (analytic, shape-derived) plus the measured wall-clock ratio.
    ``hbm x`` is the kernel's traffic floor relative to jnp's — for these
    memory-bound ops that IS the roofline movement."""
    lines = ["| op | shape | jnp HBM B | kernel HBM B | hbm x | "
             "jnp interm. B | kernel interm. B | interm. x | wall x |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in records:
        hbm_ratio = (r["kernel_hbm_bytes"]
                     / max(r["jnp_hbm_bytes"], 1))
        lines.append(
            f"| {r['name']} | {'x'.join(str(s) for s in r['shape'])} "
            f"| {r['jnp_hbm_bytes']:,} | {r['kernel_hbm_bytes']:,} "
            f"| {hbm_ratio:.3f} "
            f"| {r['jnp_intermediate_bytes']:,} "
            f"| {r['kernel_intermediate_bytes']:,} "
            f"| {r['intermediate_ratio']:.4f} | {r['rel_time']:.2f} |")
    return lines


def kernel_rows(records: List[Dict]) -> List[str]:
    out = []
    for r in records:
        shape = "x".join(str(s) for s in r["shape"])
        out.append(f"kernel_hbm_ratio[{r['name']}][{shape}],"
                   f"{r['kernel_ms']*1e3:.1f},"
                   f"{r['kernel_hbm_bytes'] / max(r['jnp_hbm_bytes'], 1):.3f}")
    return out


def summary(records) -> Dict[str, int]:
    counts = {"compute_s": 0, "memory_s": 0, "collective_s": 0, "failed": 0}
    for r in records.values():
        if r.get("ok"):
            counts[r["dominant"]] += 1
        else:
            counts["failed"] += 1
    return counts
