"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  table2_acc[...]   derived = final accuracy (%)          (paper Table II)
  table3_time[...]  derived = simulated convergence time  (paper Table III)
  fig3_*[...]       derived = ledger TPS                  (paper Fig. 3)
  roofline[...]     derived = dominant roofline term      (framework §Roofline)

``python -m benchmarks.run [--full]`` — fast mode is CI-sized; --full runs
the paper's full 3-dataset x 3-distribution grid.
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--skip-fl", action="store_true",
                    help="only ledger + roofline benchmarks")
    ap.add_argument("--cohort-size", type=int, default=0,
                    help="also benchmark the vectorized cohort engine at "
                         "this batch size (cohort_speedup[...] rows)")
    ap.add_argument("--n-clients", type=int, default=16,
                    help="client count for the cohort engine benchmark")
    ap.add_argument("--mesh", default="0",
                    help="also benchmark the mesh-sharded SPMD cohort "
                         "engine: N devices or CxD (2-D clients x data, "
                         "e.g. 4x2); 0 = skip")
    ap.add_argument("--scenario", action="append", default=None,
                    metavar="NAME",
                    help="run ONLY the robustness suite for this scenario "
                         "(repeatable; 'all' = the full matrix) — the same "
                         "entrypoint CI's robustness job uses "
                         "(benchmarks/robustness.py)")
    ap.add_argument("--serve", action="store_true",
                    help="run ONLY the live-traffic serving benchmark "
                         "(frontier -> replica publication under a query "
                         "stream) — the same entrypoint CI's serve smoke "
                         "uses (benchmarks/serve_perf.py)")
    args = ap.parse_args()

    if args.serve:
        from benchmarks import serve_perf
        report = serve_perf.run_serve_perf(quick=not args.full)
        print("name,us_per_call,derived")
        for r in serve_perf.rows(report):
            print(r)
        return

    if args.scenario:
        from benchmarks import fl_tables, robustness
        names = (None if "all" in args.scenario else args.scenario)
        report = robustness.run_robustness(scenarios=names,
                                           quick=not args.full)
        print("name,us_per_call,derived")
        for r in fl_tables.robustness_rows(report):
            print(r)
        return

    rows = []

    from benchmarks import chain_perf
    chain_results = chain_perf.run_chain_perf()
    rows += chain_perf.rows(chain_results)

    if args.cohort_size:
        from repro.fl.cohort import parse_mesh_spec
        mesh_c, mesh_d = parse_mesh_spec(args.mesh)
        if mesh_c == "auto":
            mesh_c = args.cohort_size
        res = chain_perf.bench_cohort_speedup(
            n_clients=args.n_clients, cohort_size=args.cohort_size,
            mesh_shape=(mesh_c, mesh_d))
        rows += chain_perf.cohort_rows(res, args.n_clients, args.cohort_size)
        print(f"# cohort engine: {res['speedup']:.2f}x wall-clock, "
              f"accuracy gap {res['accuracy_gap']*100:.2f} pts",
              file=sys.stderr)
        if "sharded_speedup" in res:
            print(f"# sharded cohort engine (mesh {res['mesh_shape']}): "
                  f"{res['sharded_speedup']:.2f}x wall-clock, mesh accuracy "
                  f"gap {res['mesh_accuracy_gap']*100:.2f} pts",
                  file=sys.stderr)

    from benchmarks import roofline
    records = roofline.load()
    if records:
        rows += roofline.rows(records)
        counts = roofline.summary(records)
        print(f"# roofline dominant-term counts: {counts}", file=sys.stderr)
    kernels = roofline.kernel_records()
    if kernels:
        rows += roofline.kernel_rows(kernels)

    if not args.skip_fl:
        from benchmarks import fl_tables
        fl_results = fl_tables.run_tables(fast=not args.full)
        rows += fl_tables.rows(fl_results)
        if args.full:
            from benchmarks import ablations
            rows += ablations.rows(ablations.run_ablations())

    print("name,us_per_call,derived")
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
