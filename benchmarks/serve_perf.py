"""Live-traffic serving benchmark: frontier -> replica publication under
a concurrent query stream (``kind=serve``), gated in CI by
``benchmarks/check_perf_gate.py``.

For each backend (CNN batched eval, LM prefill + KV-cache greedy decode)
this runs one DAG-AFL training simulation with the consensus publisher and
a seeded Poisson query stream riding the same event loop
(``repro/fl/serving.py``), then checks three things the gate pins:

* **deterministic counters** — replica versions published, queries served,
  staleness lag (in ledger append seqs — ``head_seq`` advances exactly once
  per publish, so lags are event counts, not clock readings) and the
  replica-version histogram are pure functions of the seed; a same-seed
  rerun must reproduce every counter exactly (``determinism`` leg).
* **exact output parity** — a replica IS the Eq. 6 aggregate over its
  pinned frontier refs: recomputing the aggregate from the replica's own
  refs must match bit for bit, batched eval on both must agree exactly,
  and (LM) greedy-decoding the same prompts through the replica and the
  recomputed aggregate must produce identical token streams.
* **eviction protection** — the CNN leg runs on the bounded ledger with an
  aggressive checkpoint cadence, so replica frontiers DO get pruned out
  from under the publisher; every ref pinned by a live replica must still
  be resident in the ModelStore when the run ends.

Wall-clock throughput is reported for eyeballing but NEVER gated.

Usage::

  python benchmarks/serve_perf.py --quick                # CI geometry
  python benchmarks/serve_perf.py --quick --backend cnn  # one backend
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional

import numpy as np

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (REPO_ROOT, os.path.join(REPO_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

from benchmarks.chain_perf import _WORLDS  # noqa: E402

BACKEND_ORDER = ["cnn", "lm"]

#: serving-report keys excluded from the determinism comparison: wall-clock
#: by definition, and the mean query accuracy (a float average of eval
#: outputs — the gate pins event counts, never accuracies)
NONDETERMINISTIC_KEYS = ("query_wall_s", "queries_per_s",
                         "query_accuracy_mean")


def _geometry(quick: bool, backend: str) -> Dict:
    if backend == "cnn":
        geo = dict(n_clients=4, n_samples=1200, max_rounds=3, local_epochs=1,
                   serve_every=4.0, query_rate=1.0, query_batch=16,
                   prompt_len=0, new_tokens=0,
                   # bounded ledger with an aggressive cadence: replica
                   # frontiers MUST get pruned so eviction protection is
                   # actually exercised
                   ledger_checkpoint_every=4.0)
        if not quick:
            geo.update(n_clients=8, n_samples=2400, max_rounds=4)
        return geo
    geo = dict(n_clients=3, n_samples=512, max_rounds=2, local_epochs=1,
               serve_every=4.0, query_rate=0.5, query_batch=2,
               prompt_len=8, new_tokens=4,
               ledger_checkpoint_every=0.0)   # unbounded reference ledger
    if not quick:
        geo.update(n_clients=4, max_rounds=3, query_rate=1.0)
    return geo


def _run_serve(backend_kind: str, geo: Dict, seed: int):
    """One coordinator run with serving on; convergence tracking disabled
    (patience >> max_rounds) so every serving counter is a pure function
    of the seed."""
    from repro.core.coordinator import DagAflConfig, DagAflCoordinator
    from repro.core.simulator import CostModel, make_profiles
    from repro.fl.serving import ServingConfig

    backend, client_data, test = _WORLDS[backend_kind](
        geo["n_clients"], geo["n_samples"], geo["local_epochs"], seed)
    scfg = ServingConfig(every=geo["serve_every"],
                         query_rate=geo["query_rate"],
                         query_batch=geo["query_batch"],
                         prompt_len=max(geo["prompt_len"], 1),
                         new_tokens=max(geo["new_tokens"], 2),
                         seed=seed + 777, backend=backend_kind)
    cfg = DagAflConfig(
        n_clients=geo["n_clients"], max_rounds=geo["max_rounds"],
        local_epochs=geo["local_epochs"], seed=seed,
        target_accuracy=None, patience=10 ** 6,
        ledger_checkpoint_every=geo["ledger_checkpoint_every"],
        serving=scfg)
    t0 = time.time()
    coord = DagAflCoordinator(
        backend, client_data, test, cfg, CostModel(),
        make_profiles(geo["n_clients"], 1.0, seed))
    res = coord.run()
    return coord, res, time.time() - t0


def _parity_leg(backend_kind: str, coord, geo: Dict, seed: int) -> Dict:
    """Exact replica-vs-direct-aggregation parity on the FINAL replica."""
    from repro.fl.serving import (LMQueryDriver, consensus_over_refs,
                                  replica_parity, trees_bitwise_equal)
    replica = coord.publisher.replica()
    pinned = coord.publisher.pinned_refs()
    out = {
        "final_version": replica.version,
        "params_bitwise": bool(replica_parity(replica, coord.store)),
        "pinned_refs": len(pinned),
        "pinned_resident": all(r in coord.store for r in pinned),
    }
    direct = consensus_over_refs(coord.store, replica.model_refs)
    if backend_kind == "lm":
        drv = LMQueryDriver(coord.backend.cfg,
                            query_batch=geo["query_batch"],
                            prompt_len=geo["prompt_len"],
                            new_tokens=geo["new_tokens"], seed=seed)
        rng = np.random.default_rng(seed + 1)
        prompts = rng.integers(0, coord.backend.cfg.vocab_size,
                               (geo["query_batch"], geo["prompt_len"]))
        a = drv.decode_prompts(replica.params, prompts)
        b = drv.decode_prompts(direct, prompts)
        out["output_parity"] = bool(np.array_equal(a, b))
        out["parity_probe"] = "greedy_decode"
    else:
        acc_rep = coord.backend.evaluate(replica.params, coord.global_test,
                                         limit=256)
        acc_dir = coord.backend.evaluate(direct, coord.global_test, limit=256)
        out["output_parity"] = bool(acc_rep == acc_dir)
        out["parity_probe"] = "batched_eval"
    out["direct_bitwise"] = bool(trees_bitwise_equal(replica.params, direct))
    return out


def _counters(report: Dict) -> Dict:
    return {k: v for k, v in report.items() if k not in NONDETERMINISTIC_KEYS}


def run_serve_perf(backends: Optional[List[str]] = None, quick: bool = True,
                   seed: int = 0, out_dir: str = "experiments/fl",
                   determinism: bool = True) -> Dict:
    names = backends or BACKEND_ORDER
    report = {"kind": "serve", "quick": quick, "seed": seed, "backends": {}}
    for kind in names:
        geo = _geometry(quick, kind)
        print(f"# serve: backend '{kind}' (n={geo['n_clients']}, "
              f"rounds={geo['max_rounds']}, every={geo['serve_every']}s, "
              f"rate={geo['query_rate']}/s)", file=sys.stderr)
        coord, res, wall = _run_serve(kind, geo, seed)
        serving = res.extra["serving"]
        entry = {
            **geo,
            "serving": serving,
            "parity": _parity_leg(kind, coord, geo, seed),
            "rounds": res.rounds,
            "sim_time": res.sim_time,
            "n_pruned": getattr(coord.ledger, "n_pruned", 0),
            "wall_s": wall,
        }
        if determinism:
            coord2, res2, _ = _run_serve(kind, geo, seed)
            a, b = _counters(serving), _counters(res2.extra["serving"])
            entry["determinism"] = {
                "counters_match": a == b,
                "mismatched_keys": sorted(k for k in a
                                          if a.get(k) != b.get(k)),
            }
        report["backends"][kind] = entry
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, "serve_perf.json")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# serve report -> {out_path}", file=sys.stderr)
    return report


def rows(report: Dict) -> List[str]:
    """``name,us_per_call,derived`` CSV rows (benchmarks/run.py convention):
    derived = queries served; us_per_call = mean seq-staleness."""
    out = []
    for kind, b in report["backends"].items():
        s = b["serving"]
        out.append(f"serve_queries[{kind}],"
                   f"{s['mean_seq_lag']:.4f},{s['queries']}")
        out.append(f"serve_replicas[{kind}],"
                   f"{s['max_seq_lag']:.1f},{s['replica_versions']}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized geometry")
    ap.add_argument("--backend", action="append", default=None,
                    choices=BACKEND_ORDER,
                    help="run only this backend (repeatable; default: both)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out-dir", default="experiments/fl")
    ap.add_argument("--no-determinism", action="store_true",
                    help="skip the same-seed rerun (faster local iteration; "
                         "the CI gate requires the determinism leg)")
    args = ap.parse_args()
    report = run_serve_perf(backends=args.backend, quick=args.quick,
                            seed=args.seed, out_dir=args.out_dir,
                            determinism=not args.no_determinism)
    print("name,us_per_call,derived")
    for r in rows(report):
        print(r)


if __name__ == "__main__":
    main()
