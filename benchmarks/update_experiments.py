"""Regenerate the data-driven tables inside EXPERIMENTS.md.

Replaces the <!-- ROOFLINE_TABLE -->, <!-- OPT_TABLE -->, <!-- REPRO_TABLE -->
and <!-- CHAIN_TABLE --> markers with current artifacts.  Idempotent: tables
are wrapped in begin/end markers on rewrite.
"""
from __future__ import annotations

import json
import os
import re
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks import roofline  # noqa: E402


def roofline_table() -> str:
    recs = roofline.load(plan="baseline")
    lines = roofline.table(recs)
    counts = roofline.summary(recs)
    lines.append("")
    lines.append(f"dominant-term counts: {counts} ({len(recs)}/40 combos OK)")
    return "\n".join(lines)


def opt_table() -> str:
    base = roofline.load(plan="baseline")
    opt = roofline.load(plan="auto")
    out = ["| arch | shape | bound base | bound opt | speedup | dominant "
           "base → opt | CPU-reported HBM GiB base → opt |",
           "|---|---|---|---|---|---|---|"]
    gains = []
    for k in sorted(base, key=lambda k: (k[0],
                                         roofline.SHAPE_ORDER.index(k[1]))):
        b, o = base[k], opt.get(k)
        if not (b.get("ok") and o and o.get("ok")):
            continue
        bb = max(b["roofline"].values())
        ob = max(o["roofline"].values())
        sp = bb / ob if ob else float("inf")
        gains.append(sp)
        out.append(
            f"| {k[0]} | {k[1]} | {bb*1e3:.2f} ms | {ob*1e3:.2f} ms "
            f"| {sp:.2f}x | {b['dominant'].replace('_s','')} → "
            f"{o['dominant'].replace('_s','')} "
            f"| {b.get('hbm_gib_per_chip',0):.1f} → "
            f"{o.get('hbm_gib_per_chip',0):.1f} |")
    if gains:
        out.append("")
        out.append(f"geometric-mean step-bound speedup: "
                   f"**{np.exp(np.mean(np.log(gains))):.2f}x** over "
                   f"{len(gains)} combos "
                   f"(improved: {sum(1 for g in gains if g > 1.05)}, "
                   f"unchanged: {sum(1 for g in gains if 0.95 <= g <= 1.05)}, "
                   f"regressed-by-design: {sum(1 for g in gains if g < 0.95)})")
    return "\n".join(out)


def repro_table() -> str:
    path = "experiments/fl/tables.json"
    if not os.path.exists(path):
        return "(run `python -m benchmarks.run` to populate)"
    data = json.load(open(path))
    out = []
    for setting, methods in data.items():
        out.append(f"**{setting}**")
        out.append("")
        out.append("| method | accuracy % | sim time s | rounds |")
        out.append("|---|---|---|---|")
        for m, r in methods.items():
            out.append(f"| {m} | {r['accuracy']*100:.2f} | "
                       f"{r['sim_time']:.1f} | {r['rounds']} |")
        out.append("")
    return "\n".join(out)


def chain_table() -> str:
    path = "experiments/fl/chain_perf.json"
    if not os.path.exists(path):
        return "(run `python -m benchmarks.run` to populate)"
    data = json.load(open(path))
    out = ["| system [clients] | upload TPS | query TPS | upload lat ms | "
           "query lat ms |", "|---|---|---|---|---|"]
    for name, r in data.items():
        out.append(f"| {name} | {r['upload_tps']:.0f} | {r['query_tps']:.0f} "
                   f"| {r['upload_latency_ms']:.2f} | "
                   f"{r['query_latency_ms']:.2f} |")
    return "\n".join(out)


MARKERS = {
    "ROOFLINE_TABLE": roofline_table,
    "OPT_TABLE": opt_table,
    "REPRO_TABLE": repro_table,
    "CHAIN_TABLE": chain_table,
}


def main():
    path = "EXPERIMENTS.md"
    text = open(path).read()
    for marker, fn in MARKERS.items():
        block = f"<!-- {marker} -->\n{fn()}\n<!-- /{marker} -->"
        pat = re.compile(
            rf"<!-- {marker} -->.*?<!-- /{marker} -->|<!-- {marker} -->",
            re.S)
        text = pat.sub(lambda m: block, text, count=1)
    open(path, "w").write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
