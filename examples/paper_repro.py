"""Paper reproduction driver: Tables II + III on synthetic benchmark data.

    PYTHONPATH=src python examples/paper_repro.py               # fast
    PYTHONPATH=src python examples/paper_repro.py --full        # full grid

Validates the paper's qualitative claims (see EXPERIMENTS.md §Repro):
  1. DAG-AFL lands in the top-2 federated methods on accuracy,
  2. async methods (FedAsync, DAG-AFL) converge faster than sync/semi-sync,
  3. DAG-AFL needs fewer tip evaluations than DAG-FL (signature filter).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    from benchmarks.fl_tables import METHOD_ORDER, run_tables
    results = run_tables(fast=not args.full)

    for setting, methods in results.items():
        print(f"\n=== {setting} ===")
        print(f"{'method':13s} {'acc%':>7s} {'time(s)':>9s} {'rounds':>7s}")
        for m in METHOD_ORDER:
            r = methods[m]
            print(f"{m:13s} {r['accuracy']*100:7.2f} {r['sim_time']:9.1f} "
                  f"{r['rounds']:7d}")
        fed = {m: methods[m] for m in METHOD_ORDER
               if m not in ("centralized", "independent")}
        ranked = sorted(fed.values(), key=lambda r: -r["accuracy"])
        second_best = ranked[min(1, len(ranked) - 1)]["accuracy"]
        top2_ok = fed["dagafl"]["accuracy"] >= second_best - 0.005  # ties
        top2 = sorted(fed, key=lambda m: -fed[m]["accuracy"])[:2]
        sync_t = min(fed[m]["sim_time"] for m in ("fedavg", "fedhisyn",
                                                  "scalesfl"))
        print(f"-> top-2 accuracy: {top2} (dagafl "
              f"{fed['dagafl']['accuracy']*100:.2f} vs 2nd "
              f"{second_best*100:.2f}) "
              f"{'[claim 1 OK]' if top2_ok else '[claim 1 MISS]'}")
        print(f"-> dagafl {fed['dagafl']['sim_time']:.0f}s vs best sync "
              f"{sync_t:.0f}s "
              f"{'[claim 2 OK]' if fed['dagafl']['sim_time'] < sync_t else '[claim 2 MISS]'}")
        ev_afl = (fed["dagafl"]["extra"].get("tip_evaluations", 0)
                  / max(fed["dagafl"]["rounds"], 1))
        ev_fl = (fed["dagfl"]["extra"].get("tip_evaluations", 0)
                 / max(fed["dagfl"]["rounds"], 1))
        print(f"-> tip evals/round: dagafl={ev_afl:.2f} dagfl={ev_fl:.2f} "
              f"{'[claim 3 OK]' if ev_afl <= ev_fl * 1.05 else '[claim 3 MISS]'}")


if __name__ == "__main__":
    main()
