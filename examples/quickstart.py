"""Quickstart: DAG-AFL federating 3 CNN clients on synthetic MNIST (~60s CPU).

Shows the full paper workflow: publisher posts genesis, trainers select tips
(freshness + reachability + signature-filtered accuracy), aggregate (Eq. 6),
train locally, publish metadata transactions, and the chain audits clean.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.cnn import vgg_for
from repro.core import (DagAflConfig, DagAflCoordinator, TipSelectionConfig,
                        verify_full_dag)
from repro.core.simulator import CostModel, make_profiles
from repro.data import make_benchmark_dataset, partition_dirichlet, split_811
from repro.fl.backend import CNNBackend


def main():
    print("== DAG-AFL quickstart ==")
    ds = make_benchmark_dataset("mnist", n_samples=1500, seed=0)
    splits = split_811(ds)
    # non-IID clients (Dirichlet beta=0.3)
    parts = partition_dirichlet(splits["train"], 3, beta=0.3, seed=0)
    client_data = []
    for i, p in enumerate(parts):
        s = split_811(p, seed=1)
        client_data.append({"train": s["train"], "val": s["val"],
                            "test": s["test"]})
        print(f"client {i}: {len(p)} samples")

    backend = CNNBackend(vgg_for("mnist"), local_epochs=2, batch_size=32)
    cfg = DagAflConfig(
        n_clients=3, max_rounds=3, local_epochs=2,
        tip=TipSelectionConfig(n_select=2, lam=0.5, alpha=0.1))
    coord = DagAflCoordinator(backend, client_data, splits["test"], cfg,
                              CostModel(), make_profiles(3, 0.6, 0))
    res = coord.run()

    print("\n== result ==")
    print(res.row())
    print(f"chain length       : {res.extra['chain_len']}")
    print(f"tip evaluations    : {res.extra['tip_evaluations']}")
    print(f"P2P bytes moved    : {res.extra['store_bytes_transferred']:,}")
    ok, reason = verify_full_dag(coord.ledger)
    print(f"chain audit        : {'OK' if ok else 'TAMPERED: ' + reason}")
    print("\naccuracy history (sim_time, val_acc):")
    for t, a in res.history:
        print(f"  {t:8.1f}s  {a*100:5.1f}%")


if __name__ == "__main__":
    main()
