"""Serving example: batched prefill + KV-cache greedy decode.

    PYTHONPATH=src python examples/serve_decode.py --arch gemma2-2b
    PYTHONPATH=src python examples/serve_decode.py --arch jamba-v0.1-52b
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main()
