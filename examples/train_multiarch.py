"""End-to-end driver: train a ~100M-param transformer for a few hundred steps,
optionally federated with DAG-AFL.

    # ~100M model (xlstm-125m full config), 200 steps
    PYTHONPATH=src python examples/train_multiarch.py --steps 200

    # any assigned arch, reduced family member (fast CPU)
    PYTHONPATH=src python examples/train_multiarch.py \
        --arch deepseek-v2-236b --reduced --steps 50

    # DAG-AFL federation of 4 transformer clients
    PYTHONPATH=src python examples/train_multiarch.py \
        --arch internlm2-1.8b --reduced --dagafl 4 --rounds 3
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main as train_main

if __name__ == "__main__":
    if "--arch" not in " ".join(sys.argv):
        sys.argv += ["--arch", "xlstm-125m"]
    if "--steps" not in " ".join(sys.argv):
        sys.argv += ["--steps", "200"]
    train_main()
