"""Config registry: ``get_config(arch_id)`` and the assigned-shape table."""
from __future__ import annotations

import importlib

from repro.configs.base import (ArchConfig, EncoderConfig, InputShape,
                                INPUT_SHAPES, LayerSpec, MLAConfig,
                                MambaConfig, MoEConfig, Stage, XLSTMConfig,
                                reduced)
from repro.configs.cnn import CNNConfig, VGG16, VGG_TINY, vgg_for

_ARCH_MODULES = {
    "internlm2-1.8b": "internlm2_1_8b",
    "gemma2-2b": "gemma2_2b",
    "xlstm-125m": "xlstm_125m",
    "whisper-medium": "whisper_medium",
    "gemma3-27b": "gemma3_27b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "qwen2-7b": "qwen2_7b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


def all_configs():
    return {name: get_config(name) for name in ARCH_IDS}


__all__ = [
    "ArchConfig", "CNNConfig", "EncoderConfig", "InputShape", "INPUT_SHAPES",
    "LayerSpec", "MLAConfig", "MambaConfig", "MoEConfig", "Stage",
    "XLSTMConfig", "ARCH_IDS", "get_config", "all_configs", "reduced",
    "VGG16", "VGG_TINY", "vgg_for",
]
