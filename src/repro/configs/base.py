"""Architecture and run configuration dataclasses.

An ``ArchConfig`` describes a model as a sequence of *stages*; each stage is a
repeating ``pattern`` of :class:`LayerSpec` blocks scanned ``repeats`` times
with ``lax.scan`` over stacked per-period parameters.  This keeps the HLO for
62-80-layer models small enough that 40 (arch x shape) dry-run compiles are
tractable, while still expressing heterogeneous interleaves (gemma local:
global, jamba mamba:attn, llama4 dense:MoE, deepseek first-dense-layer).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer-level specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN settings (GSPMD-style capacity dispatch)."""

    n_experts: int
    top_k: int
    d_expert: int                 # per-expert hidden width
    n_shared: int = 0             # always-on shared experts (DeepSeek-V2)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01   # load-balance aux loss weight
    router_z_weight: float = 1e-3     # router z-loss weight


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434)."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0          # 0 => full-rank q projection
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class MambaConfig:
    """Mamba-1 selective SSM block (used by jamba)."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 => ceil(d_model/16)
    chunk: int = 256              # scan chunk for remat / Pallas kernel


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block settings (arXiv:2405.04517)."""

    # mLSTM: matrix-memory, parallel/chunkwise trainable
    m_qk_dim_factor: float = 0.5  # qk dim = factor * d_inner
    m_expand: int = 2
    # sLSTM: scalar-memory, strictly recurrent, post-up projection
    s_expand: int = 1
    s_conv: int = 4               # causal conv window preceding sLSTM
    chunk: int = 256


@dataclass(frozen=True)
class LayerSpec:
    """One block inside a stage pattern."""

    kind: str = "attn"            # attn | mamba | mlstm | slstm
    window: int = -1              # -1 => full causal attention; >0 sliding
    ffn: str = "dense"            # dense | moe | none
    cross_attn: bool = False      # decoder cross-attention (whisper)


@dataclass(frozen=True)
class Stage:
    pattern: Tuple[LayerSpec, ...]
    repeats: int


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class EncoderConfig:
    """Audio/vision encoder backbone (frontend itself is stubbed)."""

    n_layers: int
    n_ctx: int                    # number of frame/patch embeddings
    causal: bool = False


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    citation: str

    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    stages: Tuple[Stage, ...] = ()

    # attention details
    use_rope: bool = True         # jamba uses no positional encoding
    rope_theta: float = 10000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    qkv_bias: bool = False
    attn_softcap: float = 0.0     # gemma2 attention logit soft-cap
    final_softcap: float = 0.0    # gemma2 final logit soft-cap
    mla: Optional[MLAConfig] = None

    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    encoder: Optional[EncoderConfig] = None

    norm: str = "rmsnorm"         # rmsnorm | layernorm
    act: str = "silu"             # silu | gelu
    tie_embeddings: bool = True
    norm_eps: float = 1e-6

    # long-context adaptation: window applied to full-attention layers when
    # the requested sequence length exceeds ``long_context_threshold``.
    long_context_window: int = 8192
    long_context_threshold: int = 131072

    # dtype policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    moment_dtype: str = "float32"   # AdamW first/second-moment dtype
    cache_dtype: str = "bfloat16"   # KV-cache storage dtype

    def __post_init__(self):
        n = sum(len(s.pattern) * s.repeats for s in self.stages)
        if self.stages and n != self.n_layers:
            raise ValueError(
                f"{self.name}: stages describe {n} layers, expected {self.n_layers}")

    # -- derived quantities -------------------------------------------------

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def layer_specs(self):
        """Flat list of LayerSpec in execution order."""
        out = []
        for st in self.stages:
            for _ in range(st.repeats):
                out.extend(st.pattern)
        return out

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + per-layer)."""
        d = self.d_model
        total = self.vocab_size * d
        if not self.tie_embeddings:
            total += self.vocab_size * d
        for spec in self.layer_specs():
            if spec.kind == "attn":
                if self.mla is not None:
                    m = self.mla
                    qd = self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
                    total += d * qd                           # q proj
                    total += d * (m.kv_lora_rank + m.qk_rope_dim)  # kv down
                    total += m.kv_lora_rank * self.n_heads * (
                        m.qk_nope_dim + m.v_head_dim)         # kv up
                    total += self.n_heads * m.v_head_dim * d  # o proj
                else:
                    total += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
                if spec.cross_attn:
                    total += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            elif spec.kind == "mamba":
                mc = self.mamba or MambaConfig()
                d_in = mc.expand * d
                dt_rank = mc.dt_rank or -(-d // 16)
                total += d * 2 * d_in + d_in * mc.d_conv
                total += d_in * (dt_rank + 2 * mc.d_state) + dt_rank * d_in
                total += d_in * d + 2 * d_in * mc.d_state
            elif spec.kind == "mlstm":
                xc = self.xlstm or XLSTMConfig()
                d_in = xc.m_expand * d
                qk = int(xc.m_qk_dim_factor * d_in)
                total += d * 2 * d_in + d_in * (2 * qk + d_in) + d_in * d
            elif spec.kind == "slstm":
                xc = self.xlstm or XLSTMConfig()
                total += 4 * d * d + 4 * d * d // 4 + int(4.0 / 3 * d * d) * 2
            if spec.ffn == "dense" and self.d_ff > 0:
                total += 3 * d * self.d_ff
            elif spec.ffn == "moe" and self.moe is not None:
                mo = self.moe
                total += d * mo.n_experts
                total += 3 * d * mo.d_expert * (mo.n_experts + mo.n_shared)
        if self.encoder is not None:
            e = self.encoder
            per = 4 * d * d + 3 * d * self.d_ff if self.d_ff else 4 * d * d
            total += e.n_layers * per
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE counts top_k + shared experts)."""
        if self.moe is None:
            return self.param_count()
        total = self.param_count()
        mo = self.moe
        n_moe_layers = sum(1 for s in self.layer_specs() if s.ffn == "moe")
        inactive = max(mo.n_experts - mo.top_k, 0)
        total -= n_moe_layers * 3 * self.d_model * mo.d_expert * inactive
        return total


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                     # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ArchConfig, d_model: int = 256, max_experts: int = 4) -> ArchConfig:
    """Reduced smoke-test variant of the same family: 2 layers, small dims."""
    pattern = cfg.stages[-1].pattern if cfg.stages else (LayerSpec(),)
    pattern = pattern[: min(len(pattern), 2)]
    repeats = -(-2 // len(pattern))  # >= 2 layers total
    n_layers = len(pattern) * repeats
    n_heads = min(cfg.n_heads, 4)
    head_dim = max(d_model // n_heads, 16)
    n_kv = min(cfg.n_kv_heads, n_heads)
    while n_heads % n_kv:
        n_kv -= 1
    if cfg.n_kv_heads < cfg.n_heads and n_kv == n_heads:
        n_kv = max(n_heads // 2, 1)   # preserve GQA in the reduced family
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe, n_experts=min(cfg.moe.n_experts, max_experts),
            top_k=min(cfg.moe.top_k, 2), d_expert=d_model,
            n_shared=min(cfg.moe.n_shared, 1))
    mla = None
    if cfg.mla is not None:
        mla = MLAConfig(kv_lora_rank=64, qk_nope_dim=head_dim,
                        qk_rope_dim=32, v_head_dim=head_dim)
    enc = None
    if cfg.encoder is not None:
        enc = EncoderConfig(n_layers=2, n_ctx=16, causal=cfg.encoder.causal)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=0 if cfg.d_ff == 0 else d_model * 2,
        vocab_size=512,
        stages=(Stage(pattern, repeats),),
        moe=moe,
        mla=mla,
        encoder=enc,
        mamba=MambaConfig(d_state=8, chunk=32) if cfg.mamba else None,
        xlstm=XLSTMConfig(chunk=32) if cfg.xlstm else None,
        long_context_threshold=cfg.long_context_threshold,
        # CPU test configs run everything in f32 (the CPU backend cannot
        # execute bf16 dots; TPU-targeted full configs keep bf16)
        param_dtype="float32",
        compute_dtype="float32",
        cache_dtype="float32",
    )
