"""CNN configs for the paper-faithful reproduction (VGG16 on MNIST/CIFAR).

The paper trains VGG16 [arXiv:1409.1556] with 3x3 kernels on MNIST, CIFAR-10
and CIFAR-100.  ``VGG16`` is the faithful config; ``VGG_TINY`` is the reduced
variant used by CPU experiments and tests (same family: conv stacks + maxpool
+ classifier head, exact-zero ReLU feature-map signatures per Eq. 3).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class CNNConfig:
    name: str
    citation: str
    # each entry = (out_channels per conv in the stack); maxpool after stack
    conv_stacks: Tuple[Tuple[int, ...], ...]
    fc_dims: Tuple[int, ...]
    n_classes: int = 10
    image_size: int = 32
    in_channels: int = 3
    kernel_size: int = 3
    # index of the conv layer whose feature maps provide Eq.3 signatures
    signature_layer: int = 1


VGG16 = CNNConfig(
    name="vgg16",
    citation="arXiv:1409.1556 (VGG); backbone used by DAG-AFL paper SIV-A",
    conv_stacks=((64, 64), (128, 128), (256, 256, 256),
                 (512, 512, 512), (512, 512, 512)),
    fc_dims=(4096, 4096),
    n_classes=10,
    image_size=32,
    in_channels=3,
)

VGG_TINY = CNNConfig(
    name="vgg-tiny",
    citation="reduced VGG family member for CPU-scale experiments",
    conv_stacks=((16, 16), (32, 32)),
    fc_dims=(128,),
    n_classes=10,
    image_size=16,
    in_channels=1,
    signature_layer=1,
)


def vgg_for(dataset: str, tiny: bool = True) -> CNNConfig:
    import dataclasses
    base = VGG_TINY if tiny else VGG16
    n_classes = {"mnist": 10, "cifar10": 10, "cifar100": 100}[dataset]
    in_ch = 1 if dataset == "mnist" else 3
    return dataclasses.replace(base, n_classes=n_classes, in_channels=in_ch)
