"""deepseek-v2-236b — MLA attention + fine-grained MoE [arXiv:2405.04434].

MLA: kv_lora_rank=512, decoupled RoPE key dim 64, q_lora_rank=1536.
MoE: 160 routed experts top-6 + 2 shared, expert width 1536 (the assignment's
``d_ff=1536`` denotes the MoE intermediate size; the single dense prologue
layer — DeepSeek-V2's ``first_k_dense_replace=1`` — reuses it).
"""
from repro.configs.base import ArchConfig, LayerSpec, MLAConfig, MoEConfig, Stage

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    citation="arXiv:2405.04434 (DeepSeek-V2)",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=1536,
    vocab_size=102400,
    stages=(
        Stage((LayerSpec(kind="attn", ffn="dense"),), 1),       # dense prologue
        Stage((LayerSpec(kind="attn", ffn="moe"),), 59),
    ),
    rope_theta=10000.0,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_expert=1536, n_shared=2,
                  capacity_factor=1.25),
    norm="rmsnorm",
    act="silu",
    tie_embeddings=False,
    moment_dtype="bfloat16",
)
