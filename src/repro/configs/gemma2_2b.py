"""gemma2-2b — dense, alternating local/global attention, logit softcaps
[arXiv:2408.00118]."""
from repro.configs.base import ArchConfig, LayerSpec, Stage

_LOCAL = LayerSpec(kind="attn", window=4096, ffn="dense")
_GLOBAL = LayerSpec(kind="attn", window=-1, ffn="dense")

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    citation="arXiv:2408.00118 (Gemma 2)",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    stages=(Stage((_LOCAL, _GLOBAL), 13),),
    rope_theta=10000.0,
    attn_softcap=50.0,
    final_softcap=30.0,
    norm="rmsnorm",
    act="gelu",
    tie_embeddings=True,
)
