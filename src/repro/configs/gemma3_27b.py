"""gemma3-27b — dense, 5 local : 1 global attention, 128k context
[hf:google/gemma-3-1b-pt family card, scaled per assignment]."""
from repro.configs.base import ArchConfig, LayerSpec, Stage

_L = LayerSpec(kind="attn", window=1024, ffn="dense")
_G = LayerSpec(kind="attn", window=-1, ffn="dense")

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    citation="hf:google/gemma-3-1b-pt (Gemma 3 model card)",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    # every 6th layer global; 62 = 6*10 + 2 trailing locals
    stages=(Stage((_L, _L, _L, _L, _L, _G), 10), Stage((_L, _L), 1)),
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    act="gelu",
    tie_embeddings=True,
)
