"""internlm2-1.8b — dense GQA decoder [arXiv:2403.17297]."""
from repro.configs.base import ArchConfig, LayerSpec, Stage

CONFIG = ArchConfig(
    name="internlm2-1.8b",
    family="dense",
    citation="arXiv:2403.17297 (InternLM2 Technical Report)",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92544,
    stages=(Stage((LayerSpec(kind="attn", ffn="dense"),), 24),),
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    act="silu",
    tie_embeddings=False,
)
