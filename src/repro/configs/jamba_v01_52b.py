"""jamba-v0.1-52b — hybrid Mamba + attention (1:7 attn:mamba), MoE every 2nd
layer, 16 experts top-2 [arXiv:2403.19887].

Period of 8 layers with attention at index 4 (Jamba's published block
layout); odd layer indices carry MoE FFNs, even indices dense FFNs.  Jamba
uses no explicit positional encoding (``use_rope=False``).
"""
from repro.configs.base import ArchConfig, LayerSpec, MambaConfig, MoEConfig, Stage


def _l(kind, ffn):
    return LayerSpec(kind=kind, ffn=ffn)

_PERIOD = (
    _l("mamba", "dense"), _l("mamba", "moe"),
    _l("mamba", "dense"), _l("mamba", "moe"),
    _l("attn", "dense"), _l("mamba", "moe"),
    _l("mamba", "dense"), _l("mamba", "moe"),
)

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    citation="arXiv:2403.19887 (Jamba)",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    stages=(Stage(_PERIOD, 4),),
    use_rope=False,
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=14336, capacity_factor=1.25),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, chunk=256),
    norm="rmsnorm",
    act="silu",
    tie_embeddings=False,
    moment_dtype="bfloat16",
)
