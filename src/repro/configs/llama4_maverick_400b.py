"""llama4-maverick-400b-a17b — interleaved dense/MoE decoder, 128 routed
experts top-1 + 1 shared [hf:meta-llama/Llama-4-Scout-17B-16E family card].

Early-fusion multimodality is a STUB (text-token path only; the assignment's
modality carve-out).  Maverick interleaves dense and MoE FFN layers 1:1.
"""
from repro.configs.base import ArchConfig, LayerSpec, MoEConfig, Stage

_DENSE = LayerSpec(kind="attn", ffn="dense")
_MOE = LayerSpec(kind="attn", ffn="moe")

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    citation="hf:meta-llama/Llama-4-Scout-17B-16E (Llama 4 model card)",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    stages=(Stage((_DENSE, _MOE), 24),),
    rope_theta=500_000.0,
    moe=MoEConfig(n_experts=128, top_k=1, d_expert=8192, n_shared=1,
                  capacity_factor=1.25),
    norm="rmsnorm",
    act="silu",
    tie_embeddings=False,
    moment_dtype="bfloat16",   # 400B params: fp32 moments would not fit v5e
)
