"""qwen2-7b — dense GQA decoder with QKV bias [arXiv:2407.10671]."""
from repro.configs.base import ArchConfig, LayerSpec, Stage

CONFIG = ArchConfig(
    name="qwen2-7b",
    family="dense",
    citation="arXiv:2407.10671 (Qwen2 Technical Report)",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    stages=(Stage((LayerSpec(kind="attn", ffn="dense"),), 28),),
    rope_theta=1_000_000.0,
    qkv_bias=True,
    norm="rmsnorm",
    act="silu",
    tie_embeddings=False,
)
