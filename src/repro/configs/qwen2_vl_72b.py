"""qwen2-vl-72b — VLM language backbone with M-RoPE [arXiv:2409.12191].

The SigLIP-style vision encoder + projector is a STUB per the assignment
carve-out: ``input_specs()`` supplies token embeddings; M-RoPE consumes
(temporal, height, width) position ids, which collapse to the text position
for pure-text streams.
"""
from repro.configs.base import ArchConfig, LayerSpec, Stage

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    citation="arXiv:2409.12191 (Qwen2-VL)",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    stages=(Stage((LayerSpec(kind="attn", ffn="dense"),), 80),),
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    qkv_bias=True,
    norm="rmsnorm",
    act="silu",
    tie_embeddings=False,
)
