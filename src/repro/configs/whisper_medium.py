"""whisper-medium — encoder-decoder speech backbone [arXiv:2212.04356].

The mel-spectrogram + conv frontend is a STUB per the assignment carve-out:
``input_specs()`` supplies precomputed frame embeddings of shape
``(batch, 1500, d_model)``.  We implement the 24-layer encoder and 24-layer
decoder (cross-attention) transformer backbone.  Positional encoding
adaptation: RoPE instead of Whisper's learned/sinusoidal absolute positions
(long-context decode shapes make absolute tables impractical; noted in
DESIGN.md).
"""
from repro.configs.base import ArchConfig, EncoderConfig, LayerSpec, Stage

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    citation="arXiv:2212.04356 (Whisper)",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    stages=(Stage((LayerSpec(kind="attn", ffn="dense", cross_attn=True),), 24),),
    encoder=EncoderConfig(n_layers=24, n_ctx=1500, causal=False),
    norm="layernorm",
    act="gelu",
    tie_embeddings=True,
)
