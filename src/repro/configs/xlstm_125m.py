"""xlstm-125m — sLSTM + mLSTM recurrent blocks, attention-free
[arXiv:2405.04517].

d_ff = 0: xLSTM blocks carry their own up/down projections, there is no
separate FFN sublayer.  Pattern [mLSTM x3, sLSTM] x3 approximates the paper's
mLSTM-heavy [m:s = 7:1]-style interleave at 12 layers.
"""
from repro.configs.base import ArchConfig, LayerSpec, Stage, XLSTMConfig

_M = LayerSpec(kind="mlstm", ffn="none")
_S = LayerSpec(kind="slstm", ffn="none")

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    citation="arXiv:2405.04517 (xLSTM)",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    stages=(Stage((_M, _M, _M, _S), 3),),
    use_rope=False,
    xlstm=XLSTMConfig(m_qk_dim_factor=0.5, m_expand=2, s_conv=4, chunk=256),
    norm="layernorm",
    act="gelu",
    tie_embeddings=True,
)
