"""DAG-AFL core: the paper's primary contribution.

DAG ledger + tip selection (freshness/reachability/accuracy) + signature
contract + trustworthy verification + aggregation + the asynchronous
event-driven coordinator that ties them together.
"""
from repro.core.aggregate import (tree_interpolate, tree_mean,
                                  tree_size_bytes, tree_weighted)
from repro.core.coordinator import (DagAflConfig, DagAflCoordinator,
                                    resolve_cohort_mesh)
from repro.core.dag import (BoundedDAGLedger, CheckpointRecord, DAGLedger,
                            LedgerView, ModelStore, Transaction, TxMetadata,
                            checkpoint_root, compute_tx_hash,
                            compute_tx_hash_from_digest)
from repro.core.signature import (SimilarityContract, cosine_similarity,
                                  cosine_similarity_matrix)
from repro.core.simulator import (ClientProfile, ConvergenceTracker, CostModel,
                                  EventLoop, RunResult, make_profiles)
from repro.core.tip_selection import (FnTipEvaluator, TipEvaluator, TipScore,
                                      TipSelectionConfig, TipSelectionRequest,
                                      TipSelector, freshness, select_tips,
                                      tipc)
from repro.core.verify import (IncrementalVerifier, ValidationPath,
                               extract_path, verify_checkpoints,
                               verify_full_dag, verify_path)

__all__ = [
    "DAGLedger", "BoundedDAGLedger", "LedgerView", "CheckpointRecord",
    "ModelStore", "Transaction", "TxMetadata", "compute_tx_hash",
    "compute_tx_hash_from_digest", "checkpoint_root",
    "TipSelectionConfig", "TipSelectionRequest", "TipSelector",
    "TipEvaluator", "FnTipEvaluator", "TipScore", "select_tips",
    "freshness", "tipc",
    "SimilarityContract", "cosine_similarity", "cosine_similarity_matrix",
    "tree_mean", "tree_weighted", "tree_interpolate", "tree_size_bytes",
    "ValidationPath", "extract_path", "verify_path", "verify_full_dag",
    "verify_checkpoints", "IncrementalVerifier",
    "ClientProfile", "ConvergenceTracker", "CostModel", "EventLoop",
    "RunResult", "make_profiles", "DagAflConfig", "DagAflCoordinator",
    "resolve_cohort_mesh",
]
