"""Model aggregation (paper Eq. 6) as jitted pytree programs.

Eq. 6 is a plain average over the N selected tip models; ``tree_weighted``
is the beyond-paper generalisation (staleness- or accuracy-weighted) used by
the optimized DAG-AFL variant and by several baselines (FedAsync mixing,
FedAT tier weighting).
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def tree_mean(models: Sequence):
    """Eq. 6: w = (1/N) * sum_i w_i  over a list of congruent pytrees."""
    n = len(models)
    return jax.tree_util.tree_map(
        lambda *leaves: sum(l.astype(jnp.float32) for l in leaves) / n
        if jnp.issubdtype(leaves[0].dtype, jnp.floating) else leaves[0],
        *models)


def tree_weighted(models: Sequence, weights: Sequence[float]):
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.maximum(jnp.sum(w), 1e-12)

    def combine(*leaves):
        if not jnp.issubdtype(leaves[0].dtype, jnp.floating):
            return leaves[0]
        return sum(wi * l.astype(jnp.float32) for wi, l in zip(w, leaves))

    return jax.tree_util.tree_map(combine, *models)


# -- stacked-tree variants (leading client axis) ----------------------------
#
# The cohort execution engine keeps K client models stacked as ONE pytree
# whose leaves carry a leading client axis.  Aggregating over that axis is a
# single XLA reduction instead of K Python-level ``tree_mean`` calls.
#
# With a device mesh carrying a ``clients`` axis (see
# ``repro.launch.mesh.make_cohort_mesh``), the stacked axis lives sharded
# across devices; ``stacked_mean`` / ``stacked_weighted`` then reduce it with
# ``shard_map`` + ``lax.psum`` cross-device collectives — each device sums
# its local client shard, one psum produces the Eq. 6 aggregate replicated
# everywhere.  ``mesh=None`` (the default) keeps the single-device programs
# bit-for-bit as before.


def round_up_multiple(x: int, n: int) -> int:
    """Smallest multiple of ``n`` that is >= ``x`` (the mesh-divisibility
    pad target for stacked client/model axes)."""
    return -(-x // n) * n


def pad_leading(arr, target: int):
    """Zero-pad the leading axis of ``arr`` out to ``target`` rows."""
    if arr.shape[0] == target:
        return arr
    pad = [(0, target - arr.shape[0])] + [(0, 0)] * (arr.ndim - 1)
    return jnp.pad(arr, pad)


def next_pow2(x: int) -> int:
    """Smallest power of two >= ``x`` (>= 1): the shared shape-quantization
    policy that keeps jitted program families bounded at ~log2."""
    p = 1
    while p < x:
        p *= 2
    return p


def _quantized_target(x: int, n: int) -> int:
    """Pad target for a sharded stacked axis: next power of two >= ``x``,
    rounded up to a multiple of the mesh size ``n``.  The power-of-two
    quantization bounds the psum reducers' compiled-program family at
    ~log2 of the largest window (K and M vary every cohort window; padding
    to the bare multiple would recompile per geometry)."""
    return round_up_multiple(next_pow2(x), n)


_COLLECTIVE_CACHE = {}


def _psum_reducer(mesh, axis_names: tuple, kind: str):
    """Cached jitted shard_map programs reducing a LIST of float leaves whose
    leading axis is sharded over ``axis_names`` (one mesh axis, or — on the
    2-D (clients, data) cohort mesh — BOTH axes, so every device in the mesh
    holds a slice of the stacked models and one psum over the axis pair
    assembles the aggregate).

    ``sum``:  leaves (M, ...) -> total over M, replicated.
    ``wsum``: leaves (M, ...) + weights (K, M) -> (K, ...) einsum, replicated.
    Padding rows must carry zeros (zero weight) — they fall out of the sum.
    """
    key = (mesh, axis_names, kind)
    fn = _COLLECTIVE_CACHE.get(key)
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    lead = axis_names if len(axis_names) > 1 else axis_names[0]
    if kind == "sum":
        def local(leaves):
            return [jax.lax.psum(jnp.sum(l, axis=0), axis_names)
                    for l in leaves]
        fn = jax.jit(shard_map(local, mesh=mesh, in_specs=(P(lead),),
                               out_specs=P()))
    elif kind == "wsum":
        def local(leaves, w):
            return [jax.lax.psum(jnp.einsum("km,m...->k...", w, l),
                                 axis_names)
                    for l in leaves]
        fn = jax.jit(shard_map(local, mesh=mesh,
                               in_specs=(P(lead), P(None, lead)),
                               out_specs=P()))
    else:
        raise ValueError(kind)
    _COLLECTIVE_CACHE[key] = fn
    return fn


def _mesh_axis_size(mesh, axis_name: str) -> int:
    if mesh is None or axis_name is None:
        return 1
    return int(dict(mesh.shape).get(axis_name, 1))


def _reduce_axes(mesh, axis_name: str, data_axis) -> tuple:
    """Mesh axes a stacked reduction shards its leading dim over: the
    clients axis, joined by the data axis when the mesh carries one larger
    than 1 (2-D cohort mesh — aggregation has no per-sample structure, so
    the model axis simply spreads over every device)."""
    axes = (axis_name,)
    if _mesh_axis_size(mesh, data_axis) > 1:
        axes = axes + (data_axis,)
    return axes


def tree_stack(models: Sequence):
    """Stack K congruent pytrees into one with a leading K axis per leaf."""
    return jax.tree_util.tree_map(lambda *leaves: jnp.stack(leaves), *models)


def tree_unstack(stacked) -> list:
    """Inverse of :func:`tree_stack`: split the leading axis back out."""
    leaves, treedef = jax.tree_util.tree_flatten(stacked)
    n = leaves[0].shape[0]
    return [jax.tree_util.tree_unflatten(treedef, [leaf[i] for leaf in leaves])
            for i in range(n)]


@jax.jit
def _stacked_mean_single(stacked):
    return jax.tree_util.tree_map(
        lambda leaf: jnp.mean(leaf.astype(jnp.float32), axis=0)
        if jnp.issubdtype(leaf.dtype, jnp.floating) else leaf[0], stacked)


def stacked_mean(stacked, mesh=None, axis_name: str = "clients",
                 data_axis=None):
    """Eq. 6 over a stacked tree: mean over the leading client axis.

    With a ``mesh`` whose ``axis_name`` axis is larger than one, the leading
    axis is treated as sharded over it: each device part-sums its local
    clients and one ``psum`` yields the mean (leading axis zero-padded to a
    mesh-size multiple; zeros drop out of the sum, the divisor stays K).
    On a 2-D (clients, data) cohort mesh, pass ``data_axis`` to spread the
    stacked axis over BOTH mesh axes — the psum then runs over the axis
    pair and every device carries 1/(C*D) of the models."""
    axes = _reduce_axes(mesh, axis_name, data_axis)
    n = int(np.prod([_mesh_axis_size(mesh, a) for a in axes]))
    if n <= 1:
        return _stacked_mean_single(stacked)
    leaves, treedef = jax.tree_util.tree_flatten(stacked)
    k = int(leaves[0].shape[0])
    target = _quantized_target(k, n)
    is_f = [jnp.issubdtype(l.dtype, jnp.floating) for l in leaves]
    floats = [pad_leading(l.astype(jnp.float32), target)
              for l, f in zip(leaves, is_f) if f]
    summed = iter(_psum_reducer(mesh, axes, "sum")(floats)
                  if floats else [])
    out = [next(summed) / k if f else l[0] for l, f in zip(leaves, is_f)]
    return jax.tree_util.tree_unflatten(treedef, out)


def stacked_weighted(stacked, weights, mesh=None, axis_name: str = "clients",
                     data_axis=None):
    """Weighted aggregation over a stacked tree's leading axis M.

    ``weights`` of shape (M,) produces one aggregate tree;  shape (K, M)
    produces a stacked tree of K aggregates in one einsum per leaf — the
    cohort path's "aggregate every client's tip selection at once", where
    row k holds client k's (normalised) weights over the M stacked models.

    With a ``mesh``, the M axis is sharded over ``axis_name`` (joined by
    ``data_axis`` on a 2-D cohort mesh): each device einsums its local
    models against its weight columns and one ``psum`` assembles the
    (K, ...) aggregates (M zero-padded to a mesh-size multiple with zero
    weights — identical math).
    """
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-12)
    batched = w.ndim == 2

    axes = _reduce_axes(mesh, axis_name, data_axis) if mesh is not None \
        else (axis_name,)
    n = int(np.prod([_mesh_axis_size(mesh, a) for a in axes]))
    if n > 1:
        leaves, treedef = jax.tree_util.tree_flatten(stacked)
        m = int(leaves[0].shape[0])
        target = _quantized_target(m, n)
        # quantize BOTH stacked axes: K (weight rows) and M (models) vary
        # every cohort window, and each shape pair is a compiled program
        w2 = w if batched else w[None]
        k = int(w2.shape[0])
        k_pad = _quantized_target(k, 1)
        w2 = jnp.pad(w2, ((0, k_pad - k), (0, target - m)))
        is_f = [jnp.issubdtype(l.dtype, jnp.floating) for l in leaves]
        floats = [pad_leading(l.astype(jnp.float32), target)
                  for l, f in zip(leaves, is_f) if f]
        red = iter(_psum_reducer(mesh, axes, "wsum")(floats, w2)
                   if floats else [])

        def pick(l, f):
            if f:
                r = next(red)
                return r[:k] if batched else r[0]
            if batched:
                return jnp.broadcast_to(l[0], (k,) + l.shape[1:])
            return l[0]

        out = [pick(l, f) for l, f in zip(leaves, is_f)]
        return jax.tree_util.tree_unflatten(treedef, out)

    def combine(leaf):
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            if batched:
                return jnp.broadcast_to(leaf[0], w.shape[:1] + leaf.shape[1:])
            return leaf[0]
        f = leaf.astype(jnp.float32)
        if batched:
            return jnp.einsum("km,m...->k...", w, f)
        return jnp.einsum("m,m...->...", w, f)

    return jax.tree_util.tree_map(combine, stacked)


@jax.jit
def tree_interpolate(a, b, alpha: float):
    """FedAsync-style mixing: (1-alpha)*a + alpha*b."""
    return jax.tree_util.tree_map(
        lambda x, y: ((1 - alpha) * x.astype(jnp.float32)
                      + alpha * y.astype(jnp.float32))
        if jnp.issubdtype(x.dtype, jnp.floating) else x, a, b)


def tree_size_bytes(model) -> int:
    return sum(a.size * a.dtype.itemsize
               for a in jax.tree_util.tree_leaves(model) if hasattr(a, "size"))
