"""Model aggregation (paper Eq. 6) as jitted pytree programs.

Eq. 6 is a plain average over the N selected tip models; ``tree_weighted``
is the beyond-paper generalisation (staleness- or accuracy-weighted) used by
the optimized DAG-AFL variant and by several baselines (FedAsync mixing,
FedAT tier weighting).
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp


@jax.jit
def tree_mean(models: Sequence):
    """Eq. 6: w = (1/N) * sum_i w_i  over a list of congruent pytrees."""
    n = len(models)
    return jax.tree_util.tree_map(
        lambda *leaves: sum(l.astype(jnp.float32) for l in leaves) / n
        if jnp.issubdtype(leaves[0].dtype, jnp.floating) else leaves[0],
        *models)


def tree_weighted(models: Sequence, weights: Sequence[float]):
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.maximum(jnp.sum(w), 1e-12)

    def combine(*leaves):
        if not jnp.issubdtype(leaves[0].dtype, jnp.floating):
            return leaves[0]
        return sum(wi * l.astype(jnp.float32) for wi, l in zip(w, leaves))

    return jax.tree_util.tree_map(combine, *models)


# -- stacked-tree variants (leading client axis) ----------------------------
#
# The cohort execution engine keeps K client models stacked as ONE pytree
# whose leaves carry a leading client axis.  Aggregating over that axis is a
# single XLA reduction instead of K Python-level ``tree_mean`` calls.


def tree_stack(models: Sequence):
    """Stack K congruent pytrees into one with a leading K axis per leaf."""
    return jax.tree_util.tree_map(lambda *leaves: jnp.stack(leaves), *models)


def tree_unstack(stacked) -> list:
    """Inverse of :func:`tree_stack`: split the leading axis back out."""
    leaves, treedef = jax.tree_util.tree_flatten(stacked)
    n = leaves[0].shape[0]
    return [jax.tree_util.tree_unflatten(treedef, [leaf[i] for leaf in leaves])
            for i in range(n)]


@jax.jit
def stacked_mean(stacked):
    """Eq. 6 over a stacked tree: mean over the leading client axis."""
    return jax.tree_util.tree_map(
        lambda leaf: jnp.mean(leaf.astype(jnp.float32), axis=0)
        if jnp.issubdtype(leaf.dtype, jnp.floating) else leaf[0], stacked)


def stacked_weighted(stacked, weights):
    """Weighted aggregation over a stacked tree's leading axis M.

    ``weights`` of shape (M,) produces one aggregate tree;  shape (K, M)
    produces a stacked tree of K aggregates in one einsum per leaf — the
    cohort path's "aggregate every client's tip selection at once", where
    row k holds client k's (normalised) weights over the M stacked models.
    """
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-12)
    batched = w.ndim == 2

    def combine(leaf):
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            if batched:
                return jnp.broadcast_to(leaf[0], w.shape[:1] + leaf.shape[1:])
            return leaf[0]
        f = leaf.astype(jnp.float32)
        if batched:
            return jnp.einsum("km,m...->k...", w, f)
        return jnp.einsum("m,m...->...", w, f)

    return jax.tree_util.tree_map(combine, stacked)


@jax.jit
def tree_interpolate(a, b, alpha: float):
    """FedAsync-style mixing: (1-alpha)*a + alpha*b."""
    return jax.tree_util.tree_map(
        lambda x, y: ((1 - alpha) * x.astype(jnp.float32)
                      + alpha * y.astype(jnp.float32))
        if jnp.issubdtype(x.dtype, jnp.floating) else x, a, b)


def tree_size_bytes(model) -> int:
    return sum(a.size * a.dtype.itemsize
               for a in jax.tree_util.tree_leaves(model) if hasattr(a, "size"))
