"""Model aggregation (paper Eq. 6) as jitted pytree programs.

Eq. 6 is a plain average over the N selected tip models; ``tree_weighted``
is the beyond-paper generalisation (staleness- or accuracy-weighted) used by
the optimized DAG-AFL variant and by several baselines (FedAsync mixing,
FedAT tier weighting).
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp


@jax.jit
def tree_mean(models: Sequence):
    """Eq. 6: w = (1/N) * sum_i w_i  over a list of congruent pytrees."""
    n = len(models)
    return jax.tree_util.tree_map(
        lambda *leaves: sum(l.astype(jnp.float32) for l in leaves) / n
        if jnp.issubdtype(leaves[0].dtype, jnp.floating) else leaves[0],
        *models)


def tree_weighted(models: Sequence, weights: Sequence[float]):
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.maximum(jnp.sum(w), 1e-12)

    def combine(*leaves):
        if not jnp.issubdtype(leaves[0].dtype, jnp.floating):
            return leaves[0]
        return sum(wi * l.astype(jnp.float32) for wi, l in zip(w, leaves))

    return jax.tree_util.tree_map(combine, *models)


@jax.jit
def tree_interpolate(a, b, alpha: float):
    """FedAsync-style mixing: (1-alpha)*a + alpha*b."""
    return jax.tree_util.tree_map(
        lambda x, y: ((1 - alpha) * x.astype(jnp.float32)
                      + alpha * y.astype(jnp.float32))
        if jnp.issubdtype(x.dtype, jnp.floating) else x, a, b)


def tree_size_bytes(model) -> int:
    return sum(a.size * a.dtype.itemsize
               for a in jax.tree_util.tree_leaves(model) if hasattr(a, "size"))
