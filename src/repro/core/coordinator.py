"""DAG-AFL coordinator: task publisher + asynchronous task trainers (§III-A).

Wires the DAG ledger, tip selection, signature contract, verification and
aggregation into the event-driven simulator.  Each client runs its own
asynchronous loop:

  select tips -> P2P-fetch the selected models -> aggregate (Eq. 6) ->
  local train -> validate + extract signature -> publish metadata tx

The publisher only bootstraps (genesis), audits (hash verification) and
monitors convergence — it never trains, matching the paper.

Execution engines
-----------------
``cohort_size=1`` (default) runs every client round as its own sequence of
jitted calls — the reference path.  ``cohort_size=K`` drains the event heap
in *cohort windows*: round-start events whose start times fall within
``cohort_window`` simulated seconds of the window opener are dispatched as
ONE ``jax.vmap``-batched program over the stacked K-client pytree
(:class:`repro.fl.cohort.CohortBackend`).  Each result is still published to
the DAG at its own simulated completion time (clamped to the window's flush
time in the degenerate case of a round shorter than the window — keep
``cohort_window`` below the typical round duration), so simulated-time
semantics — the paper's Table III measurement substrate — are unchanged.
The only relaxation is bounded tip staleness: a batched round's tip
selection may
observe the DAG up to ``cohort_window`` simulated seconds away from its own
start (never beyond the window), the same semi-async relaxation DAG-AFL is
built to tolerate — its whole premise is clients acting on slightly stale
tips.  Training, validation and signature extraction for the window then
run as single batched dispatches, which is where the wall-clock win lives.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.aggregate import (stacked_weighted, tree_mean,
                                  tree_size_bytes, tree_stack, tree_unstack)
from repro.core.dag import (BoundedDAGLedger, DAGLedger, ModelStore,
                            TxMetadata)
from repro.core.signature import SimilarityContract
from repro.core.simulator import (ClientProfile, CohortWindow,
                                  ConvergenceTracker, CostModel, EventLoop,
                                  RunResult, make_profiles)
from repro.core.tip_selection import (TipSelectionConfig, TipSelectionRequest,
                                      TipSelector)
from repro.core.verify import extract_path, verify_path


@dataclass
class DagAflConfig:
    n_clients: int = 10
    max_rounds: int = 30              # per-client global iterations
    local_epochs: int = 5
    target_accuracy: Optional[float] = None
    patience: int = 5
    tip: TipSelectionConfig = field(default_factory=TipSelectionConfig)
    heterogeneity: float = 0.6
    verify_paths: bool = True         # trainers audit their stored paths
    seed: int = 0
    # vectorized execution: batch up to this many concurrent client rounds
    # into one vmapped program (1 = sequential reference path)
    cohort_size: int = 1
    # round starts within this many simulated seconds share a cohort window;
    # keep it below the typical round duration — a publish whose completion
    # time falls before the window flushes is clamped to the flush time
    cohort_window: float = 1.0
    # SPMD cohort execution: "auto" builds a clients-axis mesh clamped to
    # this host's devices (1 device => exact single-device path), "CxD"
    # (e.g. "4x2") or a (clients, data) tuple — clients may be "auto" —
    # builds the 2-D (clients, data) mesh that additionally shards each
    # client group's training data, None forces single-device, or pass a
    # jax.sharding.Mesh carrying ``clients_axis`` (extra axes compose)
    mesh: object = "auto"
    clients_axis: str = "clients"
    data_axis: str = "data"
    # overlapped host pipeline: prefetch each window's batch assembly on a
    # background thread while the device computes (False = inline assembly,
    # bit-identical results — the toggle exists for benchmarking/debugging)
    overlap: bool = True
    # kernel dispatch policy for the cohort hot paths (Eq. 3 signatures, LM
    # attention): None keeps the incumbent stock-XLA math; "auto" resolves
    # per platform (TPU -> compiled Pallas, else interpreter); "compiled" /
    # "interpret" / "reference" force a concrete path.  See
    # repro.kernels.dispatch.
    kernel_policy: object = None
    # bounded-frontier ledger: > 0 switches to BoundedDAGLedger and folds
    # confirmed ancestry into checkpoints every this many SIMULATED seconds
    # (event-loop cadence), evicting pruned ModelStore entries.  Pruning
    # preserves tips/reachability/selection exactly (see DESIGN.md); the
    # run trajectory is identical to the unbounded ledger's, except that
    # with verify_paths=True the trainers' stored paths end at the pruned
    # boundary, so the (smaller) simulated audit cost shifts timings.
    # 0 keeps the append-only reference ledger.
    ledger_checkpoint_every: float = 0.0
    # fault injection: None (honest run), a repro.fl.scenarios.ScenarioConfig,
    # a registry name ("poison", "lazy", ...) or a prebuilt Scenario instance
    # (pass the instance to read its event counters after the run).  A
    # scenario with all rates zero is bit-identical to scenario=None.
    scenario: object = None
    # live-traffic serving (repro/fl/serving.py): > 0 publishes the tip
    # frontier's Eq. 6 aggregate into a versioned double-buffered replica
    # every this many SIMULATED seconds and replays a seeded Poisson query
    # trace against it concurrently with training.  Serving is read-only:
    # the training trajectory is bit-identical with it on or off.  0 = off.
    serve_every: float = 0.0
    # query driver: "auto" sniffs the backend (LMBackend -> prefill+decode,
    # else batched eval); "cnn" / "lm" force one
    serve_backend: str = "auto"
    # full repro.fl.serving.ServingConfig override (query rate/batch/seed,
    # prompt geometry, kernel policy); None derives one from the two knobs
    # above
    serving: object = None


def resolve_cohort_mesh(mesh, cohort_size: int, clients_axis: str = "clients",
                        data_axis: str = "data"):
    """Back-compat alias for :func:`repro.fl.cohort.resolve_cohort_mesh`."""
    from repro.fl.cohort import resolve_cohort_mesh as _resolve
    return _resolve(mesh, cohort_size, clients_axis, data_axis)


class _ClientTipEvaluator:
    """:class:`repro.core.tip_selection.TipEvaluator` for one client,
    bridging the coordinator's accuracy cache and the cohort engine's
    batched validation."""

    def __init__(self, coord: "DagAflCoordinator", client: int):
        self.coord = coord
        self.client = client

    def evaluate(self, tx_id: str) -> float:
        return self.coord._evaluate_tip(self.client, tx_id)

    def warm(self, tx_ids) -> None:
        if self.coord.cohort is not None and tx_ids:
            self.coord._evaluate_tips_batch(self.client, tx_ids)


class DagAflCoordinator:
    def __init__(self, backend, client_data: List[Dict], global_test,
                 cfg: DagAflConfig, cost: Optional[CostModel] = None,
                 profiles: Optional[List[ClientProfile]] = None,
                 cohort_engine=None):
        """client_data[k]: {"train": ..., "val": ..., "test": ...} per client
        (backend-specific containers).  ``cohort_engine`` lets callers reuse
        one compiled :class:`repro.fl.cohort.CohortBackend` across runs
        (jit caches live on the engine instance)."""
        self.backend = backend
        self.scenario = None
        if cfg.scenario is not None:
            # lazy import: core stays importable without the fl package
            from repro.fl.scenarios import as_scenario
            self.scenario = as_scenario(cfg.scenario, cfg.n_clients)
            # poisoned shards must exist BEFORE the cohort engine registers
            # its train shards below
            client_data = self.scenario.poison_data(client_data)
        self.client_data = client_data
        self.global_test = global_test
        self.cfg = cfg
        self.cost = cost or CostModel()
        self.profiles = profiles or make_profiles(cfg.n_clients,
                                                  cfg.heterogeneity, cfg.seed)
        if cfg.ledger_checkpoint_every > 0:
            self.ledger = BoundedDAGLedger(evict_fn=self._on_prune)
        else:
            self.ledger = DAGLedger()
        self.store = ModelStore()
        # model refs whose tx was pruned while still being a client's
        # LATEST (needed by the final per-client sweep); evicted as soon as
        # the client publishes again
        self._deferred_evict: Dict[int, str] = {}
        # live-traffic serving (built in run() when cfg.serve_every > 0);
        # must exist before the first _on_prune can fire
        self.publisher = None
        self.query_stream = None
        self.contract = SimilarityContract(cfg.n_clients)
        self.selector = TipSelector(self.ledger, self.contract, cfg.tip)
        self.loop = EventLoop()
        self.tracker = ConvergenceTracker(cfg.target_accuracy, cfg.patience,
                                          min_updates=3)
        self.rng = np.random.default_rng(cfg.seed)
        self._acc_cache: Dict = {}
        self._client_rounds = [0] * cfg.n_clients
        self._client_val = [0.0] * cfg.n_clients
        self._evals_total = 0
        self._refs_issued = 0         # monotone ref keys (len() reuses slots
                                      # once pruning evicts store entries)
        self._verify_failures = 0
        self._rounds_done = 0
        self._t_last_round = 0.0
        self._cohorts_dispatched = 0
        self._val_sets = [client_data[c]["val"] for c in range(cfg.n_clients)]
        self.cohort = None
        self._window: Optional[CohortWindow] = None
        if cfg.cohort_size > 1:
            # backend-agnostic: build_cohort_engine consults the cohort
            # program registry (CNN, LM, ...) and returns None for backends
            # without a batched program suite — those stay sequential
            from repro.fl.cohort import build_cohort_engine
            shards = [client_data[c]["train"] for c in range(cfg.n_clients)]
            if cohort_engine is not None:
                self.cohort = cohort_engine
                self.cohort.register_shards(shards, epochs=cfg.local_epochs)
            else:
                self.cohort = build_cohort_engine(
                    backend, shards, cohort_size=cfg.cohort_size,
                    mesh=cfg.mesh, clients_axis=cfg.clients_axis,
                    data_axis=cfg.data_axis, epochs=cfg.local_epochs,
                    overlap=cfg.overlap, kernel_policy=cfg.kernel_policy)
            if self.cohort is not None:
                self._window = CohortWindow(
                    self.loop, cfg.cohort_size, cfg.cohort_window,
                    self._flush_cohort, lambda: self.tracker.done)

    # -- helpers -------------------------------------------------------------

    def _on_prune(self, tx) -> None:
        """BoundedDAGLedger eviction hook: drop a pruned transaction's
        ModelStore entry.  A model still referenced as some client's LATEST
        (the final per-client sweep needs it) is deferred until that client
        publishes again, so the bounded run's results match the unbounded
        ledger's exactly."""
        client = tx.metadata.client_id
        if self.ledger.latest_of(client) == tx.tx_id:
            self._deferred_evict[client] = tx.model_ref
        else:
            self._evict_model(tx.model_ref)

    def _evict_model(self, ref: str) -> None:
        """Single chokepoint for prune-driven ModelStore evictions: a ref
        pinned by a live serving replica is handed to the publisher (which
        evicts it on the swap that unpins it) instead of being dropped out
        from under in-flight queries."""
        if self.publisher is not None and self.publisher.guard_evict(ref):
            return
        self.store.evict(ref)

    def _evaluate_tip(self, client: int, tx_id: str) -> float:
        key = (client, tx_id)
        if key not in self._acc_cache:
            model = self.store.get(self.ledger.get_tx(tx_id).model_ref)
            acc = self.backend.evaluate(model, self.client_data[client]["val"])
            self._acc_cache[key] = acc
            self._evals_total += 1
        return self._acc_cache[key]

    def _evaluate_tips_batch(self, client: int, tx_ids) -> None:
        """Validate every uncached candidate in ONE vmapped dispatch; the
        per-tip ``_evaluate_tip`` then serves from the warmed cache."""
        missing = [t for t in tx_ids if (client, t) not in self._acc_cache]
        if not missing:
            return
        models = [self.store.get(self.ledger.get_tx(t).model_ref)
                  for t in missing]
        accs = self.cohort.evaluate_many(models,
                                         self.client_data[client]["val"])
        for t, acc in zip(missing, accs):
            self._acc_cache[(client, t)] = acc
            self._evals_total += 1

    def _publish(self, client: int, model, accuracy: float, sig, epoch: int,
                 parents) -> str:
        pending = self._deferred_evict.pop(client, None)
        if pending is not None:         # pruned-while-latest: safe to drop now
            self._evict_model(pending)
        ref = self.store.put(f"m{self._refs_issued:012d}", model)
        self._refs_issued += 1
        meta = TxMetadata(client_id=client,
                          signature=tuple(float(s) for s in np.ravel(sig)[:16]),
                          model_accuracy=float(accuracy),
                          current_epoch=epoch,
                          validation_node_id=client)
        tx = self.ledger.add_transaction(meta, parents, self.loop.now, ref)
        self.contract.post_signature(client, sig)
        self.contract.commit_round(epoch)
        return tx.tx_id

    def _eval_global_on_vals(self, gm) -> List[float]:
        if self.cohort is not None:
            return self.cohort.evaluate_shared(gm, self._val_sets)
        return [self.backend.evaluate(gm, self.client_data[c]["val"])
                for c in range(self.cfg.n_clients)]

    def _start_round(self, delay: float, client: int) -> None:
        if self._window is not None:
            self.loop.schedule(delay, lambda: self._enqueue_round(client))
        else:
            self.loop.schedule(delay, lambda: self._client_round(client))

    def _complete_round(self, client: int, model, acc: float, sig,
                        epoch: int, parents) -> None:
        """Publish at the round's simulated completion time (both paths)."""
        if self.scenario is not None and self.scenario.drops_publish(client):
            # wireless dropout: the publish aborts mid-round — no tx, no
            # signature post; the attempt still counts against max_rounds
            # and the client retries with a fresh round
            self._client_rounds[client] += 1
            self._t_last_round = self.loop.now
            if (not self.tracker.done
                    and self._client_rounds[client] < self.cfg.max_rounds):
                self._start_round(0.0, client)
            return
        tx_id = self._publish(client, model, acc, sig, epoch, parents)
        if self.scenario is not None:
            self.scenario.maybe_tamper(self.ledger, tx_id)
        self._client_rounds[client] += 1
        self._client_val[client] = acc
        self._rounds_done += 1
        self._t_last_round = self.loop.now
        # publisher monitors per GLOBAL round (n_clients publishes) by
        # validating the AGGREGATED tip model on every client's val set
        # — the same quantity the sync baselines track; per-client local
        # models would ace their own non-IID shards and stop too early
        if self._rounds_done % self.cfg.n_clients == 0:
            gm = self.global_model()
            accs = self._eval_global_on_vals(gm)
            self.tracker.update(self.loop.now, float(np.mean(accs)))
        if (not self.tracker.done
                and self._client_rounds[client] < self.cfg.max_rounds):
            self._start_round(0.0, client)

    # -- round front half: tip selection + fetch + simulated costs ----------

    def _select_and_cost(self, client: int):
        """Tip selection, P2P fetch accounting and the path audit for one
        round; returns (model refs to aggregate, parents, t_select+t_fetch).
        Shared verbatim by the sequential and cohort paths."""
        cfgc, cost, prof = self.cfg, self.cost, self.profiles[client]
        epoch = self._client_rounds[client]

        n_evals_before = self._evals_total
        req = TipSelectionRequest(client_id=client, cur_epoch=epoch,
                                  now=self.loop.now, round_idx=epoch)
        scores = self.selector.select(req, _ClientTipEvaluator(self, client))
        n_evals = self._evals_total - n_evals_before
        t_select = cost.eval_time(prof, n_evals) + cost.chain_op * len(scores)

        refs = [self.ledger.get_tx(s.tx_id).model_ref for s in scores]
        t_fetch = sum(cost.transfer_time(prof, cost.model_bytes)
                      for _ in refs)
        if cfgc.verify_paths and scores:
            path = extract_path(self.ledger, scores[0].tx_id)
            ok, _ = verify_path(self.ledger, path)
            if not ok:
                self._verify_failures += 1
            t_fetch += cost.chain_op * len(path.records)

        if not refs:
            refs = [self.ledger.get_tx(self.ledger.genesis_id).model_ref]
        parents = tuple(s.tx_id for s in scores) or (self.ledger.genesis_id,)
        return refs, parents, epoch, t_select + t_fetch

    def _t_post(self, prof: ClientProfile) -> float:
        """Simulated cost of validate + signature + metadata publish."""
        cost = self.cost
        return (cost.eval_time(prof, 1) + cost.signature * prof.speed
                + cost.transfer_time(prof, cost.metadata_bytes))

    def _front_half(self, client: int, t_start: float) -> Dict:
        """Tip selection + the round's simulated-cost draws, as one record.
        RNG order (seed, then train-time jitter) matches the seed repo's
        sequential stream."""
        refs, parents, epoch, t_front = self._select_and_cost(client)
        seed = int(self.rng.integers(2 ** 31))
        t_train = self.cost.train_time(self.profiles[client],
                                       self.cfg.local_epochs, self.rng)
        if self.scenario is not None:
            # heavy-tailed straggler slowdown (x1.0 exactly for non-
            # stragglers, so the honest trajectory keeps its bits)
            t_train *= self.scenario.duration_multiplier(client)
        return {"client": client, "t_start": t_start, "refs": refs,
                "parents": parents, "epoch": epoch, "t_front": t_front,
                "t_train": t_train, "seed": seed}

    def _dispatch_one(self, rd: Dict) -> None:
        """Back half of ONE round on the backend's own jitted programs:
        aggregate, train, validate, sign, and schedule the publish at the
        round's own simulated completion time.  Used verbatim by the
        sequential path and by cohort windows of one."""
        client = rd["client"]
        agg = tree_mean([self.store.get(r) for r in rd["refs"]])
        model, _ = self.backend.train_local(
            agg, self.client_data[client]["train"], seed=rd["seed"],
            epochs=self.cfg.local_epochs)
        if self.scenario is not None:
            model = self._scenario_update_one(client, agg, model)
        acc = self.backend.evaluate(model, self.client_data[client]["val"])
        sig = self.backend.signature(model, self.client_data[client]["train"])
        total = rd["t_front"] + rd["t_train"] + self._t_post(
            self.profiles[client])
        self.loop.schedule(
            rd["t_start"] + total - self.loop.now,
            lambda: self._complete_round(client, model, acc, sig,
                                         rd["epoch"] + 1, rd["parents"]))

    # -- fault injection (repro/fl/scenarios.py) -------------------------------

    def _scenario_update_one(self, client: int, agg, model):
        """Scenario update transform for ONE trained model (sequential path
        and windows of one); injection happens BEFORE validation and the
        signature so the published artefacts describe the attacked model."""
        sc = self.scenario
        plan = sc.update_plan([client])
        if plan is not None and plan["affected"][0]:
            from repro.fl.cohort import perturb_update
            model = perturb_update(agg, model, plan, 0)
        return self._scenario_stale(client, model)

    def _scenario_stale(self, client: int, model):
        """lazy_mode='stale' free-riders republish their previous model
        (host-side swap; first publish has nothing to replay)."""
        sc = self.scenario
        if not sc.wants_stale(client):
            return model
        prev = self.ledger.latest_of(client)
        if prev is not None and self.ledger.has_tx(prev):
            ref = self.ledger.get_tx(prev).model_ref
            if ref in self.store:
                sc.updates_lazy += 1
                return self.store.get(ref)
        return model

    def _scenario_update_cohort(self, rounds, agg_stacked, new_stacked):
        """Scenario update transforms for a whole window: one vmapped jitted
        program on the cohort engine; unaffected rows keep their exact bits
        (see CohortBackend.perturb_cohort_stacked)."""
        sc = self.scenario
        clients = [rd["client"] for rd in rounds]
        plan = sc.update_plan(clients)
        if plan is not None:
            new_stacked = self.cohort.perturb_cohort_stacked(
                agg_stacked, new_stacked, plan)
        stale = [k for k, c in enumerate(clients) if sc.wants_stale(c)]
        if stale:
            models = tree_unstack(new_stacked)
            for k in stale:
                models[k] = self._scenario_stale(clients[k], models[k])
            new_stacked = tree_stack(models)
        return new_stacked

    # -- sequential client round ---------------------------------------------

    def _client_round(self, client: int) -> None:
        if self.tracker.done:
            return
        self._dispatch_one(self._front_half(client, self.loop.now))

    # -- cohort-window client rounds ------------------------------------------

    def _enqueue_round(self, client: int) -> None:
        if not self.tracker.done:
            self._window.add(client)

    def _flush_cohort(self, batch) -> None:
        """Dispatch one window: batch is [(client, start_time)] from
        :class:`CohortWindow`.  Tip selection stays per-client (DAG-state
        logic; its expensive part — candidate validation — is batched
        underneath), then training/validation/signatures run as single
        vmapped programs and every result publishes at its own simulated
        completion time."""
        cfgc = self.cfg
        rounds = [self._front_half(client, t_start)
                  for client, t_start in batch]

        if len(rounds) == 1:
            # a window of one: the backend's own jitted programs are already
            # optimal — skip the stack/pad/unstack round trip entirely
            self._dispatch_one(rounds[0])
            return

        # the window's membership and seeds are now fixed, so its batch
        # assembly (per-client np RNG sampling + stack/pad + device_put)
        # can start on the assembler's background thread and overlap the
        # device work below — tip-model stacking and the Eq. 6 collective
        train_sets = [self.client_data[rd["client"]]["train"] for rd in rounds]
        seeds = [rd["seed"] for rd in rounds]
        self.cohort.prefetch_window(train_sets, seeds,
                                    epochs=cfgc.local_epochs)

        # Eq. 6 for the whole cohort as ONE stacked reduction: stack the
        # union of selected models once, then a (K, M) weight matrix row per
        # client (uniform over its own selection, zero elsewhere)
        uniq = list(dict.fromkeys(r for rd in rounds for r in rd["refs"]))
        ref_pos = {r: i for i, r in enumerate(uniq)}
        weights = np.zeros((len(rounds), len(uniq)), np.float32)
        for k, rd in enumerate(rounds):
            for r in rd["refs"]:
                weights[k, ref_pos[r]] = 1.0
        # under a mesh this is the window's cross-device collective: the M
        # stacked tip models spread over the mesh (BOTH axes of a 2-D one)
        # and one psum-einsum yields every client's Eq. 6 aggregate (see
        # core/aggregate.py)
        stacked_tips = tree_stack([self.store.get(r) for r in uniq])
        agg_stacked = stacked_weighted(stacked_tips, weights,
                                       mesh=self.cohort.mesh,
                                       axis_name=self.cohort.clients_axis,
                                       data_axis=self.cohort.data_axis)

        # batched local training + validation + signature extraction
        val_sets = [self.client_data[rd["client"]]["val"] for rd in rounds]
        new_stacked, _ = self.cohort.train_cohort_stacked(
            agg_stacked, train_sets, seeds, epochs=cfgc.local_epochs)
        if self.scenario is not None:
            new_stacked = self._scenario_update_cohort(rounds, agg_stacked,
                                                       new_stacked)
        val_accs = self.cohort.evaluate_cohort_stacked(new_stacked, val_sets)
        sigs = self.cohort.signature_cohort_stacked(new_stacked, train_sets)
        new_models = tree_unstack(new_stacked)
        self._cohorts_dispatched += 1

        # publish each round at ITS OWN simulated completion time
        for rd, model, acc, sig in zip(rounds, new_models, val_accs, sigs):
            total = (rd["t_front"] + rd["t_train"]
                     + self._t_post(self.profiles[rd["client"]]))

            def finish(rd=rd, model=model, acc=acc, sig=sig):
                self._complete_round(rd["client"], model, acc, sig,
                                     rd["epoch"] + 1, rd["parents"])

            self.loop.schedule(rd["t_start"] + total - self.loop.now, finish)

    # -- run -------------------------------------------------------------------

    def global_model(self):
        """Average of the models at the current tips (publisher's view)."""
        tips = self.ledger.tips()
        models = [self.store.get(self.ledger.get_tx(t).model_ref)
                  for t in tips]
        return tree_mean(models) if models else None

    def _serving_config(self):
        """The effective ServingConfig, or None when serving is off."""
        if self.cfg.serving is not None:
            return self.cfg.serving
        if self.cfg.serve_every > 0:
            from repro.fl.serving import ServingConfig
            return ServingConfig(every=self.cfg.serve_every,
                                 backend=self.cfg.serve_backend,
                                 kernel_policy=self.cfg.kernel_policy)
        return None

    def _start_serving(self) -> None:
        """Bring up the replica publisher + query stream on the event loop
        (no-op when serving is off).  Runs after genesis so replica v0 is
        the genesis frontier."""
        scfg = self._serving_config()
        if scfg is None:
            return
        from repro.fl.serving import (ConsensusPublisher, QueryStream,
                                      make_query_driver)
        done = lambda: self.tracker.done
        self.publisher = ConsensusPublisher(self.ledger, self.store,
                                            self.loop, scfg.every, stop=done)
        driver = make_query_driver(scfg, self.backend, self.global_test)
        self.query_stream = QueryStream(self.publisher, driver, self.loop,
                                        self.ledger, scfg.query_rate,
                                        scfg.seed, stop=done)
        self.publisher.start()
        self.query_stream.start()

    def run(self, init_key=None) -> RunResult:
        import jax
        key = init_key if init_key is not None else jax.random.PRNGKey(self.cfg.seed)
        init_model = self.backend.init(key)
        ref = self.store.put("genesis", init_model)
        self.cost.model_bytes = max(tree_size_bytes(init_model), 1)
        meta = TxMetadata(client_id=-1, signature=(0.0,) * 16,
                          model_accuracy=0.0, current_epoch=0,
                          validation_node_id=-1)
        self.ledger.add_genesis(meta, 0.0, ref)
        if self.cfg.ledger_checkpoint_every > 0:
            # simulated-clock checkpoint cadence: fold confirmed ancestry
            # and evict its models while the run is in flight
            self.loop.schedule_every(
                self.cfg.ledger_checkpoint_every,
                lambda: self.ledger.maybe_checkpoint(now=self.loop.now),
                stop=lambda: self.tracker.done)
        self._start_serving()
        for c in range(self.cfg.n_clients):
            # staggered joins: asynchrony from the first event on
            self._start_round(float(self.rng.uniform(0, 2.0)), c)
        self.loop.run(stop=lambda: self.tracker.done)
        if self._window is not None:
            self._window.pending.clear()  # tracker stopped us mid-window

        # paper Table II reports AVERAGE accuracy across participants:
        # evaluate each client's latest model on the global test set
        latest_models = []
        for c in range(self.cfg.n_clients):
            tx = self.ledger.latest_of(c)
            if tx is None:
                continue
            ref = (self._deferred_evict.get(c)
                   if not self.ledger.has_tx(tx)
                   else self.ledger.get_tx(tx).model_ref)
            if ref is None or ref not in self.store:
                continue
            latest_models.append(self.store.get(ref))
        if self.cohort is not None and latest_models:
            client_accs = self.cohort.evaluate_many(latest_models,
                                                    self.global_test)
        else:
            client_accs = [self.backend.evaluate(m, self.global_test)
                           for m in latest_models]
        gm = self.global_model()
        tip_mean_acc = self.backend.evaluate(gm, self.global_test)
        client_mean = float(np.mean(client_accs)) if client_accs else 0.0
        # the publisher's deliverable is the aggregated model from the
        # current tips (the paper's 'global model'); per-client average in
        # extra for reference
        final_acc = max(tip_mean_acc, client_mean)
        extra_scenario = {}
        if self.scenario is not None:
            extra_scenario = {"scenario": self.scenario.cfg.name,
                              "scenario_counts": self.scenario.counts()}
        if self.query_stream is not None:
            extra_scenario["serving"] = {**self.publisher.report(),
                                         **self.query_stream.report()}
        return RunResult(
            name="DAG-AFL",
            final_accuracy=final_acc,
            best_accuracy=max(final_acc, self.tracker.best),
            # last ROUND completion, not loop.now: trailing maintenance
            # ticks (checkpoint cadence) are not training time
            sim_time=(self.tracker.converged_at or self._t_last_round
                      or self.loop.now),
            rounds=self._rounds_done,
            history=self.tracker.history,
            extra={
                "tip_mean_accuracy": tip_mean_acc,
                "client_mean_accuracy": client_mean,
                "tip_evaluations": self._evals_total,
                "chain_len": len(self.ledger),
                "verify_failures": self._verify_failures,
                "store_bytes_transferred": self.store.bytes_transferred,
                "cohorts_dispatched": self._cohorts_dispatched,
                **extra_scenario,
            })
