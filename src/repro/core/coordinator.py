"""DAG-AFL coordinator: task publisher + asynchronous task trainers (§III-A).

Wires the DAG ledger, tip selection, signature contract, verification and
aggregation into the event-driven simulator.  Each client runs its own
asynchronous loop:

  select tips -> P2P-fetch the selected models -> aggregate (Eq. 6) ->
  local train -> validate + extract signature -> publish metadata tx

The publisher only bootstraps (genesis), audits (hash verification) and
monitors convergence — it never trains, matching the paper.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.aggregate import tree_mean, tree_size_bytes
from repro.core.dag import DAGLedger, ModelStore, TxMetadata
from repro.core.signature import SimilarityContract
from repro.core.simulator import (ClientProfile, ConvergenceTracker, CostModel,
                                  EventLoop, RunResult, make_profiles)
from repro.core.tip_selection import TipSelectionConfig, select_tips
from repro.core.verify import extract_path, verify_path


@dataclass
class DagAflConfig:
    n_clients: int = 10
    max_rounds: int = 30              # per-client global iterations
    local_epochs: int = 5
    target_accuracy: Optional[float] = None
    patience: int = 5
    tip: TipSelectionConfig = field(default_factory=TipSelectionConfig)
    heterogeneity: float = 0.6
    verify_paths: bool = True         # trainers audit their stored paths
    seed: int = 0


class DagAflCoordinator:
    def __init__(self, backend, client_data: List[Dict], global_test,
                 cfg: DagAflConfig, cost: Optional[CostModel] = None,
                 profiles: Optional[List[ClientProfile]] = None):
        """client_data[k]: {"train": ..., "val": ..., "test": ...} per client
        (backend-specific containers)."""
        self.backend = backend
        self.client_data = client_data
        self.global_test = global_test
        self.cfg = cfg
        self.cost = cost or CostModel()
        self.profiles = profiles or make_profiles(cfg.n_clients,
                                                  cfg.heterogeneity, cfg.seed)
        self.ledger = DAGLedger()
        self.store = ModelStore()
        self.contract = SimilarityContract(cfg.n_clients)
        self.loop = EventLoop()
        self.tracker = ConvergenceTracker(cfg.target_accuracy, cfg.patience,
                                          min_updates=3)
        self.rng = np.random.default_rng(cfg.seed)
        self._acc_cache: Dict = {}
        self._client_rounds = [0] * cfg.n_clients
        self._client_val = [0.0] * cfg.n_clients
        self._evals_total = 0
        self._verify_failures = 0
        self._rounds_done = 0

    # -- helpers -------------------------------------------------------------

    def _evaluate_tip(self, client: int, tx_id: str) -> float:
        key = (client, tx_id)
        if key not in self._acc_cache:
            model = self.store.get(self.ledger.nodes[tx_id].model_ref)
            acc = self.backend.evaluate(model, self.client_data[client]["val"])
            self._acc_cache[key] = acc
            self._evals_total += 1
        return self._acc_cache[key]

    def _publish(self, client: int, model, accuracy: float, sig, epoch: int,
                 parents) -> None:
        ref = self.store.put(f"m{len(self.store):06d}", model)
        meta = TxMetadata(client_id=client,
                          signature=tuple(float(s) for s in np.ravel(sig)[:16]),
                          model_accuracy=float(accuracy),
                          current_epoch=epoch,
                          validation_node_id=client)
        self.ledger.add_transaction(meta, parents, self.loop.now, ref)
        self.contract.post_signature(client, sig)
        self.contract.commit_round(epoch)

    # -- client round ---------------------------------------------------------

    def _client_round(self, client: int) -> None:
        if self.tracker.done:
            return
        cfgc, cost, prof = self.cfg, self.cost, self.profiles[client]
        epoch = self._client_rounds[client]

        n_evals_before = self._evals_total
        scores = select_tips(self.ledger, client, epoch, self.loop.now,
                             lambda t: self._evaluate_tip(client, t),
                             self.contract, cfgc.tip, round_idx=epoch)
        n_evals = self._evals_total - n_evals_before
        t_select = cost.eval_time(prof, n_evals) + cost.chain_op * len(scores)

        # P2P fetch of the selected models + optional path audit
        models = [self.store.get(self.ledger.nodes[s.tx_id].model_ref)
                  for s in scores]
        t_fetch = sum(cost.transfer_time(prof, cost.model_bytes)
                      for _ in models)
        if cfgc.verify_paths and scores:
            path = extract_path(self.ledger, scores[0].tx_id)
            ok, _ = verify_path(self.ledger, path)
            if not ok:
                self._verify_failures += 1
            t_fetch += cost.chain_op * len(path.records)

        agg = tree_mean(models) if models else self.store.get(
            self.ledger.nodes[self.ledger.genesis_id].model_ref)

        new_model, _ = self.backend.train_local(
            agg, self.client_data[client]["train"],
            seed=int(self.rng.integers(2 ** 31)), epochs=cfgc.local_epochs)
        t_train = cost.train_time(prof, cfgc.local_epochs, self.rng)

        val_acc = self.backend.evaluate(new_model,
                                        self.client_data[client]["val"])
        sig = self.backend.signature(new_model, self.client_data[client]["train"])
        t_post = (cost.eval_time(prof, 1) + cost.signature * prof.speed
                  + cost.transfer_time(prof, cost.metadata_bytes))

        parents = tuple(s.tx_id for s in scores) or (self.ledger.genesis_id,)
        total = t_select + t_fetch + t_train + t_post

        def finish(client=client, model=new_model, acc=val_acc, sig=sig,
                   epoch=epoch, parents=parents):
            self._publish(client, model, acc, sig, epoch + 1, parents)
            self._client_rounds[client] += 1
            self._client_val[client] = acc
            self._rounds_done += 1
            # publisher monitors per GLOBAL round (n_clients publishes) by
            # validating the AGGREGATED tip model on every client's val set
            # — the same quantity the sync baselines track; per-client local
            # models would ace their own non-IID shards and stop too early
            if self._rounds_done % self.cfg.n_clients == 0:
                gm = self.global_model()
                accs = [self.backend.evaluate(gm, self.client_data[c]["val"])
                        for c in range(self.cfg.n_clients)]
                self.tracker.update(self.loop.now, float(np.mean(accs)))
            if (not self.tracker.done
                    and self._client_rounds[client] < self.cfg.max_rounds):
                self.loop.schedule(0.0, lambda: self._client_round(client))

        self.loop.schedule(total, finish)

    # -- run -------------------------------------------------------------------

    def global_model(self):
        """Average of the models at the current tips (publisher's view)."""
        tips = self.ledger.tips()
        models = [self.store.get(self.ledger.nodes[t].model_ref) for t in tips]
        return tree_mean(models) if models else None

    def run(self, init_key=None) -> RunResult:
        import jax
        key = init_key if init_key is not None else jax.random.PRNGKey(self.cfg.seed)
        init_model = self.backend.init(key)
        ref = self.store.put("genesis", init_model)
        self.cost.model_bytes = max(tree_size_bytes(init_model), 1)
        meta = TxMetadata(client_id=-1, signature=(0.0,) * 16,
                          model_accuracy=0.0, current_epoch=0,
                          validation_node_id=-1)
        self.ledger.add_genesis(meta, 0.0, ref)
        for c in range(self.cfg.n_clients):
            # staggered joins: asynchrony from the first event on
            self.loop.schedule(float(self.rng.uniform(0, 2.0)),
                               lambda c=c: self._client_round(c))
        self.loop.run(stop=lambda: self.tracker.done)

        # paper Table II reports AVERAGE accuracy across participants:
        # evaluate each client's latest model on the global test set
        client_accs = []
        for c in range(self.cfg.n_clients):
            tx = self.ledger.latest_of(c)
            if tx is None:
                continue
            model = self.store.get(self.ledger.nodes[tx].model_ref)
            client_accs.append(self.backend.evaluate(model, self.global_test))
        gm = self.global_model()
        tip_mean_acc = self.backend.evaluate(gm, self.global_test)
        client_mean = float(np.mean(client_accs)) if client_accs else 0.0
        # the publisher's deliverable is the aggregated model from the
        # current tips (the paper's 'global model'); per-client average in
        # extra for reference
        final_acc = max(tip_mean_acc, client_mean)
        return RunResult(
            name="DAG-AFL",
            final_accuracy=final_acc,
            best_accuracy=max(final_acc, self.tracker.best),
            sim_time=self.tracker.converged_at or self.loop.now,
            rounds=self._rounds_done,
            history=self.tracker.history,
            extra={
                "tip_mean_accuracy": tip_mean_acc,
                "client_mean_accuracy": client_mean,
                "tip_evaluations": self._evals_total,
                "chain_len": len(self.ledger),
                "verify_failures": self._verify_failures,
                "store_bytes_transferred": self.store.bytes_transferred,
            })
