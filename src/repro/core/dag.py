"""The DAG ledger (IOTA-style tangle) that DAG-AFL coordinates over.

Transactions carry ONLY metadata (paper §III-A: ``<ClientId, Signature,
ModelAccuracy, CurrentEpoch, ValidationNodeId>``); model weights travel peer
to peer through :class:`ModelStore`.  Tips are transactions with in-degree 0
(no later transaction approves them).  Each new transaction approves
``n_parents`` tips (2 in the paper).

Reachability (paper Alg. 1): BFS over *approval children* starting from the
client's own latest transaction — a tip is *reachable* iff it (directly or
transitively) approved the client's node, i.e. it has integrated the client's
previous aggregate.

Two ledger implementations share the :class:`LedgerView` protocol:

* :class:`DAGLedger` — the append-only reference ledger; every transaction
  ever published stays resident.
* :class:`BoundedDAGLedger` — the production ledger for 10^5-10^6 client
  populations.  When every current tip transitively approves a transaction
  it is *confirmed*; confirmed ancestry is periodically folded into a
  :class:`CheckpointRecord` (a merkle-style rollup of the pruned region's
  Eq. 7 hashes) and its bodies evicted, so live state is bounded by the
  consensus frontier, not total history.  Tip selection is index-backed:
  a freshness-ordered tip heap and incremental per-client reachability
  summaries replace from-scratch BFS + full tip scans.  See DESIGN.md.

Consumers (tip selection, verification, the coordinator) must go through
:class:`LedgerView` methods — ``get_tx``/``has_tx``/``hash_of``/... — never
the ``.nodes``/``.children`` dicts, so ledger internals can change without
touching them.
"""
from __future__ import annotations

import hashlib
import heapq
import json
from collections import deque
from dataclasses import dataclass, field
from typing import (Callable, Dict, Iterable, Iterator, List, Optional,
                    Sequence, Tuple)

try:  # py3.8+: typing.Protocol
    from typing import Protocol, runtime_checkable
except ImportError:  # pragma: no cover
    Protocol = object

    def runtime_checkable(cls):
        return cls


@dataclass(frozen=True)
class TxMetadata:
    """Exactly the tuple the paper puts on chain (§III-B end)."""

    client_id: int
    signature: Tuple[float, ...]       # feature signature vector (Eq. 3-4)
    model_accuracy: float
    current_epoch: int                 # trainer's global iteration epoch
    validation_node_id: int

    def digest(self) -> str:
        payload = json.dumps({
            "client_id": self.client_id,
            "signature": [round(float(s), 8) for s in self.signature],
            "model_accuracy": round(float(self.model_accuracy), 8),
            "current_epoch": int(self.current_epoch),
            "validation_node_id": int(self.validation_node_id),
        }, sort_keys=True)
        return hashlib.sha256(payload.encode()).hexdigest()


@dataclass
class Transaction:
    tx_id: str
    metadata: TxMetadata
    parents: Tuple[str, ...]           # approved tips (empty for genesis)
    timestamp: float                   # simulated publish time
    tx_hash: str = ""                  # Eq. 7: H(H1 | H2 | hash(metadata))
    model_ref: str = ""                # ModelStore key (P2P pointer)
    seq: int = 0                       # global append order (audit cursor)


def compute_tx_hash_from_digest(parent_hashes: Sequence[str],
                                metadata_digest: str) -> str:
    """Eq. 7 from an already-computed metadata digest (used when the body
    has been pruned and only the digest survives in a validation path)."""
    h = hashlib.sha256()
    for ph in parent_hashes:
        h.update(ph.encode())
    h.update(metadata_digest.encode())
    return h.hexdigest()


def compute_tx_hash(parent_hashes: Sequence[str], metadata: TxMetadata) -> str:
    """Eq. 7: block header = parent hashes, body = metadata digest."""
    return compute_tx_hash_from_digest(parent_hashes, metadata.digest())


def checkpoint_root(prev_root: str, leaves: Sequence[Tuple[str, str]]) -> str:
    """Merkle-style rollup of a pruned region: chain the previous
    checkpoint's root with the sorted ``(tx_id, tx_hash)`` leaves."""
    h = hashlib.sha256()
    h.update(prev_root.encode())
    for tx_id, tx_hash in sorted(leaves):
        h.update(tx_id.encode())
        h.update(tx_hash.encode())
    return h.hexdigest()


@dataclass(frozen=True)
class CheckpointRecord:
    """One checkpoint+prune: the confirmed region folded into a rollup.

    ``leaf_ids`` names the pruned transactions; their Eq. 7 hashes stay
    resident in the ledger's retained-hash map so ``root`` can be
    re-derived (tamper audit) and validation paths that cross the pruned
    region can still be hash-checked without the bodies.
    """

    ckpt_id: str
    seq: int                          # checkpoint ordinal (0-based)
    created_at: float                 # simulated time of the fold
    n_pruned: int                     # transactions folded by THIS record
    root: str                         # checkpoint_root(prev_root, leaves)
    prev_root: str
    leaf_ids: Tuple[str, ...]


GENESIS_ROOT = hashlib.sha256(b"dag-afl-checkpoint-genesis").hexdigest()


@runtime_checkable
class LedgerView(Protocol):
    """What ledger consumers (tip selection, verification, coordinator) may
    rely on.  Implemented by :class:`DAGLedger` and
    :class:`BoundedDAGLedger`; internals (``nodes``/``children`` dicts,
    indexes, prune bookkeeping) are private to the implementations.
    """

    genesis_id: Optional[str]

    def tips(self) -> List[str]: ...

    def tips_by_freshness(self, limit: Optional[int] = None) -> List[str]: ...

    def latest_of(self, client_id: int) -> Optional[str]: ...

    def head_seq(self) -> int: ...

    def reachable_tips(self, start_node: Optional[str],
                       within: Optional[Iterable[str]] = None
                       ) -> Tuple[List[str], List[str]]: ...

    def ancestors(self, tx_id: str,
                  max_depth: Optional[int] = None) -> List[str]: ...

    def get_tx(self, tx_id: str) -> Transaction: ...

    def has_tx(self, tx_id: str) -> bool: ...

    def is_pruned(self, tx_id: str) -> bool: ...

    def hash_of(self, tx_id: str) -> str: ...

    def transactions(self) -> Iterator[Transaction]: ...

    @property
    def checkpoints(self) -> Sequence[CheckpointRecord]: ...

    def __len__(self) -> int: ...


class ModelStore:
    """P2P weight transport stand-in: tx_id -> model pytree.

    On a pod, 'peers' are mesh slices and the transfer is device-to-device;
    here it is an in-memory map so the DAG provably never carries weights.
    """

    def __init__(self):
        self._store: Dict[str, object] = {}
        self.bytes_transferred = 0

    def put(self, key: str, model) -> str:
        self._store[key] = model
        return key

    def get(self, key: str):
        import jax
        model = self._store[key]
        self.bytes_transferred += sum(
            a.size * a.dtype.itemsize for a in jax.tree_util.tree_leaves(model)
            if hasattr(a, "size"))
        return model

    def evict(self, key: str):
        self._store.pop(key, None)

    def __contains__(self, key):
        return key in self._store

    def __len__(self):
        return len(self._store)


class DAGLedger:
    """Append-only DAG of transactions with tip tracking."""

    # 12-digit ids keep lexicographic order == numeric insertion order up
    # to 10^12 transactions.  The old 6-digit padding silently broke every
    # sorted-id iteration (tips(), reachable splits, top-up determinism)
    # past the 999999 -> 1000000 boundary.
    ID_DIGITS = 12

    def __init__(self):
        self.nodes: Dict[str, Transaction] = {}
        self.children: Dict[str, List[str]] = {}
        self._tips: set = set()
        self.genesis_id: Optional[str] = None
        self._counter = 0
        # per-client latest-transaction index: ``latest_of`` sits on the
        # coordinator's hot path (once per round per client plus the final
        # sweep), so an O(ledger) scan per call turns quadratic — keep it
        # O(1) by updating on append.  Only (tx_id, timestamp) is retained
        # so a pruned transaction's body is not pinned by the index.
        self._latest: Dict[int, Tuple[str, float]] = {}

    # -- construction -------------------------------------------------------

    def add_genesis(self, metadata: TxMetadata, timestamp: float = 0.0,
                    model_ref: str = "") -> Transaction:
        assert self.genesis_id is None, "genesis already exists"
        tx = self._make_tx(metadata, (), timestamp, model_ref)
        self.genesis_id = tx.tx_id
        return tx

    def add_transaction(self, metadata: TxMetadata, parents: Sequence[str],
                        timestamp: float, model_ref: str = "") -> Transaction:
        for p in parents:
            if not self._parent_known(p):
                raise KeyError(f"unknown parent {p}")
        return self._make_tx(metadata, tuple(parents), timestamp, model_ref)

    def _parent_known(self, tx_id: str) -> bool:
        return tx_id in self.nodes

    def _make_tx(self, metadata, parents, timestamp, model_ref) -> Transaction:
        tx_id = f"tx{self._counter:0{self.ID_DIGITS}d}"
        seq = self._counter
        self._counter += 1
        parent_hashes = [self.hash_of(p) for p in parents]
        tx = Transaction(tx_id=tx_id, metadata=metadata, parents=parents,
                         timestamp=timestamp,
                         tx_hash=compute_tx_hash(parent_hashes, metadata),
                         model_ref=model_ref or tx_id, seq=seq)
        self.nodes[tx_id] = tx
        self.children[tx_id] = []
        for p in parents:
            if p in self.children:         # pruned parents keep no edge list
                self.children[p].append(tx_id)
            self._tips.discard(p)
        self._tips.add(tx_id)
        # >= keeps the old full-scan tie-break: among equal timestamps the
        # latest-inserted transaction wins
        prev = self._latest.get(metadata.client_id)
        displaced = None
        if prev is None or timestamp >= prev[1]:
            self._latest[metadata.client_id] = (tx_id, timestamp)
            displaced = prev[0] if prev is not None else None
        self._on_append(tx, displaced)
        return tx

    def _on_append(self, tx: Transaction, displaced: Optional[str]) -> None:
        """Index-maintenance hook for subclasses (no-op here).  ``displaced``
        is the client's previous latest tx iff this append replaced it."""

    # -- queries ------------------------------------------------------------

    def tips(self) -> List[str]:
        """Transactions with in-degree 0 (unapproved)."""
        return sorted(self._tips)

    def tips_by_freshness(self, limit: Optional[int] = None) -> List[str]:
        """Tips ordered most-recent first (timestamp desc, id asc on ties).
        The reference ledger sorts on demand; :class:`BoundedDAGLedger`
        serves the same order from an incrementally maintained heap."""
        out = sorted(self._tips,
                     key=lambda t: (-self.nodes[t].timestamp, t))
        return out if limit is None else out[:limit]

    def latest_of(self, client_id: int) -> Optional[str]:
        """O(1): served from the per-client index maintained in _make_tx."""
        entry = self._latest.get(client_id)
        return entry[0] if entry is not None else None

    def head_seq(self) -> int:
        """Append seq of the most recent transaction (-1 before genesis).
        Monotone across pruning — this is the ledger-position clock that
        serving staleness (frontier-to-replica lag) is measured against:
        unlike wall/sim time it advances exactly once per publish, so lag
        counters are deterministic event counts."""
        return self._counter - 1

    def reachable_tips(self, start_node: Optional[str],
                       within: Optional[Iterable[str]] = None
                       ) -> Tuple[List[str], List[str]]:
        """Paper Alg. 1: BFS from the client's latest node over approval
        children; returns (ReachableTips, UnreachableTips).  ``within``
        restricts the split to a candidate subset of the tips (the
        index-backed selection path passes its freshness-capped candidates
        so large populations never pay an all-tips scan per query)."""
        if within is None:
            all_tips = set(self._tips)
        else:
            all_tips = {t for t in within if t in self._tips}
        if start_node is None or not self._start_known(start_node):
            return [], sorted(all_tips)
        if self.is_pruned(start_node):
            # confirmed == every current tip transitively approves it, and
            # confirmation is monotone (new transactions approve existing
            # tips), so a pruned start reaches the whole tip set
            return sorted(all_tips), []
        reachable = self._reach_from(start_node, all_tips)
        return sorted(reachable), sorted(all_tips - reachable)

    def _start_known(self, tx_id: str) -> bool:
        return tx_id in self.nodes or self.is_pruned(tx_id)

    def _reach_from(self, start_node: str, all_tips: set) -> set:
        visited = {start_node}
        q = deque([start_node])
        reachable = set()
        while q:
            node = q.popleft()
            if node in all_tips:
                reachable.add(node)
            for ch in self.children[node]:
                if ch not in visited:
                    visited.add(ch)
                    q.append(ch)
        return reachable

    def ancestors(self, tx_id: str, max_depth: Optional[int] = None):
        """Walk parent links over the LIVE region (used by verification
        paths); stops at the pruned boundary on a bounded ledger."""
        out, depth = [], 0
        frontier = [p for p in self.get_tx(tx_id).parents if self.has_tx(p)]
        seen = set(frontier)
        while frontier and (max_depth is None or depth < max_depth):
            out.extend(frontier)
            nxt = []
            for f in frontier:
                for p in self.nodes[f].parents:
                    if p not in seen and p in self.nodes:
                        seen.add(p)
                        nxt.append(p)
            frontier = nxt
            depth += 1
        return out

    def get_tx(self, tx_id: str) -> Transaction:
        return self.nodes[tx_id]

    def has_tx(self, tx_id: str) -> bool:
        return tx_id in self.nodes

    def is_pruned(self, tx_id: str) -> bool:
        return False

    def hash_of(self, tx_id: str) -> str:
        """Eq. 7 hash of a live (or, on a bounded ledger, pruned) tx."""
        return self.nodes[tx_id].tx_hash

    def transactions(self) -> Iterator[Transaction]:
        """Live transactions in append order."""
        return iter(self.nodes.values())

    @property
    def checkpoints(self) -> Sequence[CheckpointRecord]:
        return ()

    def __len__(self):
        return len(self.nodes)


class _ReachSummary:
    """Incremental reachability state for one start transaction.

    ``visited`` is the known descendant set of ``start`` (including it);
    ``cursor`` is the last append seq folded in.  Because appends only ever
    ADD descendants, a query needs to process just the transactions
    appended since ``cursor`` — O(new appends), not O(live region).
    """

    __slots__ = ("start", "visited", "cursor")

    def __init__(self, start: str, seq: int):
        self.start = start
        self.visited = {start}
        self.cursor = seq


class BoundedDAGLedger(DAGLedger):
    """DAG ledger with a bounded consensus frontier (see module docstring).

    ``checkpoint_interval`` > 0 folds confirmed ancestry automatically every
    that many appends; ``checkpoint()`` may also be driven externally (the
    coordinator hooks it onto the simulated clock).  ``evict_fn`` receives
    each pruned transaction so the caller can drop its ModelStore entry.

    Invariant maintained by pruning: the pruned set is ancestor-closed
    (parents of a pruned tx are pruned), so a live transaction never has a
    pruned child and downward BFS over live nodes is exact for live starts.
    """

    def __init__(self, checkpoint_interval: int = 0,
                 evict_fn: Optional[Callable[[Transaction], None]] = None,
                 max_summaries: int = 65536,
                 summary_cap: int = 65536):
        super().__init__()
        self.checkpoint_interval = int(checkpoint_interval)
        self.evict_fn = evict_fn
        self._pruned_hashes: Dict[str, str] = {}
        self._checkpoints: List[CheckpointRecord] = []
        self._appends_since_ckpt = 0
        # freshness-ordered tip index: lazy-deletion heap of
        # (-timestamp, tx_id); stale entries (no longer tips) are skipped
        # on query and swept wholesale at checkpoint time
        self._tip_heap: List[Tuple[float, str]] = []
        # per-start incremental reachability summaries, keyed by start tx.
        # One summary per client's latest transaction; bounded in count
        # (max_summaries, FIFO eviction) and per-summary size (summary_cap,
        # overflow falls back to frontier-bounded BFS).
        self._reach: Dict[str, _ReachSummary] = {}
        self.max_summaries = max_summaries
        self.summary_cap = summary_cap
        # seq-ordered log of live transactions for summary catch-up;
        # compacted to the live set at each checkpoint
        self._log: List[Transaction] = []
        self._log_seqs: List[int] = []
        # deterministic work counters (perf-gate instrumentation)
        self.stat_reach_processed = 0     # log entries folded into summaries
        self.stat_reach_bfs = 0           # nodes visited by BFS fallbacks
        self.stat_tip_heap_pops = 0       # heap entries popped (incl. stale)

    # -- append-side index maintenance --------------------------------------

    def _parent_known(self, tx_id: str) -> bool:
        # a parent selected as a tip may be confirmed+pruned before its
        # approver publishes (async publish lag); its Eq. 7 hash survives
        # in the retained-hash map, so the approval stays verifiable
        return tx_id in self.nodes or tx_id in self._pruned_hashes

    def hash_of(self, tx_id: str) -> str:
        tx = self.nodes.get(tx_id)
        if tx is not None:
            return tx.tx_hash
        return self._pruned_hashes[tx_id]

    def is_pruned(self, tx_id: str) -> bool:
        return tx_id in self._pruned_hashes

    def _on_append(self, tx: Transaction, displaced: Optional[str]) -> None:
        heapq.heappush(self._tip_heap, (-tx.timestamp, tx.tx_id))
        self._log.append(tx)
        self._log_seqs.append(tx.seq)
        # a client's reachability start moves to its new transaction: the
        # old summary can never be queried again
        if displaced is not None:
            self._reach.pop(displaced, None)
        if len(self._reach) < self.max_summaries:
            self._reach[tx.tx_id] = _ReachSummary(tx.tx_id, tx.seq)
        self._appends_since_ckpt += 1
        if (self.checkpoint_interval
                and self._appends_since_ckpt >= self.checkpoint_interval):
            self.checkpoint(now=tx.timestamp)

    # -- freshness-ordered tip index ----------------------------------------

    def tips_by_freshness(self, limit: Optional[int] = None) -> List[str]:
        if limit is None or limit >= len(self._tips):
            return super().tips_by_freshness(limit)
        out: List[str] = []
        kept: List[Tuple[float, str]] = []
        heap = self._tip_heap
        while heap and len(out) < limit:
            entry = heapq.heappop(heap)
            self.stat_tip_heap_pops += 1
            if entry[1] in self._tips:
                out.append(entry[1])
                kept.append(entry)
        for entry in kept:                 # tips stay in the index
            heapq.heappush(heap, entry)
        return out

    # -- index-backed reachability ------------------------------------------

    def _reach_from(self, start_node: str, all_tips: set) -> set:
        summary = self._reach.get(start_node)
        if summary is None:
            self.stat_reach_bfs += 1
            visited = super()._reach_from(start_node, all_tips)
            self.stat_reach_bfs += len(visited)
            return visited
        if summary.cursor < self._counter - 1:
            lo = self._bisect_log(summary.cursor)
            for tx in self._log[lo:]:
                if tx.tx_id in summary.visited:
                    continue
                for p in tx.parents:
                    if p in summary.visited:
                        summary.visited.add(tx.tx_id)
                        break
                self.stat_reach_processed += 1
            summary.cursor = self._counter - 1
        if len(summary.visited) > self.summary_cap:
            self._reach.pop(start_node, None)
        return {t for t in all_tips if t in summary.visited}

    def _bisect_log(self, cursor: int) -> int:
        import bisect
        return bisect.bisect_right(self._log_seqs, cursor)

    # -- checkpoint + prune --------------------------------------------------

    def confirmed(self) -> set:
        """Transactions every current tip transitively approves (proper
        common ancestors of the tip set).

        One reverse-topological pass over the live region with per-node
        reached-tip bitmasks: children always have a larger append seq than
        their parents, so processing live transactions in descending seq
        order makes ``mask(n) = own_bit | OR(mask(children))`` exact — n is
        confirmed iff its mask covers every tip.  O(live * avg_out_degree)
        bigint ORs, vs the O(|tips| * live) per-tip ancestor walks this
        replaced (which dominated checkpoint cost at 10^5 clients).
        """
        tips = sorted(self._tips)
        if not tips:
            return set()
        bit = {t: 1 << i for i, t in enumerate(tips)}
        full = (1 << len(tips)) - 1
        mask: Dict[str, int] = {}
        out = set()
        for tx in sorted(self.nodes.values(), key=lambda x: -x.seq):
            m = bit.get(tx.tx_id, 0)
            for ch in self.children[tx.tx_id]:
                m |= mask[ch]
            mask[tx.tx_id] = m
            if m == full and tx.tx_id not in bit:
                out.add(tx.tx_id)
        return out

    def maybe_checkpoint(self, now: float = 0.0,
                         min_appends: int = 1) -> Optional[CheckpointRecord]:
        """Checkpoint if at least ``min_appends`` landed since the last one
        (the coordinator's simulated-clock cadence hook)."""
        if self._appends_since_ckpt < min_appends:
            return None
        return self.checkpoint(now)

    def checkpoint(self, now: float = 0.0) -> Optional[CheckpointRecord]:
        """Fold the currently confirmed region into a checkpoint record and
        evict its bodies.  Returns the record, or None if nothing confirmed.
        """
        self._appends_since_ckpt = 0
        confirmed = self.confirmed()
        if not confirmed:
            return None
        leaves = [(t, self.nodes[t].tx_hash) for t in confirmed]
        prev_root = (self._checkpoints[-1].root if self._checkpoints
                     else GENESIS_ROOT)
        rec = CheckpointRecord(
            ckpt_id=f"ckpt{len(self._checkpoints):06d}",
            seq=len(self._checkpoints), created_at=float(now),
            n_pruned=len(confirmed),
            root=checkpoint_root(prev_root, leaves), prev_root=prev_root,
            leaf_ids=tuple(sorted(confirmed)))
        self._checkpoints.append(rec)
        for t in confirmed:
            tx = self.nodes.pop(t)
            self.children.pop(t, None)
            self._pruned_hashes[t] = tx.tx_hash
            self._reach.pop(t, None)
            if self.evict_fn is not None:
                self.evict_fn(tx)
        # compact the indexes to the live set: summary catch-up may skip
        # pruned entries entirely (a confirmed tx is never a descendant of
        # a live, unconfirmed start — see DESIGN.md)
        self._log = [tx for tx in self._log if tx.tx_id in self.nodes]
        self._log_seqs = [tx.seq for tx in self._log]
        self._tip_heap = [e for e in self._tip_heap if e[1] in self._tips]
        heapq.heapify(self._tip_heap)
        return rec

    @property
    def checkpoints(self) -> Sequence[CheckpointRecord]:
        return tuple(self._checkpoints)

    @property
    def n_pruned(self) -> int:
        return len(self._pruned_hashes)

    # test/audit access: the retained Eq. 7 hash of one pruned transaction
    def pruned_hash(self, tx_id: str) -> str:
        return self._pruned_hashes[tx_id]

    def _tamper_pruned_hash(self, tx_id: str, value: str) -> None:
        """Test hook: corrupt a retained hash (simulated checkpoint tamper)."""
        self._pruned_hashes[tx_id] = value
