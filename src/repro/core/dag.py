"""The DAG ledger (IOTA-style tangle) that DAG-AFL coordinates over.

Transactions carry ONLY metadata (paper §III-A: ``<ClientId, Signature,
ModelAccuracy, CurrentEpoch, ValidationNodeId>``); model weights travel peer
to peer through :class:`ModelStore`.  Tips are transactions with in-degree 0
(no later transaction approves them).  Each new transaction approves
``n_parents`` tips (2 in the paper).

Reachability (paper Alg. 1): BFS over *approval children* starting from the
client's own latest transaction — a tip is *reachable* iff it (directly or
transitively) approved the client's node, i.e. it has integrated the client's
previous aggregate.
"""
from __future__ import annotations

import hashlib
import json
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class TxMetadata:
    """Exactly the tuple the paper puts on chain (§III-B end)."""

    client_id: int
    signature: Tuple[float, ...]       # feature signature vector (Eq. 3-4)
    model_accuracy: float
    current_epoch: int                 # trainer's global iteration epoch
    validation_node_id: int

    def digest(self) -> str:
        payload = json.dumps({
            "client_id": self.client_id,
            "signature": [round(float(s), 8) for s in self.signature],
            "model_accuracy": round(float(self.model_accuracy), 8),
            "current_epoch": int(self.current_epoch),
            "validation_node_id": int(self.validation_node_id),
        }, sort_keys=True)
        return hashlib.sha256(payload.encode()).hexdigest()


@dataclass
class Transaction:
    tx_id: str
    metadata: TxMetadata
    parents: Tuple[str, ...]           # approved tips (empty for genesis)
    timestamp: float                   # simulated publish time
    tx_hash: str = ""                  # Eq. 7: H(H1 | H2 | hash(metadata))
    model_ref: str = ""                # ModelStore key (P2P pointer)


def compute_tx_hash(parent_hashes: Sequence[str], metadata: TxMetadata) -> str:
    """Eq. 7: block header = parent hashes, body = metadata digest."""
    h = hashlib.sha256()
    for ph in parent_hashes:
        h.update(ph.encode())
    h.update(metadata.digest().encode())
    return h.hexdigest()


class ModelStore:
    """P2P weight transport stand-in: tx_id -> model pytree.

    On a pod, 'peers' are mesh slices and the transfer is device-to-device;
    here it is an in-memory map so the DAG provably never carries weights.
    """

    def __init__(self):
        self._store: Dict[str, object] = {}
        self.bytes_transferred = 0

    def put(self, key: str, model) -> str:
        self._store[key] = model
        return key

    def get(self, key: str):
        import jax
        model = self._store[key]
        self.bytes_transferred += sum(
            a.size * a.dtype.itemsize for a in jax.tree_util.tree_leaves(model)
            if hasattr(a, "size"))
        return model

    def evict(self, key: str):
        self._store.pop(key, None)

    def __contains__(self, key):
        return key in self._store

    def __len__(self):
        return len(self._store)


class DAGLedger:
    """Append-only DAG of transactions with tip tracking."""

    def __init__(self):
        self.nodes: Dict[str, Transaction] = {}
        self.children: Dict[str, List[str]] = {}
        self._tips: set = set()
        self.genesis_id: Optional[str] = None
        self._counter = 0
        # per-client latest-transaction index: ``latest_of`` sits on the
        # coordinator's hot path (once per round per client plus the final
        # sweep), so an O(ledger) scan per call turns quadratic — keep it
        # O(1) by updating on append
        self._latest: Dict[int, Transaction] = {}

    # -- construction -------------------------------------------------------

    def add_genesis(self, metadata: TxMetadata, timestamp: float = 0.0,
                    model_ref: str = "") -> Transaction:
        assert self.genesis_id is None, "genesis already exists"
        tx = self._make_tx(metadata, (), timestamp, model_ref)
        self.genesis_id = tx.tx_id
        return tx

    def add_transaction(self, metadata: TxMetadata, parents: Sequence[str],
                        timestamp: float, model_ref: str = "") -> Transaction:
        for p in parents:
            if p not in self.nodes:
                raise KeyError(f"unknown parent {p}")
        return self._make_tx(metadata, tuple(parents), timestamp, model_ref)

    def _make_tx(self, metadata, parents, timestamp, model_ref) -> Transaction:
        tx_id = f"tx{self._counter:06d}"
        self._counter += 1
        parent_hashes = [self.nodes[p].tx_hash for p in parents]
        tx = Transaction(tx_id=tx_id, metadata=metadata, parents=parents,
                         timestamp=timestamp,
                         tx_hash=compute_tx_hash(parent_hashes, metadata),
                         model_ref=model_ref or tx_id)
        self.nodes[tx_id] = tx
        self.children[tx_id] = []
        for p in parents:
            self.children[p].append(tx_id)
            self._tips.discard(p)
        self._tips.add(tx_id)
        # >= keeps the old full-scan tie-break: among equal timestamps the
        # latest-inserted transaction wins
        cur = self._latest.get(metadata.client_id)
        if cur is None or timestamp >= cur.timestamp:
            self._latest[metadata.client_id] = tx
        return tx

    # -- queries ------------------------------------------------------------

    def tips(self) -> List[str]:
        """Transactions with in-degree 0 (unapproved)."""
        return sorted(self._tips)

    def latest_of(self, client_id: int) -> Optional[str]:
        """O(1): served from the per-client index maintained in _make_tx."""
        tx = self._latest.get(client_id)
        return tx.tx_id if tx is not None else None

    def reachable_tips(self, start_node: Optional[str]
                       ) -> Tuple[List[str], List[str]]:
        """Paper Alg. 1: BFS from the client's latest node over approval
        children; returns (ReachableTips, UnreachableTips)."""
        all_tips = set(self._tips)
        if start_node is None or start_node not in self.nodes:
            return [], sorted(all_tips)
        visited = {start_node}
        q = deque([start_node])
        reachable = set()
        while q:
            node = q.popleft()
            if node in all_tips:
                reachable.add(node)
            for ch in self.children[node]:
                if ch not in visited:
                    visited.add(ch)
                    q.append(ch)
        return sorted(reachable), sorted(all_tips - reachable)

    def ancestors(self, tx_id: str, max_depth: Optional[int] = None):
        """Walk parent links (used by verification paths)."""
        out, depth = [], 0
        frontier = list(self.nodes[tx_id].parents)
        seen = set(frontier)
        while frontier and (max_depth is None or depth < max_depth):
            out.extend(frontier)
            nxt = []
            for f in frontier:
                for p in self.nodes[f].parents:
                    if p not in seen:
                        seen.add(p)
                        nxt.append(p)
            frontier = nxt
            depth += 1
        return out

    def __len__(self):
        return len(self.nodes)
