"""Feature signatures and the similarity 'smart contract' (paper Eq. 3-5).

``cosine_similarity_matrix`` is the jitted data-plane piece; the
:class:`SimilarityContract` mirrors the paper's on-chain contract that stores
a per-round client-similarity matrix for later queries.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def cosine_similarity_matrix(signatures: jnp.ndarray) -> jnp.ndarray:
    """signatures (n_clients, n_sig) -> (n_clients, n_clients) cosine sims."""
    s = signatures.astype(jnp.float32)
    norm = jnp.linalg.norm(s, axis=-1, keepdims=True)
    s = s / jnp.maximum(norm, 1e-12)
    return s @ s.T


@jax.jit
def cosine_similarity(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    denom = jnp.maximum(jnp.linalg.norm(a) * jnp.linalg.norm(b), 1e-12)
    return jnp.dot(a, b) / denom


class SimilarityContract:
    """Smart-contract stand-in: records similarity matrices per round and
    answers top-p most-similar queries (paper §III-B3)."""

    def __init__(self, n_clients: int):
        self.n_clients = n_clients
        self._rounds: Dict[int, np.ndarray] = {}
        self._latest_sig: Dict[int, np.ndarray] = {}

    def post_signature(self, client_id: int, signature) -> None:
        self._latest_sig[client_id] = np.asarray(signature, np.float32)

    def signatures_known(self) -> Sequence[int]:
        return sorted(self._latest_sig)

    def commit_round(self, round_idx: int) -> Optional[np.ndarray]:
        """Compute + store the similarity matrix from the latest signatures."""
        if len(self._latest_sig) < 2:
            return None
        ids = sorted(self._latest_sig)
        sigs = jnp.stack([jnp.asarray(self._latest_sig[i]) for i in ids])
        mat = np.asarray(cosine_similarity_matrix(sigs))
        full = np.full((self.n_clients, self.n_clients), np.nan, np.float32)
        for a, ia in enumerate(ids):
            for b, ib in enumerate(ids):
                full[ia, ib] = mat[a, b]
        self._rounds[round_idx] = full
        return full

    def query(self, round_idx: int, client_id: int) -> Optional[np.ndarray]:
        """Similarity row for ``client_id`` at the latest round <= round_idx."""
        rounds = [r for r in self._rounds if r <= round_idx]
        if not rounds:
            return None
        return self._rounds[max(rounds)][client_id]

    def most_similar(self, round_idx: int, client_id: int,
                     candidates: Sequence[int], p: int) -> Sequence[int]:
        row = self.query(round_idx, client_id)
        if row is None:
            return list(candidates)[:p]
        scored = sorted(candidates,
                        key=lambda c: -(row[c] if not np.isnan(row[c]) else -2.0))
        return scored[:p]
