"""Event-driven asynchronous FL simulator.

The container has no cluster and no wall-clock realism, so *simulated time*
is the measurement substrate for the paper's Table III / Fig. 3 claims:
every client has a heterogeneity profile (compute speed, link bandwidth,
per-message latency); training, validation and transfer costs advance a
simulated clock through an event heap.  All algorithms (DAG-AFL and the 8
baselines) run on this same scheduler, so relative timings are comparable.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class ClientProfile:
    client_id: int
    speed: float            # local-step time multiplier (1.0 = reference)
    bandwidth: float        # bytes / second for model transfer
    latency: float          # per-message fixed latency (seconds)


def make_profiles(n_clients: int, heterogeneity: float = 0.6,
                  seed: int = 0) -> List[ClientProfile]:
    """Lognormal speed / bandwidth draws; ``heterogeneity`` is the sigma."""
    rng = np.random.default_rng(seed)
    profiles = []
    for c in range(n_clients):
        speed = float(np.exp(rng.normal(0.0, heterogeneity)))
        bw = float(50e6 * np.exp(rng.normal(0.0, heterogeneity)))
        lat = float(np.abs(rng.normal(0.05, 0.02)) + 0.01)
        profiles.append(ClientProfile(c, speed, bw, lat))
    return profiles


@dataclass
class CostModel:
    """Simulated cost of the primitive operations (reference-client seconds)."""

    local_epoch: float = 6.0        # one local epoch of training
    eval_batch: float = 0.4         # validate one model on the local val set
    signature: float = 0.15         # extract a feature signature
    chain_op: float = 0.02          # ledger append / metadata query
    model_bytes: int = 4_000_000    # serialized model size (metadata ~ 1e3)
    metadata_bytes: int = 1_024

    def train_time(self, p: ClientProfile, epochs: int, rng) -> float:
        jitter = float(np.exp(rng.normal(0.0, 0.1)))
        return self.local_epoch * epochs * p.speed * jitter

    def transfer_time(self, p: ClientProfile, nbytes: int) -> float:
        return p.latency + nbytes / p.bandwidth

    def eval_time(self, p: ClientProfile, n_models: int) -> float:
        return self.eval_batch * n_models * p.speed


class EventLoop:
    """Min-heap of (time, seq, callback)."""

    def __init__(self):
        self._heap: List[Tuple[float, int, Callable[[], None]]] = []
        self._seq = 0
        self.now = 0.0
        # negative-delay schedules clamped to "now" (observable: the cohort
        # path legitimately produces these when a round completes before its
        # window flushes — the publish lands at the flush time)
        self.clamped = 0
        # pending recurring-stream ticks (schedule_every / schedule_stream).
        # Streams re-arm only while NON-stream events remain — counting the
        # ticks themselves would let two concurrent cadences (e.g. ledger
        # checkpointing + serving publisher + query stream) keep a drained
        # simulation alive forever by each seeing the other's next tick.
        self._maintenance = 0

    def schedule(self, delay: float, fn: Callable[[], None]) -> None:
        if delay < 0.0:
            self.clamped += 1
            delay = 0.0
        t = self.now + delay
        # the clamp must hold: simulated time never runs backwards, and a
        # NaN delay would silently corrupt the heap order
        assert t >= self.now, f"schedule produced past/NaN time {t!r}"
        heapq.heappush(self._heap, (t, self._seq, fn))
        self._seq += 1

    def schedule_every(self, interval: float, fn: Callable[[], None],
                       stop: Optional[Callable[[], bool]] = None) -> None:
        """Recurring hook: run ``fn`` every ``interval`` simulated seconds
        until ``stop()`` returns True (checked before each firing) or no
        OTHER events remain — a maintenance cadence (e.g. ledger
        checkpointing) must never keep an otherwise-drained simulation
        alive.  Rides the simulated clock, not event counts."""
        if interval <= 0.0:
            raise ValueError(f"interval must be > 0, got {interval!r}")
        self.schedule_stream(lambda: interval, fn, stop=stop)

    def schedule_stream(self, next_delay: Callable[[], float],
                        fn: Callable[[], None],
                        stop: Optional[Callable[[], bool]] = None) -> None:
        """Generalized recurring hook: like :meth:`schedule_every`, but the
        gap before each firing is drawn from ``next_delay()`` (e.g. a seeded
        Poisson arrival process for a serving query stream).  Draws happen
        one at a time on the event loop, so a seeded generator stays
        deterministic.  Drain rule: the stream re-arms only while events
        OTHER than recurring-stream ticks remain pending, so any number of
        concurrent cadences wind down together once real work is done —
        two streams must not keep each other (and a finished simulation)
        alive by mutually observing the other's next tick."""

        def tick() -> None:
            self._maintenance -= 1
            if stop is not None and stop():
                return
            fn()
            if len(self._heap) > self._maintenance:   # real work pending
                self._maintenance += 1
                self.schedule(float(next_delay()), tick)

        self._maintenance += 1
        self.schedule(float(next_delay()), tick)

    def run(self, until: Optional[float] = None,
            stop: Optional[Callable[[], bool]] = None,
            max_events: int = 1_000_000) -> None:
        events = 0
        while self._heap and events < max_events:
            t, _, fn = heapq.heappop(self._heap)
            if until is not None and t > until:
                self.now = until
                return
            self.now = t
            fn()
            events += 1
            if stop is not None and stop():
                return


class CohortWindow:
    """Batches concurrent round-start requests for vectorized dispatch.

    Requests ``add()``-ed within ``window`` simulated seconds of the first
    one share a batch.  The batch flushes when it reaches ``capacity`` or,
    via a close-timer armed when the window opens, at window-end — so a
    request's dispatch (and therefore its tip staleness in DAG-AFL) is
    never deferred past ``window`` seconds, regardless of what other
    events pop in between.  ``flush_fn`` receives ``[(item, start_time)]``;
    ``stop_fn`` suppresses the timer flush after the simulation has
    converged (a mid-window stop leaves ``pending`` for the owner to
    discard).
    """

    def __init__(self, loop: EventLoop, capacity: int, window: float,
                 flush_fn: Callable, stop_fn: Callable[[], bool]):
        self.loop = loop
        self.capacity = capacity
        self.window = window
        self.flush_fn = flush_fn
        self.stop_fn = stop_fn
        self.pending: List = []
        self._gen = 0

    def add(self, item) -> None:
        self.pending.append((item, self.loop.now))
        if len(self.pending) == 1:           # window opener: arm the closer
            gen = self._gen
            self.loop.schedule(self.window, lambda: self._close(gen))
        if len(self.pending) >= self.capacity:
            self.flush()

    def _close(self, gen: int) -> None:
        if gen == self._gen and self.pending and not self.stop_fn():
            self.flush()

    def flush(self) -> None:
        batch, self.pending = self.pending, []
        self._gen += 1
        if batch:
            self.flush_fn(batch)


@dataclass
class ConvergenceTracker:
    """Validation-accuracy early stopping (paper: patience 5 on val avg)."""

    target_accuracy: Optional[float] = None
    patience: int = 5
    min_delta: float = 1e-4
    min_updates: int = 0          # never converge before this many updates
    history: List[Tuple[float, float]] = field(default_factory=list)
    best: float = -1.0
    stale_rounds: int = 0
    converged_at: Optional[float] = None

    def update(self, sim_time: float, val_acc: float) -> bool:
        self.history.append((sim_time, float(val_acc)))
        if val_acc > self.best + self.min_delta:
            self.best = float(val_acc)
            self.stale_rounds = 0
        else:
            self.stale_rounds += 1
        hit_target = (self.target_accuracy is not None
                      and val_acc >= self.target_accuracy)
        if (hit_target or self.stale_rounds >= self.patience) \
                and self.converged_at is None \
                and len(self.history) >= self.min_updates:
            self.converged_at = sim_time
        return self.converged_at is not None

    @property
    def done(self) -> bool:
        return self.converged_at is not None


@dataclass
class RunResult:
    name: str
    final_accuracy: float
    best_accuracy: float
    sim_time: float
    rounds: int
    history: List[Tuple[float, float]]
    extra: Dict = field(default_factory=dict)

    def row(self) -> str:
        return (f"{self.name:14s} acc={self.final_accuracy*100:6.2f}% "
                f"best={self.best_accuracy*100:6.2f}% "
                f"time={self.sim_time:8.1f}s rounds={self.rounds}")
