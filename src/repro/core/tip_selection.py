"""Tip selection (paper §III-B): freshness + reachability + model accuracy.

The selection pipeline for client ``c`` choosing N tips:

  1. Alg. 1 BFS from c's latest transaction splits current tips into
     reachable / unreachable.
  2. N1 = round(lambda*N) reachable tips: validated directly on c's local
     validation set, ranked by ``freshness * accuracy``.
  3. N2 = N - N1 unreachable tips: the similarity contract pre-filters the
     p most signature-similar candidates (Eq. 5), only those are validated,
     and the top N2 by accuracy are kept — this is the paper's trick for
     avoiding accuracy evaluation of every tip.
  4. Shortfalls on either side spill over to the other; if the DAG has
     fewer than N tips, all of them are selected.

Eq. 2 as printed increases with dwell time, contradicting the paper's prose;
``literal_eq2=True`` reproduces the printed formula, the default implements
the prose (see DESIGN.md).

API
---
:class:`TipSelector` is the selection engine: construct it once per
(ledger, contract, config) and call :meth:`TipSelector.select` with a
:class:`TipSelectionRequest` and a :class:`TipEvaluator`.  The evaluator
protocol unifies the old ``evaluate_fn`` / ``evaluate_batch`` callable pair:
``evaluate(tx_id) -> accuracy`` validates one tip, ``warm(tx_ids)`` lets a
vectorized backend validate a whole candidate set in one batched dispatch
(the per-tip ``evaluate`` then serves from its cache).

``select_tips(...)`` remains as a thin back-compat wrapper over the same
engine.  .. deprecated:: its 9-positional-argument signature is frozen;
new call sites should construct a :class:`TipSelector` — the wrapper will
be removed once external callers have migrated.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence

try:
    from typing import Protocol, runtime_checkable
except ImportError:  # pragma: no cover
    Protocol = object

    def runtime_checkable(cls):
        return cls

from repro.core.dag import LedgerView
from repro.core.signature import SimilarityContract


@dataclass(frozen=True)
class TipSelectionConfig:
    n_select: int = 2            # N (paper default: two tips per transaction)
    lam: float = 0.5             # lambda: reachable fraction
    alpha: float = 0.1           # freshness dwell-time decay factor
    p_similar: int = 4           # p: candidates pre-filtered by similarity
    literal_eq2: bool = False    # reproduce the paper's printed Eq. 2
    use_freshness: bool = True
    use_similarity: bool = True  # ablation: disable signature pre-filter
    # at large populations, consider only this many FRESHEST tips as
    # candidates (served from the ledger's freshness-ordered tip index)
    # instead of scanning the whole tip set; None = consider every tip
    max_tip_candidates: Optional[int] = None


def tipc(cur_epoch: int, tip_epoch: int) -> float:
    """Eq. 1: epoch-gap factor, exp(-|T_cur - T_tip|) in (0, 1]."""
    return math.exp(-abs(cur_epoch - tip_epoch))


def freshness(cur_epoch: int, tip_epoch: int, now: float, tip_time: float,
              alpha: float, literal_eq2: bool = False) -> float:
    """Eq. 2 (prose semantics by default; see module docstring)."""
    t = tipc(cur_epoch, tip_epoch)
    dwell = max(now - tip_time, 0.0)
    decay = 1.0 / (1.0 + alpha * dwell)
    if literal_eq2:
        return 1.0 / max(t * decay, 1e-12)
    return t * decay


@dataclass
class TipScore:
    tx_id: str
    reachable: bool
    freshness: float
    accuracy: float
    score: float


@dataclass(frozen=True)
class TipSelectionRequest:
    """One client's selection query: who is asking, and when."""

    client_id: int
    cur_epoch: int
    now: float
    round_idx: int = 0


@runtime_checkable
class TipEvaluator(Protocol):
    """Validates candidate tips on the requesting client's local data.

    ``evaluate`` is the expensive per-tip step the similarity filter
    minimises; ``warm`` receives each candidate set before the per-tip
    loop so a vectorized backend can validate the whole set in one batched
    dispatch and serve ``evaluate`` from its cache — the set of evaluated
    tips (and therefore the simulated validation cost) is identical either
    way.
    """

    def evaluate(self, tx_id: str) -> float: ...

    def warm(self, tx_ids: Sequence[str]) -> None: ...


class FnTipEvaluator:
    """Adapter from the legacy ``evaluate_fn`` / ``evaluate_batch`` callable
    pair to the :class:`TipEvaluator` protocol."""

    def __init__(self, evaluate_fn: Callable[[str], float],
                 evaluate_batch: Optional[
                     Callable[[Sequence[str]], None]] = None):
        self._fn = evaluate_fn
        self._batch = evaluate_batch

    def evaluate(self, tx_id: str) -> float:
        return self._fn(tx_id)

    def warm(self, tx_ids: Sequence[str]) -> None:
        if self._batch is not None and tx_ids:
            self._batch(tx_ids)


class TipSelector:
    """The paper's §III-B selection engine over a :class:`LedgerView`."""

    def __init__(self, ledger: LedgerView,
                 contract: Optional[SimilarityContract],
                 cfg: TipSelectionConfig):
        self.ledger = ledger
        self.contract = contract
        self.cfg = cfg

    # -- candidate set -------------------------------------------------------

    def _candidate_tips(self) -> List[str]:
        cfg = self.cfg
        if cfg.max_tip_candidates is None:
            return self.ledger.tips()
        # index-backed: only the k freshest tips are considered, served
        # from the ledger's freshness-ordered tip index (sub-linear in the
        # tip count for a BoundedDAGLedger); re-sorted by id so downstream
        # iteration order matches the unrestricted path
        return sorted(self.ledger.tips_by_freshness(cfg.max_tip_candidates))

    def _fresh(self, req: TipSelectionRequest, tx_id: str) -> float:
        cfg = self.cfg
        if not cfg.use_freshness:
            return 1.0
        tx = self.ledger.get_tx(tx_id)
        return freshness(req.cur_epoch, tx.metadata.current_epoch, req.now,
                         tx.timestamp, cfg.alpha, cfg.literal_eq2)

    # -- selection -----------------------------------------------------------

    def select(self, req: TipSelectionRequest,
               evaluator: TipEvaluator) -> List[TipScore]:
        """Returns the selected tips with their diagnostic scores."""
        ledger, cfg = self.ledger, self.cfg
        all_tips = self._candidate_tips()
        # a client never selects its OWN transactions: the paper's reachable
        # set (Fig. 2) is peers who integrated your aggregate, and
        # P2P-fetching your own model is a no-op that silos training
        # (observed: self-selection via the accuracy rank costs ~10 accuracy
        # points under beta=0.1)
        tips = [t for t in all_tips
                if ledger.get_tx(t).metadata.client_id != req.client_id]
        if not tips:
            tips = all_tips
        n = min(cfg.n_select, len(tips))
        if n == 0:
            return []

        start = ledger.latest_of(req.client_id)
        # the split is restricted to the candidate set up front, so a
        # freshness-capped selection never pays an all-tips scan
        reachable, unreachable = ledger.reachable_tips(start, within=tips)

        fresh = lambda t: self._fresh(req, t)  # noqa: E731

        n1 = min(round(cfg.lam * n), len(reachable))
        n2 = min(n - n1, len(unreachable))
        n1 = min(n - n2, len(reachable))          # spill shortfall back

        chosen: List[TipScore] = []

        # -- reachable side: direct validation, freshness-weighted rank ----
        evaluator.warm(reachable)
        scored_r = []
        for t in reachable:
            acc = evaluator.evaluate(t)
            f = fresh(t)
            scored_r.append(TipScore(t, True, f, acc, f * acc))
        scored_r.sort(key=lambda s: -s.score)
        chosen.extend(scored_r[:n1])

        # -- unreachable side: similarity pre-filter, then validate --------
        if n2 > 0:
            cands = list(unreachable)
            if cfg.use_similarity and self.contract is not None:
                owners = {t: ledger.get_tx(t).metadata.client_id
                          for t in cands}
                p = max(cfg.p_similar, n2)
                owner_rank = self.contract.most_similar(
                    req.round_idx, req.client_id,
                    sorted(set(owners.values())), p)
                rank_pos = {cid: i for i, cid in enumerate(owner_rank)}
                cands.sort(
                    key=lambda t: rank_pos.get(owners[t], len(rank_pos)))
                cands = cands[:p]
            evaluator.warm(cands)
            scored_u = []
            for t in cands:
                acc = evaluator.evaluate(t)
                f = fresh(t)
                scored_u.append(TipScore(t, False, f, acc, f * acc))
            scored_u.sort(key=lambda s: -s.accuracy)
            chosen.extend(scored_u[:n2])

        # -- top-up if still short (tiny DAGs) -----------------------------
        if len(chosen) < n:
            chosen.extend(top_up_tips(
                chosen, tips, reachable, fresh, evaluator.evaluate,
                lambda ids: evaluator.warm(ids), n))
        return chosen


def select_tips(ledger: LedgerView,
                client_id: int,
                cur_epoch: int,
                now: float,
                evaluate_fn: Callable[[str], float],
                contract: Optional[SimilarityContract],
                cfg: TipSelectionConfig,
                round_idx: int = 0,
                evaluate_batch: Optional[
                    Callable[[Sequence[str]], None]] = None) -> List[TipScore]:
    """Back-compat wrapper over :class:`TipSelector`.

    .. deprecated::
        Construct a :class:`TipSelector` and call :meth:`TipSelector.select`
        with a :class:`TipSelectionRequest` and a :class:`TipEvaluator`
        instead; this 9-argument signature is frozen and will be removed
        once external callers have migrated.
    """
    selector = TipSelector(ledger, contract, cfg)
    req = TipSelectionRequest(client_id=client_id, cur_epoch=cur_epoch,
                              now=now, round_idx=round_idx)
    return selector.select(req, FnTipEvaluator(evaluate_fn, evaluate_batch))


def top_up_tips(chosen: Sequence[TipScore], tips: Sequence[str],
                reachable: Sequence[str],
                fresh: Callable[[str], float],
                evaluate_fn: Callable[[str], float],
                evaluate_batch: Optional[Callable[[Sequence[str]], None]],
                n: int) -> List[TipScore]:
    """Fill a short selection from the not-yet-chosen tips.

    Ranks by the paper's ``freshness * accuracy`` score, exactly like the
    reachable side — ranking by freshness alone let stale-but-accurate
    garbage outrank good models.  The remainder set is batch-validated
    FIRST (when the caller has a vectorized backend), so the per-tip
    ``evaluate_fn`` serves from the warmed cache instead of paying one
    sequential dispatch per top-up tip, and freshness is computed once per
    candidate, not three times.
    """
    have = {c.tx_id for c in chosen}
    remaining = [t for t in tips if t not in have]
    if evaluate_batch is not None and remaining:
        evaluate_batch(remaining)
    reach_set = set(reachable)
    scored = []
    for t in remaining:
        f = fresh(t)                         # once per candidate
        acc = evaluate_fn(t)
        scored.append(TipScore(t, t in reach_set, f, acc, f * acc))
    scored.sort(key=lambda s: -s.score)
    return scored[: n - len(chosen)]
