"""Trustworthy verification of the DAG (paper §III-C, Eq. 7).

The task publisher holds the full DAG; trainers keep only *validation paths*
(the hash chain from a tip back to genesis).  Re-deriving every hash along a
stored path and comparing against the path's recorded values detects any
tampering of metadata or structure by the publisher.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.core.dag import DAGLedger, Transaction, compute_tx_hash


@dataclass(frozen=True)
class PathRecord:
    tx_id: str
    tx_hash: str
    parents: Tuple[str, ...]
    metadata_digest: str


@dataclass
class ValidationPath:
    """What a trainer stores: hash-chain records from a tip to genesis."""

    tip_id: str
    records: List[PathRecord]


def extract_path(ledger: DAGLedger, tip_id: str) -> ValidationPath:
    """Walk first-parent links from ``tip_id`` to genesis, recording hashes."""
    records = []
    cur: Optional[str] = tip_id
    while cur is not None:
        tx = ledger.nodes[cur]
        records.append(PathRecord(tx.tx_id, tx.tx_hash, tx.parents,
                                  tx.metadata.digest()))
        cur = tx.parents[0] if tx.parents else None
    return ValidationPath(tip_id=tip_id, records=records)


def verify_path(ledger: DAGLedger, path: ValidationPath) -> Tuple[bool, str]:
    """Re-derive each hash on the stored path from the publisher's current DAG
    state; any mismatch => tampering.  Returns (ok, reason)."""
    for rec in path.records:
        tx = ledger.nodes.get(rec.tx_id)
        if tx is None:
            return False, f"{rec.tx_id}: transaction missing from DAG"
        if tx.parents != rec.parents:
            return False, f"{rec.tx_id}: approval edges changed"
        if tx.metadata.digest() != rec.metadata_digest:
            return False, f"{rec.tx_id}: metadata digest mismatch"
        recomputed = compute_tx_hash(
            [ledger.nodes[p].tx_hash for p in tx.parents
             if p in ledger.nodes], tx.metadata)
        if recomputed != rec.tx_hash:
            return False, f"{rec.tx_id}: hash mismatch (Eq. 7 recompute)"
    return True, "ok"


def verify_full_dag(ledger: DAGLedger) -> Tuple[bool, str]:
    """Publisher-side audit: every stored hash must re-derive (Eq. 7)."""
    for tx in ledger.nodes.values():
        recomputed = compute_tx_hash(
            [ledger.nodes[p].tx_hash for p in tx.parents], tx.metadata)
        if recomputed != tx.tx_hash:
            return False, f"{tx.tx_id}: stored hash does not re-derive"
    return True, "ok"
