"""Trustworthy verification of the DAG (paper §III-C, Eq. 7).

The task publisher holds the full DAG; trainers keep only *validation paths*
(the hash chain from a tip back to genesis).  Re-deriving every hash along a
stored path and comparing against the path's recorded values detects any
tampering of metadata or structure by the publisher.

On a :class:`~repro.core.dag.BoundedDAGLedger` the pruned region's bodies
are gone, but each pruned transaction's Eq. 7 hash survives in the
checkpoint rollup, so a stored path record that crosses the pruned boundary
is still checkable: its hash must re-derive from the record's own parents +
metadata digest AND match the retained checkpoint hash.  ``verify_full_dag``
additionally re-derives every checkpoint root so tampering with the
retained-hash map itself is caught.

:class:`IncrementalVerifier` is the publisher's steady-state audit: it
hash-checks only the transactions (and checkpoints) appended since the last
audit instead of re-walking all history.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.core.dag import (GENESIS_ROOT, LedgerView, checkpoint_root,
                            compute_tx_hash, compute_tx_hash_from_digest)


@dataclass(frozen=True)
class PathRecord:
    tx_id: str
    tx_hash: str
    parents: Tuple[str, ...]
    metadata_digest: str


@dataclass
class ValidationPath:
    """What a trainer stores: hash-chain records from a tip to genesis."""

    tip_id: str
    records: List[PathRecord]


def extract_path(ledger: LedgerView, tip_id: str) -> ValidationPath:
    """Walk first-parent links from ``tip_id``, recording hashes; ends at
    genesis or, on a bounded ledger, at the pruned (checkpoint) boundary."""
    records = []
    cur: Optional[str] = tip_id
    while cur is not None and ledger.has_tx(cur):
        tx = ledger.get_tx(cur)
        records.append(PathRecord(tx.tx_id, tx.tx_hash, tx.parents,
                                  tx.metadata.digest()))
        cur = tx.parents[0] if tx.parents else None
    return ValidationPath(tip_id=tip_id, records=records)


def _record_parent_hashes(ledger: LedgerView,
                          parents: Tuple[str, ...]) -> List[str]:
    """Hashes for a record's claimed parents, live or pruned; unknown
    parents are skipped (their absence surfaces as a hash mismatch)."""
    return [ledger.hash_of(p) for p in parents
            if ledger.has_tx(p) or ledger.is_pruned(p)]


def verify_path(ledger: LedgerView, path: ValidationPath) -> Tuple[bool, str]:
    """Re-derive each hash on the stored path from the publisher's current
    DAG state; any mismatch => tampering.  Returns (ok, reason)."""
    for rec in path.records:
        if ledger.has_tx(rec.tx_id):
            tx = ledger.get_tx(rec.tx_id)
            if tx.parents != rec.parents:
                return False, f"{rec.tx_id}: approval edges changed"
            if tx.metadata.digest() != rec.metadata_digest:
                return False, f"{rec.tx_id}: metadata digest mismatch"
            recomputed = compute_tx_hash(
                _record_parent_hashes(ledger, tx.parents), tx.metadata)
            if recomputed != rec.tx_hash:
                return False, f"{rec.tx_id}: hash mismatch (Eq. 7 recompute)"
        elif ledger.is_pruned(rec.tx_id):
            # body pruned: the record's own parents + digest must re-derive
            # its hash AND agree with the checkpoint-retained hash
            recomputed = compute_tx_hash_from_digest(
                _record_parent_hashes(ledger, rec.parents),
                rec.metadata_digest)
            if recomputed != rec.tx_hash:
                return False, (f"{rec.tx_id}: pruned-record hash mismatch "
                               f"(Eq. 7 recompute)")
            if ledger.hash_of(rec.tx_id) != rec.tx_hash:
                return False, (f"{rec.tx_id}: retained checkpoint hash "
                               f"mismatch")
        else:
            return False, f"{rec.tx_id}: transaction missing from DAG"
    return True, "ok"


def verify_checkpoints(ledger: LedgerView) -> Tuple[bool, str]:
    """Re-derive every checkpoint's merkle-style root from the retained
    leaf hashes; detects tampering of the pruned region's rollup."""
    prev_root = GENESIS_ROOT
    for rec in ledger.checkpoints:
        if rec.prev_root != prev_root:
            return False, f"{rec.ckpt_id}: checkpoint chain broken"
        try:
            leaves = [(t, ledger.hash_of(t)) for t in rec.leaf_ids]
        except KeyError as e:
            return False, f"{rec.ckpt_id}: retained hash missing for {e}"
        if checkpoint_root(prev_root, leaves) != rec.root:
            return False, f"{rec.ckpt_id}: checkpoint root does not re-derive"
        prev_root = rec.root
    return True, "ok"


def detect_tampered(ledger: LedgerView) -> List[str]:
    """Counting tamper sweep: re-derive Eq. 7 for EVERY live transaction
    and return all ids whose stored hash does not re-derive (sorted for
    determinism).  ``verify_full_dag`` stops at the first failure — the
    robustness benchmark gates on exact detection counts, so it needs the
    complete set.  Metadata tampering breaks only the victim's own hash
    (children committed to the parent's stored tx_hash, which the attacker
    left in place), so the sweep returns exactly the tampered set."""
    bad = []
    for tx in ledger.transactions():
        try:
            parent_hashes = [ledger.hash_of(p) for p in tx.parents]
        except KeyError:
            bad.append(tx.tx_id)
            continue
        if compute_tx_hash(parent_hashes, tx.metadata) != tx.tx_hash:
            bad.append(tx.tx_id)
    return sorted(bad)


def verify_full_dag(ledger: LedgerView) -> Tuple[bool, str]:
    """Publisher-side audit: every stored hash must re-derive (Eq. 7),
    live transactions against parent hashes (retained ones for pruned
    parents), plus every checkpoint root against its retained leaves."""
    for tx in ledger.transactions():
        try:
            parent_hashes = [ledger.hash_of(p) for p in tx.parents]
        except KeyError:
            return False, f"{tx.tx_id}: parent hash unavailable"
        if compute_tx_hash(parent_hashes, tx.metadata) != tx.tx_hash:
            return False, f"{tx.tx_id}: stored hash does not re-derive"
    return verify_checkpoints(ledger)


class IncrementalVerifier:
    """Audits only what changed since the last audit.

    ``audit()`` re-derives Eq. 7 for transactions with append seq beyond
    the last audited one and re-derives roots of checkpoints created since,
    so steady-state audit cost tracks the append rate, not total history.
    ``txs_checked`` / ``checkpoints_checked`` count cumulative work (the
    benchmark gates on them being ~O(appends), not O(history^2)).
    """

    def __init__(self, ledger: LedgerView):
        self.ledger = ledger
        self._last_seq = -1
        self._last_ckpt = 0
        self.txs_checked = 0
        self.checkpoints_checked = 0

    def audit(self) -> Tuple[bool, str]:
        led = self.ledger
        max_seq = self._last_seq
        for tx in led.transactions():
            if tx.seq <= self._last_seq:
                continue
            try:
                parent_hashes = [led.hash_of(p) for p in tx.parents]
            except KeyError:
                return False, f"{tx.tx_id}: parent hash unavailable"
            if compute_tx_hash(parent_hashes, tx.metadata) != tx.tx_hash:
                return False, f"{tx.tx_id}: stored hash does not re-derive"
            self.txs_checked += 1
            max_seq = max(max_seq, tx.seq)
        ckpts = led.checkpoints
        prev_root = (ckpts[self._last_ckpt - 1].root
                     if self._last_ckpt else GENESIS_ROOT)
        for rec in ckpts[self._last_ckpt:]:
            if rec.prev_root != prev_root:
                return False, f"{rec.ckpt_id}: checkpoint chain broken"
            try:
                leaves = [(t, led.hash_of(t)) for t in rec.leaf_ids]
            except KeyError as e:
                return False, f"{rec.ckpt_id}: retained hash missing for {e}"
            if checkpoint_root(prev_root, leaves) != rec.root:
                return False, (f"{rec.ckpt_id}: checkpoint root does not "
                               f"re-derive")
            prev_root = rec.root
            self.checkpoints_checked += 1
        self._last_ckpt = len(ckpts)
        self._last_seq = max_seq
        return True, "ok"
