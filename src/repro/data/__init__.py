from repro.data.partition import (label_distribution, partition_dirichlet,
                                  partition_iid)
from repro.data.synthetic import (Dataset, make_benchmark_dataset,
                                  make_image_dataset, make_lm_dataset,
                                  split_811)

__all__ = ["Dataset", "make_benchmark_dataset", "make_image_dataset",
           "make_lm_dataset", "split_811", "partition_iid",
           "partition_dirichlet", "label_distribution"]
