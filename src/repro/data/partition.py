"""Client data partitioning: IID and Dirichlet non-IID (paper §IV-A).

Smaller beta => more heterogeneous label distributions and size deviation,
matching the paper's beta in {0.1, 0.05} settings.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.data.synthetic import Dataset


def partition_iid(ds: Dataset, n_clients: int, seed: int = 0) -> List[Dataset]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(ds))
    return [Dataset(ds.x[s], ds.y[s]) for s in np.array_split(idx, n_clients)]


def partition_dirichlet(ds: Dataset, n_clients: int, beta: float,
                        seed: int = 0, min_per_client: int = 8) -> List[Dataset]:
    """Label-Dirichlet partition: p(class c on client k) ~ Dir(beta).

    Clients below ``min_per_client`` rows are topped up by sampling the
    missing rows WITHOUT replacement from the global pool, excluding rows
    the client already owns — so a client never holds duplicate rows.
    Overlap semantics: topped-up rows may still be owned by OTHER clients
    (cross-client sharing is inherent to a top-up from a fixed pool); the
    Dirichlet split itself remains disjoint across clients."""
    rng = np.random.default_rng(seed)
    n_classes = int(ds.y.max()) + 1
    client_idx: List[List[int]] = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        idx_c = np.where(ds.y == c)[0]
        rng.shuffle(idx_c)
        props = rng.dirichlet(np.full(n_clients, beta))
        cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
        for k, part in enumerate(np.split(idx_c, cuts)):
            client_idx[k].extend(part.tolist())
    # ensure no client is starved (tiny random top-up, duplicate-free)
    for k in range(n_clients):
        missing = min_per_client - len(client_idx[k])
        if missing > 0:
            pool = np.setdiff1d(np.arange(len(ds)),
                                np.asarray(client_idx[k], dtype=int))
            extra = rng.choice(pool, size=min(missing, len(pool)),
                               replace=False)
            client_idx[k].extend(extra.tolist())
    out = []
    for k in range(n_clients):
        sel = np.asarray(client_idx[k])
        rng.shuffle(sel)
        out.append(Dataset(ds.x[sel], ds.y[sel]))
    return out


def label_distribution(parts: List[Dataset], n_classes: int) -> np.ndarray:
    dist = np.zeros((len(parts), n_classes))
    for k, p in enumerate(parts):
        for c in range(n_classes):
            dist[k, c] = np.sum(p.y == c)
    return dist
