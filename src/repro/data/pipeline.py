"""Token-batch pipeline for LM training (synthetic Markov streams)."""
from __future__ import annotations

from typing import Iterator, Optional

import numpy as np

from repro.data.synthetic import make_lm_dataset


class TokenPipeline:
    """Infinite (batch, seq+1) sampler over a token stream with optional
    per-client sharding (each client sees a disjoint slice)."""

    def __init__(self, vocab: int, batch: int, seq: int,
                 n_tokens: int = 500_000, seed: int = 0,
                 n_shards: int = 1, shard: int = 0):
        stream = make_lm_dataset(vocab=vocab, n_tokens=n_tokens, seed=seed)
        per = len(stream) // n_shards
        self.stream = stream[shard * per:(shard + 1) * per]
        self.batch = batch
        self.seq = seq
        self.rng = np.random.default_rng(seed * 997 + shard)

    def __iter__(self) -> Iterator[np.ndarray]:
        while True:
            starts = self.rng.integers(
                0, len(self.stream) - self.seq - 1, self.batch)
            yield np.stack([self.stream[s:s + self.seq + 1] for s in starts])

    def batch_dict(self, arr: np.ndarray):
        return {"tokens": arr[:, :-1].astype(np.int32),
                "labels": arr[:, 1:].astype(np.int32)}
