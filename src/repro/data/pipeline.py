"""Host-side data pipelines: token-batch sampling and cohort-window assembly.

:class:`TokenPipeline` is the LM streaming sampler (synthetic Markov
streams, optional disjoint per-client sharding).

:class:`WindowAssembler` is the cohort engine's host-side batch-assembly
stage, extracted from ``repro.fl.cohort`` so it can run as a prefetching
double-buffered pipeline: while the device computes one cohort window, the
NEXT window's batches are sampled, stacked, padded and ``device_put`` on a
background thread.  RNG parity is by construction — every client's batch
stream comes from ``np.random.default_rng(seed)`` seeded per client, so the
sampled tokens/images are identical whether assembly runs inline, early, or
on another thread; the only ordered RNG (the coordinator's seed/jitter
stream) never enters the assembler.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Iterator, List, Optional, Sequence

import numpy as np

from repro.data.synthetic import make_lm_dataset


class TokenPipeline:
    """Infinite (batch, seq+1) sampler over a token stream with optional
    per-client sharding (each client sees a disjoint slice).

    Shard boundaries follow ``np.array_split`` semantics: the remainder
    tokens of ``len(stream) % n_shards`` spread over the first shards
    instead of silently falling off the tail, so every token belongs to
    exactly one client."""

    def __init__(self, vocab: int, batch: int, seq: int,
                 n_tokens: int = 500_000, seed: int = 0,
                 n_shards: int = 1, shard: int = 0):
        if not 0 <= shard < n_shards:
            raise ValueError(f"shard {shard} out of range for "
                             f"{n_shards} shards")
        stream = make_lm_dataset(vocab=vocab, n_tokens=n_tokens, seed=seed)
        self.stream = np.array_split(stream, n_shards)[shard]
        # a (seq+1)-token window needs at least one valid start position
        if len(self.stream) < seq + 1:
            raise ValueError(
                f"shard {shard} holds {len(self.stream)} tokens but "
                f"seq={seq} windows need at least {seq + 1}; lower "
                f"n_shards (={n_shards}) or raise n_tokens (={n_tokens})")
        self.batch = batch
        self.seq = seq
        self.rng = np.random.default_rng(seed * 997 + shard)

    def __iter__(self) -> Iterator[np.ndarray]:
        # starts range over EVERY valid window, so the shard's final token
        # is reachable (high is exclusive: max start = len - seq - 1)
        while True:
            starts = self.rng.integers(
                0, len(self.stream) - self.seq, self.batch)
            yield np.stack([self.stream[s:s + self.seq + 1] for s in starts])

    def batch_dict(self, arr: np.ndarray):
        return {"tokens": arr[:, :-1].astype(np.int32),
                "labels": arr[:, 1:].astype(np.int32)}


# ---------------------------------------------------------------------------
# cohort-window assembly (the cohort engine's host-side stage)
# ---------------------------------------------------------------------------


_SHARED_EXECUTOR: Optional[ThreadPoolExecutor] = None
_SHARED_EXECUTOR_LOCK = threading.Lock()


def _shared_executor() -> ThreadPoolExecutor:
    """One process-wide assembly worker, created on first use: a sweep that
    builds hundreds of engines (benchmarks, experiments) must not
    accumulate one idle thread per engine, and the one-slot prefetch
    protocol never has more than one window in flight anyway."""
    global _SHARED_EXECUTOR
    with _SHARED_EXECUTOR_LOCK:
        if _SHARED_EXECUTOR is None:
            _SHARED_EXECUTOR = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="window-assembler")
        return _SHARED_EXECUTOR


@dataclass
class AssembledWindow:
    """One cohort window's device-ready training batch.

    ``xb``/``yb`` are (K_pad, T, B_pad, ...) stacked client batches (client
    axis padded to the engine's cohort target, step axis to the monotone
    ``T`` target, batch axis to a data-mesh multiple); ``mask`` (K_pad, T)
    masks padded steps; ``bm`` (B_pad,) masks padded batch rows (``None``
    off the data axis); ``steps`` are the real per-client step counts and
    ``uniform`` says whether every client runs exactly ``T`` steps (the
    engine's mask-free fast path)."""

    xb: object
    yb: object
    mask: object
    bm: object
    steps: List[int]
    uniform: bool


class WindowAssembler:
    """Double-buffered host-side batch assembly for the cohort engine.

    ``assemble`` is the synchronous reference path: sample every client's
    batches (``programs.client_batches`` — the exact sequential np RNG
    stream per seed), pad the step axis to the monotone ``T`` target, the
    client axis to the engine's cohort target (repeats of the last client,
    fully masked), the batch axis to a ``data``-mesh multiple (zero rows,
    masked by ``bm``), and ``device_put`` everything with the engine's
    shardings.

    ``prefetch``/``take`` add the overlap: ``prefetch`` schedules the same
    assembly on a ONE-SLOT background executor (double buffering: at most
    one window in flight while one computes) and ``take`` collects it —
    falling back to inline assembly whenever the prefetched request doesn't
    match, so correctness never depends on the caller prefetching the right
    thing.  ``overlap=False`` disables the executor entirely (every take
    assembles inline); both modes produce bit-identical windows, which the
    parity tests pin down.
    """

    def __init__(self, programs, *, n_data: int = 1, shardings=None,
                 overlap: bool = True):
        self.programs = programs
        self.n_data = max(int(n_data), 1)
        # dict with "batch" (xb/yb), "mask", "bm" NamedShardings (or None)
        self.shardings = shardings
        self.overlap = overlap
        self._lock = threading.Lock()
        self._pad_T = 0            # monotone step-axis pad target
        self._pending = None       # (key, Future[AssembledWindow])

    # -- pad-target registration (moved from CohortBackend) -----------------

    def register_shards(self, train_shards: Sequence, epochs: int) -> None:
        """Pre-size the monotone step-axis pad target so the very first
        window already compiles the steady-state program (see
        ``CohortBackend.register_shards`` for why the target must match the
        epochs the caller actually trains with)."""
        with self._lock:
            for ds in train_shards:
                self._pad_T = max(self._pad_T,
                                  self.programs.train_steps(ds, epochs))

    @property
    def pad_T(self) -> int:
        return self._pad_T

    # -- assembly ------------------------------------------------------------

    @staticmethod
    def _key(datasets, seeds, epochs: int, cohort_target: int):
        return (tuple(id(ds) for ds in datasets), tuple(int(s) for s in seeds),
                int(epochs), int(cohort_target))

    def assemble(self, datasets: Sequence, seeds: Sequence[int], epochs: int,
                 cohort_target: int) -> AssembledWindow:
        """Synchronous assembly (the reference path — also what the
        background thread runs)."""
        import jax
        import jax.numpy as jnp

        from repro.core.aggregate import pad_leading, round_up_multiple

        xs_all, ys_all, steps = [], [], []
        for ds, seed in zip(datasets, seeds):
            xb, yb = self.programs.client_batches(ds, seed, epochs)
            xs_all.append(xb)
            ys_all.append(yb)
            steps.append(int(xb.shape[0]))

        with self._lock:
            self._pad_T = max(self._pad_T, *steps)
            T = self._pad_T
        xb = jnp.stack([pad_leading(x, T) for x in xs_all])
        yb = jnp.stack([pad_leading(y, T) for y in ys_all])
        mask = jnp.stack([
            jnp.arange(T) < s for s in jnp.asarray(steps)]).astype(jnp.float32)
        uniform = all(s == T for s in steps)

        # client-axis padding: repeats of the last client, fully masked
        k = len(steps)
        if k < cohort_target:
            reps = cohort_target - k
            xb = jnp.concatenate([xb, jnp.repeat(xb[-1:], reps, axis=0)])
            yb = jnp.concatenate([yb, jnp.repeat(yb[-1:], reps, axis=0)])
            mask = jnp.concatenate(
                [mask, jnp.zeros((reps,) + mask.shape[1:], mask.dtype)])

        # batch-axis padding to a data-mesh multiple: zero rows carrying
        # zero weight in ``bm``, so they never enter the psum'd gradients
        bm = None
        if self.n_data > 1:
            b = int(xb.shape[2])
            b_pad = round_up_multiple(b, self.n_data)
            if b_pad != b:
                widths = [(0, 0), (0, 0), (0, b_pad - b)]
                xb = jnp.pad(xb, widths + [(0, 0)] * (xb.ndim - 3))
                yb = jnp.pad(yb, widths + [(0, 0)] * (yb.ndim - 3))
            bm = (jnp.arange(b_pad) < b).astype(jnp.float32)

        if self.shardings is not None:
            xb = jax.device_put(xb, self.shardings["batch"])
            yb = jax.device_put(yb, self.shardings["batch"])
            if not uniform:          # the uniform program never reads mask
                mask = jax.device_put(mask, self.shardings["mask"])
            if bm is not None:
                bm = jax.device_put(bm, self.shardings["bm"])
        return AssembledWindow(xb, yb, mask, bm, steps, uniform)

    def prefetch(self, datasets: Sequence, seeds: Sequence[int], epochs: int,
                 cohort_target: int) -> None:
        """Schedule background assembly of the given window (one slot: a
        second prefetch before the first is taken replaces it).  No-op when
        overlap is off."""
        if not self.overlap:
            return
        key = self._key(datasets, seeds, epochs, cohort_target)
        pending = self._pending
        if pending is not None and pending[0] == key:
            return                   # already in flight
        self._drain_pending()
        fut: Future = _shared_executor().submit(
            self.assemble, tuple(datasets), tuple(seeds), epochs,
            cohort_target)
        self._pending = (key, fut)

    def take(self, datasets: Sequence, seeds: Sequence[int], epochs: int,
             cohort_target: int) -> AssembledWindow:
        """The prefetched window when it matches this request, else inline
        assembly (identical output either way)."""
        pending, self._pending = self._pending, None
        if pending is not None:
            key, fut = pending
            if key == self._key(datasets, seeds, epochs, cohort_target):
                return fut.result()
            fut.result()             # stale prefetch: settle, then discard
        return self.assemble(datasets, seeds, epochs, cohort_target)

    def _drain_pending(self) -> None:
        pending, self._pending = self._pending, None
        if pending is not None:
            pending[1].result()      # never leave assembly racing the next

    def close(self) -> None:
        """Settle any in-flight assembly.  The worker thread itself is the
        process-wide shared executor's — nothing per-assembler to tear
        down."""
        self._drain_pending()
