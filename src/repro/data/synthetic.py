"""Synthetic datasets (offline container: no MNIST/CIFAR downloads).

``make_image_dataset`` builds class-conditional image data with learnable
structure: each class has a smooth prototype image; samples are prototype +
noise + random brightness.  A small CNN separates the classes well, so
accuracy curves behave like the paper's (centralized > federated > indep).

``make_lm_dataset`` builds token streams from a mixture of per-client Markov
chains so transformer clients also see heterogeneous, learnable data.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np


def _name_salt(name: str) -> int:
    """Stable per-dataset seed offset.  Builtin ``hash()`` is salted by
    ``PYTHONHASHSEED`` and would generate different data in every process;
    crc32 is stable across processes, platforms, and Python versions.

    The ``:v1`` suffix versions the derivation: bumping it re-rolls every
    synthetic dataset at once, the escape hatch if a draw ever lands
    pathologically (e.g. an untrained model scoring far above chance, which
    the bare ``crc32(name)`` draw for "mnist" did)."""
    return zlib.crc32(f"{name}:v1".encode("utf-8")) % (2 ** 16)


@dataclass
class Dataset:
    x: np.ndarray
    y: np.ndarray

    def __len__(self):
        return len(self.y)


def _prototypes(n_classes: int, size: int, channels: int, rng) -> np.ndarray:
    """Smooth per-class prototype images (low-frequency random fields)."""
    base = rng.normal(0, 1, (n_classes, size // 4 + 1, size // 4 + 1, channels))
    protos = np.zeros((n_classes, size, size, channels), np.float32)
    for c in range(n_classes):
        img = base[c]
        img = np.kron(img, np.ones((4, 4, 1)))[:size, :size]
        protos[c] = img
    protos /= np.maximum(np.abs(protos).max(axis=(1, 2, 3), keepdims=True), 1e-6)
    return protos.astype(np.float32)


def make_image_dataset(name: str, n_samples: int = 6000, n_classes: int = 10,
                       size: int = 16, channels: int = 1, noise: float = 0.35,
                       seed: int = 0) -> Dataset:
    rng = np.random.default_rng(seed + _name_salt(name))
    protos = _prototypes(n_classes, size, channels, rng)
    y = rng.integers(0, n_classes, n_samples)
    x = protos[y]
    x = x * rng.uniform(0.7, 1.3, (n_samples, 1, 1, 1)).astype(np.float32)
    x = x + rng.normal(0, noise, x.shape).astype(np.float32)
    return Dataset(x.astype(np.float32), y.astype(np.int32))


DATASET_SPECS = {
    # name: (classes, size, channels, noise) — difficulty ordered like the
    # paper's MNIST < CIFAR-10 < CIFAR-100
    "mnist": (10, 16, 1, 0.30),
    "cifar10": (10, 16, 3, 0.55),
    "cifar100": (20, 16, 3, 0.70),
}


def make_benchmark_dataset(name: str, n_samples: int = 6000, seed: int = 0
                           ) -> Dataset:
    n_classes, size, ch, noise = DATASET_SPECS[name]
    return make_image_dataset(name, n_samples, n_classes, size, ch, noise, seed)


def split_811(ds: Dataset, seed: int = 0) -> Dict[str, Dataset]:
    """Paper §IV-A: train/val/test at 8:1:1."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(ds))
    n = len(ds)
    n_tr, n_val = int(0.8 * n), int(0.1 * n)
    sl = {
        "train": idx[:n_tr],
        "val": idx[n_tr:n_tr + n_val],
        "test": idx[n_tr + n_val:],
    }
    return {k: Dataset(ds.x[v], ds.y[v]) for k, v in sl.items()}


def make_lm_dataset(vocab: int = 512, n_tokens: int = 200_000, order: float = 2.0,
                    seed: int = 0) -> np.ndarray:
    """Markov-chain token stream: learnable synthetic LM data."""
    rng = np.random.default_rng(seed)
    trans = rng.dirichlet(np.full(vocab, 1.0 / order), size=vocab)
    toks = np.zeros(n_tokens, np.int32)
    toks[0] = rng.integers(vocab)
    cum = np.cumsum(trans, axis=1)
    u = rng.random(n_tokens)
    for i in range(1, n_tokens):
        toks[i] = np.searchsorted(cum[toks[i - 1]], u[i])
    return np.clip(toks, 0, vocab - 1)
