from repro.fl.backend import CNNBackend, LMBackend
from repro.fl.baselines import (ALGORITHMS, FLConfig, fedat_tier_weights,
                                run_centralized, run_csafl, run_dagafl,
                                run_dagfl, run_fedasync, run_fedat,
                                run_fedavg, run_fedhisyn, run_independent,
                                run_scalesfl)
from repro.fl.cohort import (CNNCohortPrograms, CohortBackend, CohortPrograms,
                             LMCohortPrograms, build_cohort_engine,
                             perturb_update, register_cohort_programs,
                             resolve_cohort_mesh)
from repro.fl.scenarios import (SCENARIOS, Scenario, ScenarioConfig,
                                as_scenario, dag_attack_metrics)
from repro.fl.serving import (CNNQueryDriver, ConsensusPublisher,
                              LMQueryDriver, QueryStream, ServingConfig,
                              ServingReplica, consensus_over_refs,
                              frontier_snapshot, make_query_driver,
                              replica_parity, trees_bitwise_equal)

__all__ = ["CNNBackend", "LMBackend", "ALGORITHMS", "FLConfig",
           "run_centralized", "run_independent", "run_fedavg", "run_fedasync",
           "run_fedat", "run_csafl", "run_fedhisyn", "run_scalesfl",
           "run_dagfl", "run_dagafl", "fedat_tier_weights",
           "CohortBackend", "CohortPrograms", "CNNCohortPrograms",
           "LMCohortPrograms", "build_cohort_engine", "perturb_update",
           "register_cohort_programs", "resolve_cohort_mesh",
           "SCENARIOS", "Scenario", "ScenarioConfig", "as_scenario",
           "dag_attack_metrics",
           "ServingConfig", "ServingReplica", "ConsensusPublisher",
           "QueryStream", "CNNQueryDriver", "LMQueryDriver",
           "make_query_driver", "consensus_over_refs", "frontier_snapshot",
           "replica_parity", "trees_bitwise_equal"]
