"""Client training backends shared by DAG-AFL and all baselines.

A backend owns the jitted local-training/eval/signature programs for one
model family.  ``CNNBackend`` is the paper-faithful path (VGG family, exact
Eq. 3 zero-count signatures); ``LMBackend`` federates any ArchConfig
transformer (threshold-zero signatures; see DESIGN.md hardware adaptation).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.configs.cnn import CNNConfig
from repro.data.synthetic import Dataset
from repro.models import cnn as cnn_mod
from repro.models import transformer as tfm
from repro.optim.optimizers import apply_updates, sgd
from repro.runtime import Runtime


class CNNBackend:
    """VGG-family clients on image data (the paper's experimental setup)."""

    def __init__(self, cfg: CNNConfig, lr: float = 0.01,
                 local_epochs: int = 5, batch_size: int = 64,
                 kernel_policy: Optional[str] = None):
        self.cfg = cfg
        self.lr = lr
        self.local_epochs = local_epochs
        self.batch_size = batch_size
        # None -> incumbent pure-jnp signature math; anything else resolves
        # through the dispatch layer (e.g. "auto" -> interpret on CPU CI).
        if kernel_policy is None:
            self.kernel_policy = "reference"
        else:
            from repro.kernels.dispatch import resolve_policy
            self.kernel_policy = resolve_policy(kernel_policy)
        self.opt = sgd(lr, momentum=0.9)
        self._train_epoch = jax.jit(self._train_epoch_impl)
        self._eval = jax.jit(self._eval_impl)
        self._signature = jax.jit(self._signature_impl)

    # -- jitted programs ----------------------------------------------------

    def _train_epoch_impl(self, params, opt_state, xb, yb):
        """xb (n_batches, B, H, W, C); yb (n_batches, B)."""

        def step(carry, batch):
            params, opt_state = carry
            x, y = batch
            loss, grads = jax.value_and_grad(
                lambda p: cnn_mod.cnn_loss(p, {"images": x, "labels": y},
                                           self.cfg)[0])(params)
            updates, opt_state = self.opt.update(grads, opt_state, params)
            params = apply_updates(params, updates)
            return (params, opt_state), loss

        (params, opt_state), losses = jax.lax.scan(
            step, (params, opt_state), (xb, yb))
        return params, opt_state, jnp.mean(losses)

    def _eval_impl(self, params, x, y):
        logits, _ = cnn_mod.cnn_forward(params, x, self.cfg)
        return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))

    def _signature_impl(self, params, x):
        _, sig = cnn_mod.cnn_forward(params, x, self.cfg, want_signature=True,
                                     kernel_policy=self.kernel_policy)
        return sig

    # -- public API ----------------------------------------------------------

    def init(self, key):
        return cnn_mod.init_cnn(key, self.cfg)

    def init_opt(self, params):
        return self.opt.init(params)

    def _batches(self, ds: Dataset, rng) -> tuple:
        n = (len(ds) // self.batch_size) * self.batch_size
        if n == 0:  # tiny shard: single batch with repetition
            idx = rng.integers(0, len(ds), self.batch_size)
            return (jnp.asarray(ds.x[idx])[None], jnp.asarray(ds.y[idx])[None])
        idx = rng.permutation(len(ds))[:n]
        xb = jnp.asarray(ds.x[idx]).reshape(-1, self.batch_size, *ds.x.shape[1:])
        yb = jnp.asarray(ds.y[idx]).reshape(-1, self.batch_size)
        return xb, yb

    def train_local(self, params, ds: Dataset, seed: int = 0,
                    epochs: Optional[int] = None):
        rng = np.random.default_rng(seed)
        opt_state = self.init_opt(params)
        loss = jnp.zeros(())
        for _ in range(epochs or self.local_epochs):
            xb, yb = self._batches(ds, rng)
            params, opt_state, loss = self._train_epoch(params, opt_state,
                                                        xb, yb)
        return params, float(loss)

    def evaluate(self, params, ds: Dataset, limit: int = 512) -> float:
        n = min(len(ds), limit)
        return float(self._eval(params, jnp.asarray(ds.x[:n]),
                                jnp.asarray(ds.y[:n])))

    def signature(self, params, ds: Dataset, limit: int = 128) -> np.ndarray:
        n = min(len(ds), limit)
        return np.asarray(self._signature(params, jnp.asarray(ds.x[:n])))


class LMBackend:
    """Transformer clients on token streams (framework-scale DAG-AFL)."""

    def __init__(self, cfg: ArchConfig, lr: float = 3e-3,
                 local_steps: int = 8, batch_size: int = 8, seq_len: int = 64,
                 kernel_policy: Optional[str] = None):
        self.cfg = cfg
        self.local_steps = local_steps
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.opt = sgd(lr, momentum=0.9)
        # kernel_policy=None keeps the incumbent stock-XLA forward; a policy
        # turns on the Pallas hot paths (attention + Eq. 3 signature) for
        # eval/signature programs — training stays on the XLA path because
        # pallas_call is not differentiable (see cohort.LMCohortPrograms).
        if kernel_policy is None:
            self.kernel_policy = "reference"
            self.runtime = Runtime(want_signature=True)
        else:
            from repro.kernels.dispatch import resolve_policy
            self.kernel_policy = resolve_policy(kernel_policy)
            self.runtime = Runtime(want_signature=True, use_pallas=True,
                                   kernel_policy=self.kernel_policy)
        self._train_steps = jax.jit(self._train_steps_impl)
        self._eval = jax.jit(self._eval_impl)

    def _train_steps_impl(self, params, opt_state, tokens):
        """tokens (n_steps, B, S+1)."""

        def step(carry, tb):
            params, opt_state = carry
            batch = {"tokens": tb[:, :-1], "labels": tb[:, 1:]}
            (loss, aux), grads = jax.value_and_grad(
                lambda p: tfm.loss_fn(p, batch, self.cfg), has_aux=True)(params)
            updates, opt_state = self.opt.update(grads, opt_state, params)
            params = apply_updates(params, updates)
            return (params, opt_state), loss

        (params, opt_state), losses = jax.lax.scan(step, (params, opt_state),
                                                   tokens)
        return params, opt_state, jnp.mean(losses)

    def _eval_impl(self, params, tokens):
        batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
        logits, aux, _ = tfm.forward(params, batch, self.cfg, self.runtime,
                                     mode="prefill")
        pred = jnp.argmax(logits, -1)
        acc = jnp.mean((pred == tokens[:, 1:]).astype(jnp.float32))
        return acc, aux.get("signature", jnp.zeros((64,)))

    def init(self, key):
        return tfm.init_params(key, self.cfg)

    def _sample(self, stream: np.ndarray, rng, n: int):
        starts = rng.integers(0, len(stream) - self.seq_len - 1,
                              (n, self.batch_size))
        return jnp.asarray(np.stack([
            np.stack([stream[s:s + self.seq_len + 1] for s in row])
            for row in starts]))

    def train_local(self, params, stream: np.ndarray, seed: int = 0,
                    epochs: Optional[int] = None):
        rng = np.random.default_rng(seed)
        toks = self._sample(stream, rng, epochs or self.local_steps)
        opt_state = self.opt.init(params)
        params, _, loss = self._train_steps(params, opt_state, toks)
        return params, float(loss)

    def evaluate(self, params, stream: np.ndarray, seed: int = 1) -> float:
        rng = np.random.default_rng(seed)
        toks = self._sample(stream, rng, 1)[0]
        acc, _ = self._eval(params, toks)
        return float(acc)

    def signature(self, params, stream: np.ndarray, seed: int = 2) -> np.ndarray:
        rng = np.random.default_rng(seed)
        toks = self._sample(stream, rng, 1)[0]
        _, sig = self._eval(params, toks)
        return np.asarray(sig)
