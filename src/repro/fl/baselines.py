"""The paper's eight competitors, all on the shared simulator substrate.

Each algorithm consumes the same (backend, client_data, global_test,
profiles, cost model) quintuple and returns a :class:`RunResult`, so the
Table II / Table III benchmark compares like with like.

  centralized   no privacy: one model on the pooled data (upper bound)
  independent   each client alone (lower bound)
  fedavg        McMahan et al. 2017 — synchronous rounds, barrier on slowest
  fedasync      Xie et al. 2019 — server mixes on every arrival, staleness-
                adaptive alpha
  fedat         Chai et al. 2021 — latency tiers: sync within, async across
  csafl         Zhang et al. 2021 — similarity clusters, semi-async groups
  fedhisyn      Li et al. 2022 — speed clusters, sequential ring inside a
                cluster then cross-cluster sync (slowest, like the paper)
  dagfl         Cao et al. 2021 — DAG ledger, but tips chosen by cumulative
                weight and EVERY candidate tip validated (no signature
                pre-filter, no freshness) — DAG-AFL's direct ancestor
  scalesfl      Madill et al. 2022 — sharded committee chain on top of
                synchronous FL (per-round consensus overhead)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.aggregate import tree_interpolate, tree_mean, tree_weighted
from repro.core.dag import DAGLedger, ModelStore, TxMetadata
from repro.core.simulator import (ClientProfile, CohortWindow,
                                  ConvergenceTracker, CostModel, EventLoop,
                                  RunResult, make_profiles)


@dataclass
class FLConfig:
    n_clients: int = 10
    max_rounds: int = 30
    local_epochs: int = 5
    target_accuracy: Optional[float] = None
    patience: int = 5
    heterogeneity: float = 0.6
    seed: int = 0
    # vectorized execution: batch up to this many concurrent client rounds
    # into one vmapped program (1 = sequential reference path)
    cohort_size: int = 1
    cohort_window: float = 1.0
    # SPMD cohort execution (see DagAflConfig.mesh):
    # "auto" | "CxD" | (clients, data) | None | Mesh
    mesh: object = "auto"
    clients_axis: str = "clients"
    data_axis: str = "data"
    # overlapped host pipeline (see DagAflConfig.overlap)
    overlap: bool = True
    # kernel dispatch policy for the cohort hot paths
    # (see DagAflConfig.kernel_policy / repro.kernels.dispatch)
    kernel_policy: object = None
    # algorithm-specific knobs
    fedasync_alpha: float = 0.6
    fedasync_staleness: str = "poly"     # poly | constant
    n_tiers: int = 3                     # fedat / csafl / fedhisyn clusters
    dagfl_n_select: int = 2
    consensus_overhead: float = 1.5      # scalesfl per-round committee cost
    # DAG ledgers (dagfl / dagafl): > 0 switches to the bounded-frontier
    # BoundedDAGLedger, checkpointing every this many simulated seconds
    # (see DagAflConfig.ledger_checkpoint_every); 0 = append-only ledger
    ledger_checkpoint_every: float = 0.0
    # fault injection: None (honest), a repro.fl.scenarios.ScenarioConfig,
    # a registry name or a prebuilt Scenario (see DagAflConfig.scenario) —
    # the same scenarios attack the baselines and the DAG coordinator, so
    # the robustness benchmark compares like with like
    scenario: object = None


class _Harness:
    """Common state for every baseline."""

    def __init__(self, backend, client_data, global_test, cfg: FLConfig,
                 cost=None, profiles=None):
        import jax
        self.backend = backend
        self.scenario = None
        self._last_submitted: Dict[int, object] = {}
        if cfg.scenario is not None:
            from repro.fl.scenarios import as_scenario
            self.scenario = as_scenario(cfg.scenario, cfg.n_clients)
            client_data = self.scenario.poison_data(client_data)
        self.client_data = client_data
        self.global_test = global_test
        self.cfg = cfg
        self.cost = cost or CostModel()
        self.profiles = profiles or make_profiles(cfg.n_clients,
                                                  cfg.heterogeneity, cfg.seed)
        self.rng = np.random.default_rng(cfg.seed)
        self.tracker = ConvergenceTracker(cfg.target_accuracy, cfg.patience)
        self.key = jax.random.PRNGKey(cfg.seed)
        self.cohort = None
        if cfg.cohort_size > 1:
            # backend-agnostic construction via the cohort program registry
            from repro.fl.cohort import build_cohort_engine
            self.cohort = build_cohort_engine(
                backend,
                [client_data[c]["train"] for c in range(cfg.n_clients)],
                cohort_size=cfg.cohort_size, mesh=cfg.mesh,
                clients_axis=cfg.clients_axis, data_axis=cfg.data_axis,
                epochs=cfg.local_epochs, overlap=cfg.overlap,
                kernel_policy=cfg.kernel_policy)
        self._val_sets = [client_data[c]["val"]
                          for c in range(cfg.n_clients)]

    def init_model(self):
        from repro.core.aggregate import tree_size_bytes
        m = self.backend.init(self.key)
        self.cost.model_bytes = max(tree_size_bytes(m), 1)
        return m

    def train(self, model, client: int):
        out = self.backend.train_local(
            model, self.client_data[client]["train"],
            seed=int(self.rng.integers(2 ** 31)),
            epochs=self.cfg.local_epochs)[0]
        if self.scenario is not None:
            out = self._scenario_update(client, model, out)
        return out

    def _scenario_update(self, client: int, base, new):
        """Scenario fault injection on one submitted update (see
        repro/fl/scenarios.py); lazy 'stale' free-riders resubmit whatever
        they last handed the server."""
        sc = self.scenario
        plan = sc.update_plan([client])
        if plan is not None and plan["affected"][0]:
            from repro.fl.cohort import perturb_update
            new = perturb_update(base, new, plan, 0)
        if sc.wants_stale(client):
            prev = self._last_submitted.get(client)
            if prev is not None:
                sc.updates_lazy += 1
                new = prev
            self._last_submitted[client] = new
        return new

    def drops(self, c: int) -> bool:
        """Scenario wireless dropout for this client's current publish."""
        return self.scenario is not None and self.scenario.drops_publish(c)

    def round_duration(self, c: int) -> float:
        """Simulated cost of one local round: train + up/down transfer."""
        t_train = self.cost.train_time(self.profiles[c],
                                       self.cfg.local_epochs, self.rng)
        if self.scenario is not None:
            t_train *= self.scenario.duration_multiplier(c)
        return (t_train
                + 2 * self.cost.transfer_time(self.profiles[c],
                                              self.cost.model_bytes))

    def train_many(self, model, clients):
        """Local rounds for several clients starting from one shared model;
        returns (local models, simulated durations).  With a cohort engine,
        capacity-sized groups run as single vmapped programs instead of
        len(clients) serial ``train_local`` calls.  The sequential path
        draws (seed, duration-jitter) interleaved per client — the seed
        repo's RNG stream — so cohort_size=1 reproduces it exactly."""
        clients = list(clients)
        if self.cohort is None or len(clients) < 2:
            out, durs = [], []
            for c in clients:
                out.append(self.train(model, c))
                durs.append(self.round_duration(c))
            return out, durs
        out, durs = [], []
        cap = self.cfg.cohort_size
        for i in range(0, len(clients), cap):
            group = clients[i:i + cap]
            if len(group) == 1:
                out.append(self.train(model, group[0]))
                durs.append(self.round_duration(group[0]))
                continue
            seeds = [int(self.rng.integers(2 ** 31)) for _ in group]
            models, _ = self.cohort.train_cohort(
                [model] * len(group),
                [self.client_data[c]["train"] for c in group],
                seeds, epochs=self.cfg.local_epochs)
            if self.scenario is not None:
                models = [self._scenario_update(c, model, m)
                          for c, m in zip(group, models)]
            out.extend(models)
            durs.extend(self.round_duration(c) for c in group)
        return out, durs

    def val_acc(self, model, client: int) -> float:
        return self.backend.evaluate(model, self.client_data[client]["val"])

    def mean_val(self, model) -> float:
        if self.cohort is not None:
            accs = self.cohort.evaluate_shared(model, self._val_sets)
        else:
            accs = [self.val_acc(model, c) for c in range(self.cfg.n_clients)]
        return float(np.mean(accs))

    def result(self, name, model, sim_time, rounds, extra=None) -> RunResult:
        acc = self.backend.evaluate(model, self.global_test)
        return RunResult(name=name, final_accuracy=acc,
                         best_accuracy=max(acc, self.tracker.best),
                         sim_time=sim_time, rounds=rounds,
                         history=self.tracker.history, extra=extra or {})


# ---------------------------------------------------------------------------
# bounds
# ---------------------------------------------------------------------------


def run_centralized(backend, client_data, global_test, cfg: FLConfig,
                    cost=None, profiles=None, pooled_train=None) -> RunResult:
    h = _Harness(backend, client_data, global_test, cfg, cost, profiles)
    model = h.init_model()
    assert pooled_train is not None, "centralized needs the pooled train set"
    t = 0.0
    ref = h.profiles[0]
    for r in range(cfg.max_rounds):
        model, _ = backend.train_local(model, pooled_train, seed=r,
                                       epochs=cfg.local_epochs)
        t += h.cost.train_time(ref, cfg.local_epochs, h.rng)
        if h.tracker.update(t, h.mean_val(model)):
            break
    return h.result("Centralized", model, h.tracker.converged_at or t, r + 1)


def run_independent(backend, client_data, global_test, cfg: FLConfig,
                    cost=None, profiles=None) -> RunResult:
    h = _Harness(backend, client_data, global_test, cfg, cost, profiles)
    accs, times = [], []
    model0 = h.init_model()
    last = model0
    for c in range(cfg.n_clients):
        model = model0
        t = 0.0
        tr = ConvergenceTracker(cfg.target_accuracy, cfg.patience)
        for r in range(cfg.max_rounds):
            model = h.train(model, c)
            t += h.cost.train_time(h.profiles[c], cfg.local_epochs, h.rng)
            if tr.update(t, h.val_acc(model, c)):
                break
        accs.append(backend.evaluate(model, global_test))
        times.append(tr.converged_at or t)
        h.tracker.history.extend(tr.history)
        last = model
    res = h.result("Independent", last, float(np.mean(times)), cfg.max_rounds)
    res.final_accuracy = float(np.mean(accs))
    res.best_accuracy = float(np.max(accs))
    res.history = sorted(h.tracker.history)
    return res


# ---------------------------------------------------------------------------
# synchronous / asynchronous FL
# ---------------------------------------------------------------------------


def run_fedavg(backend, client_data, global_test, cfg: FLConfig,
               cost=None, profiles=None, name="FedAvg",
               round_overhead: float = 0.0) -> RunResult:
    h = _Harness(backend, client_data, global_test, cfg, cost, profiles)
    model = h.init_model()
    t = 0.0
    sizes = [len(client_data[c]["train"]) for c in range(cfg.n_clients)]
    for r in range(cfg.max_rounds):
        locals_, durations = h.train_many(model, range(cfg.n_clients))
        t += max(durations) + round_overhead      # synchronous barrier
        # scenario dropouts: the barrier still pays for the dropped
        # clients' rounds, but their updates never reach the server
        kept = [c for c in range(cfg.n_clients) if not h.drops(c)]
        if kept:
            model = tree_weighted([locals_[c] for c in kept],
                                  [sizes[c] for c in kept])
        if h.tracker.update(t, h.mean_val(model)):
            break
    return h.result(name, model, h.tracker.converged_at or t, r + 1)


def run_fedasync(backend, client_data, global_test, cfg: FLConfig,
                 cost=None, profiles=None) -> RunResult:
    h = _Harness(backend, client_data, global_test, cfg, cost, profiles)
    loop = EventLoop()
    state = {"model": h.init_model(), "version": 0, "rounds": 0}

    def arrive(c: int, local, v: int):
        if not h.drops(c):      # scenario dropout: the update never arrives
            staleness = state["version"] - v
            alpha = cfg.fedasync_alpha
            if cfg.fedasync_staleness == "poly":
                alpha = alpha / (1.0 + staleness) ** 0.5
            state["model"] = tree_interpolate(state["model"], local, alpha)
            state["version"] += 1
        state["rounds"] += 1
        if state["rounds"] % cfg.n_clients == 0:
            h.tracker.update(loop.now, h.mean_val(state["model"]))
        if (not h.tracker.done
                and state["rounds"] < cfg.max_rounds * cfg.n_clients):
            loop.schedule(0.0, lambda: client_round(c))

    def client_round(c: int):
        """Sequential path: train at the round-start event from the model
        (and version) current at that event."""
        if h.tracker.done:
            return
        v = state["version"]
        local = h.train(state["model"], c)
        loop.schedule(h.round_duration(c), lambda: arrive(c, local, v))

    def flush(batch):
        """Cohort path: one vmapped program for the window's rounds
        (bounded staleness within cohort_window, as in the coordinator).
        Version is captured HERE — the same moment state['model'] is read —
        so staleness discounting matches what each round actually trained
        from."""
        v = state["version"]
        locals_, durs = h.train_many(state["model"], [b[0] for b in batch])
        for (c_, t0_), local, dur in zip(batch, locals_, durs):
            loop.schedule(t0_ + dur - loop.now,
                          lambda c_=c_, local=local: arrive(c_, local, v))

    if h.cohort is not None:
        window = CohortWindow(loop, cfg.cohort_size, cfg.cohort_window,
                              flush, lambda: h.tracker.done)
        client_round = (lambda c: h.tracker.done or window.add(c))  # noqa: E731

    for c in range(cfg.n_clients):
        loop.schedule(float(h.rng.uniform(0, 1.0)),
                      lambda c=c: client_round(c))
    loop.run(stop=lambda: h.tracker.done)
    return h.result("FedAsync", state["model"],
                    h.tracker.converged_at or loop.now, state["rounds"])


# ---------------------------------------------------------------------------
# tiered / clustered semi-async
# ---------------------------------------------------------------------------


def _cluster_by(values: List[float], n_clusters: int) -> List[List[int]]:
    order = np.argsort(values)
    return [list(part) for part in np.array_split(order, n_clusters)]


def fedat_tier_weights(tier_updates: List[int],
                       ready: List[int]) -> List[float]:
    """FedAT's cross-tier aggregation weights (Chai et al. 2021, Eq. 4).

    Tier k's weight DECREASES in its update count T_k: straggler tiers
    update less often, so each of their (rarer) models carries more weight
    in the cross-tier average — without this, fast tiers dominate the
    global model and the stragglers' data is drowned out.  The paper's
    normalized form is p_k proportional to (sum_i T_i) - T_k; we use the
    rank-equivalent 1/T_k (both strictly decreasing in T_k, identical
    ordering), pinned by the regression tests in
    tests/test_fl_baselines.py.  ``tier_updates`` counts start at 1 (the
    init model counts as every tier's zeroth update), so the weights are
    always finite.
    """
    return [1.0 / tier_updates[i] for i in ready]


def run_fedat(backend, client_data, global_test, cfg: FLConfig,
              cost=None, profiles=None) -> RunResult:
    """Latency tiers: synchronous within a tier, async weighted across."""
    h = _Harness(backend, client_data, global_test, cfg, cost, profiles)
    tiers = _cluster_by([p.speed for p in h.profiles], cfg.n_tiers)
    loop = EventLoop()
    tier_models = {i: None for i in range(len(tiers))}
    state = {"model": h.init_model(), "rounds": 0, "tier_updates": [1] * len(tiers)}

    def tier_round(ti: int, rnd: int):
        if h.tracker.done or rnd >= cfg.max_rounds:
            return
        members = tiers[ti]
        locals_, durs = h.train_many(state["model"], members)
        dur = max(durs)

        def arrive(ti=ti, locals_=locals_, rnd=rnd):
            tier_models[ti] = tree_mean(locals_)
            state["tier_updates"][ti] += 1
            # cross-tier weighted average: straggler tiers get MORE weight
            # (FedAT's inverse-frequency weighting, see fedat_tier_weights)
            ready = [i for i in tier_models if tier_models[i] is not None]
            inv = fedat_tier_weights(state["tier_updates"], ready)
            state["model"] = tree_weighted([tier_models[i] for i in ready], inv)
            state["rounds"] += 1
            h.tracker.update(loop.now, h.mean_val(state["model"]))
            if not h.tracker.done:
                loop.schedule(0.0, lambda: tier_round(ti, rnd + 1))

        loop.schedule(dur, arrive)

    for ti in range(len(tiers)):
        loop.schedule(0.0, lambda ti=ti: tier_round(ti, 0))
    loop.run(stop=lambda: h.tracker.done)
    return h.result("FedAT", state["model"],
                    h.tracker.converged_at or loop.now, state["rounds"],
                    extra={"tier_updates": list(state["tier_updates"]),
                           "tiers": [list(map(int, t)) for t in tiers]})


def run_csafl(backend, client_data, global_test, cfg: FLConfig,
              cost=None, profiles=None) -> RunResult:
    """Clustered semi-async: groups by data similarity (label histograms),
    sync inside a group, FedAsync-style mixing across groups."""
    h = _Harness(backend, client_data, global_test, cfg, cost, profiles)
    # group by label distribution similarity
    hists = []
    for c in range(cfg.n_clients):
        y = np.asarray(client_data[c]["train"].y)
        n_classes = int(max(y.max() for cd in [client_data[i]["train"]
                                               for i in range(cfg.n_clients)]
                            for y in [np.asarray(cd.y)])) + 1
        hist = np.bincount(y, minlength=n_classes).astype(float)
        hists.append(hist / max(hist.sum(), 1))
    proj = [float(np.argmax(hh)) + 0.01 * i for i, hh in enumerate(hists)]
    groups = _cluster_by(proj, cfg.n_tiers)
    loop = EventLoop()
    state = {"model": h.init_model(), "rounds": 0, "version": 0}

    def group_round(gi: int, rnd: int, version: int):
        if h.tracker.done or rnd >= cfg.max_rounds:
            return
        members = groups[gi]
        locals_, durs = h.train_many(state["model"], members)
        dur = max(durs)

        def arrive(gi=gi, locals_=locals_, rnd=rnd, v=version):
            staleness = state["version"] - v
            alpha = cfg.fedasync_alpha / (1.0 + staleness) ** 0.5
            state["model"] = tree_interpolate(state["model"],
                                              tree_mean(locals_), alpha)
            state["version"] += 1
            state["rounds"] += 1
            h.tracker.update(loop.now, h.mean_val(state["model"]))
            if not h.tracker.done:
                loop.schedule(0.0, lambda: group_round(gi, rnd + 1,
                                                       state["version"]))

        loop.schedule(dur, arrive)

    for gi in range(len(groups)):
        loop.schedule(0.0, lambda gi=gi: group_round(gi, 0, 0))
    loop.run(stop=lambda: h.tracker.done)
    return h.result("CSAFL", state["model"],
                    h.tracker.converged_at or loop.now, state["rounds"])


def run_fedhisyn(backend, client_data, global_test, cfg: FLConfig,
                 cost=None, profiles=None) -> RunResult:
    """Hierarchical sync: speed clusters; inside a cluster the model is
    passed sequentially (ring), then clusters aggregate synchronously —
    sequential passes make it the slowest method, as in the paper."""
    h = _Harness(backend, client_data, global_test, cfg, cost, profiles)
    clusters = _cluster_by([p.speed for p in h.profiles], cfg.n_tiers)
    model = h.init_model()
    t = 0.0
    for r in range(cfg.max_rounds):
        cluster_models, durs = [], []
        for members in clusters:
            m = model
            dur = 0.0
            for c in members:                      # sequential ring
                m = h.train(m, c)
                dur += (h.cost.train_time(h.profiles[c], cfg.local_epochs, h.rng)
                        + 2 * h.cost.transfer_time(h.profiles[c],
                                                   h.cost.model_bytes))
            cluster_models.append(m)
            durs.append(dur)
        t += max(durs)                             # sync barrier on clusters
        sizes = [sum(len(client_data[c]["train"]) for c in members)
                 for members in clusters]
        model = tree_weighted(cluster_models, sizes)
        if h.tracker.update(t, h.mean_val(model)):
            break
    return h.result("FedHiSyn", model, h.tracker.converged_at or t, r + 1)


# ---------------------------------------------------------------------------
# blockchain-based competitors
# ---------------------------------------------------------------------------


def run_scalesfl(backend, client_data, global_test, cfg: FLConfig,
                 cost=None, profiles=None) -> RunResult:
    """Sharded committee chain over synchronous FL: FedAvg + per-round
    shard-consensus overhead (committee validation of every local update)."""
    h0 = CostModel() if cost is None else cost
    overhead = cfg.consensus_overhead + 0.2 * cfg.n_clients * h0.eval_batch
    res = run_fedavg(backend, client_data, global_test, cfg, cost, profiles,
                     name="ScaleSFL", round_overhead=overhead)
    return res


def run_dagfl(backend, client_data, global_test, cfg: FLConfig,
              cost=None, profiles=None) -> RunResult:
    """DAG-FL (Cao et al.): DAG ledger, cumulative-weight tip selection,
    every candidate validated, no freshness / signature filter."""
    from repro.core.coordinator import DagAflConfig, DagAflCoordinator
    from repro.core.tip_selection import TipSelectionConfig

    dcfg = DagAflConfig(
        n_clients=cfg.n_clients, max_rounds=cfg.max_rounds,
        local_epochs=cfg.local_epochs, target_accuracy=cfg.target_accuracy,
        patience=cfg.patience, heterogeneity=cfg.heterogeneity, seed=cfg.seed,
        verify_paths=False, cohort_size=cfg.cohort_size,
        cohort_window=cfg.cohort_window, mesh=cfg.mesh,
        clients_axis=cfg.clients_axis, data_axis=cfg.data_axis,
        overlap=cfg.overlap,
        ledger_checkpoint_every=cfg.ledger_checkpoint_every,
        scenario=cfg.scenario,
        tip=TipSelectionConfig(n_select=cfg.dagfl_n_select, lam=0.0,
                               use_freshness=False, use_similarity=False,
                               p_similar=max(cfg.n_clients, 8)))
    coord = DagAflCoordinator(backend, client_data, global_test, dcfg,
                              cost, profiles)
    res = coord.run()
    res.name = "DAG-FL"
    return res


def run_dagafl(backend, client_data, global_test, cfg: FLConfig,
               cost=None, profiles=None, tip_cfg=None) -> RunResult:
    from repro.core.coordinator import DagAflConfig, DagAflCoordinator
    from repro.core.tip_selection import TipSelectionConfig

    dcfg = DagAflConfig(
        n_clients=cfg.n_clients, max_rounds=cfg.max_rounds,
        local_epochs=cfg.local_epochs, target_accuracy=cfg.target_accuracy,
        patience=cfg.patience, heterogeneity=cfg.heterogeneity, seed=cfg.seed,
        cohort_size=cfg.cohort_size, cohort_window=cfg.cohort_window,
        mesh=cfg.mesh, clients_axis=cfg.clients_axis,
        data_axis=cfg.data_axis, overlap=cfg.overlap,
        ledger_checkpoint_every=cfg.ledger_checkpoint_every,
        scenario=cfg.scenario,
        tip=tip_cfg or TipSelectionConfig())
    coord = DagAflCoordinator(backend, client_data, global_test, dcfg,
                              cost, profiles)
    return coord.run()


ALGORITHMS = {
    "centralized": run_centralized,
    "independent": run_independent,
    "fedavg": run_fedavg,
    "fedasync": run_fedasync,
    "fedat": run_fedat,
    "csafl": run_csafl,
    "fedhisyn": run_fedhisyn,
    "scalesfl": run_scalesfl,
    "dagfl": run_dagfl,
    "dagafl": run_dagafl,
}
