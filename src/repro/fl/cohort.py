"""Vectorized cohort execution engine: K clients as ONE batched XLA program.

The simulator's event heap decides *when* each client's round runs in
simulated time; this module decides *how* the container executes the work.
Instead of K serial ``train_local`` / ``evaluate`` / ``signature`` calls, a
:class:`CohortBackend` stacks the K clients' parameter pytrees along a
leading client axis (``tree_stack``) and runs local training, evaluation and
signature extraction as single batched jitted programs.

The batched programs themselves are supplied per backend family by a
*cohort programs* suite (:class:`CohortPrograms`):

  * :class:`CNNCohortPrograms` — the paper-faithful VGG path.  Training is
    ``jax.vmap``-batched with the convolutions rewritten as im2col GEMMs
    (see ``_conv_as_matmul``); evaluation and signatures are FLOP-light, so
    they are ``lax.map``-fused into one dispatch while keeping the
    dense-conv lowering per client.
  * :class:`LMCohortPrograms` — the transformer (``LMBackend``) path.
    Training vmaps the stacked K-client param pytrees over the same masked
    scan (token batches pre-sampled per client exactly like the sequential
    RNG stream); evaluation and Eq. 3 signatures (threshold-zero fractions
    of the designated final-norm activations, per sample so padding masks
    out) run ``lax.map``-fused like the CNN ones.

``register_cohort_programs`` extends the registry; ``CohortBackend.supports``
answers for any backend instance, and callers fall back to the sequential
path for unregistered backends.

Ragged shards are handled by padding + masking:

  * training: every client's step sequence is padded to a common length
    ``T``; masked steps compute a gradient on zero-padding but the pytree
    select keeps the pre-step params/optimizer state, so padding NEVER
    leaks into the trained weights.
  * evaluation/signature: sample axes are padded to a common length and the
    accuracy / Eq. 3 zero-fraction means are masked, so padded samples carry
    zero weight.

Shape discipline (CPU/TPU friendly): the cohort axis is padded to powers of
two capped at ``capacity``, the training step axis to a monotone registered
maximum, and eval/signature sample axes to per-call targets quantized by
``eval_pad_quantum`` — so steady-state dispatches hit a bounded set of
compiled programs instead of retracing.  Eval/signature data buffers are
cached per dataset with an LRU bound (``eval_cache_entries``) so a
long-running simulator never pins an unbounded set of shards.

SPMD over a device mesh: passing ``mesh`` (any ``jax.sharding.Mesh`` whose
``clients_axis`` axis has more than one device — see
``repro.launch.mesh.make_cohort_mesh``) turns every batched program into one
``shard_map`` SPMD program: the stacked client axis is sharded over the mesh
so each device runs the vmapped train step (and the lax.map-fused
eval/signature programs) on its own client group, with no cross-device
communication inside a window — client rounds are embarrassingly parallel;
the cross-device work is the window's Eq. 6 aggregation, which
``repro.core.aggregate`` phrases as psum collectives over the same axis.
Cohort padding rounds up to a mesh-size multiple so the groups divide
evenly; masking keeps the padding out of every result exactly as on one
device.  ``mesh=None`` (or a 1-device mesh) is bit-for-bit today's
single-device path.

2-D (clients, data) meshes (``make_cohort_mesh(C, data=D)``) additionally
shard each client group's TRAINING DATA: the per-step batch axis (and the
eval/signature sample axes) splits over the ``data`` axis, every device
computes the sum-form loss/metric terms on its local sample slice, and one
``lax.psum`` over ``data`` re-assembles the full-batch gradient (and the
masked eval/signature means) inside each client group — the client models
stay replicated within a group and advance in lockstep, so the 2-D result
matches the 1-D clients-mesh result up to float-reduction order (property-
tested).  Ragged batch/sample axes pad to a ``data`` multiple with
zero-weight rows (``bm`` masks), so non-divisible batch sizes cost padding
FLOPs but never numerics.  The suites expose their losses/metrics in
sum-and-count form (``sum_loss``/``eval_terms``) exactly so the engine can
place the division AFTER the psum.

Host-side window assembly lives in
:class:`repro.data.pipeline.WindowAssembler`: a double-buffered background
stage that samples, stacks, pads and ``device_put``s a window while the
device computes (``prefetch_window``/``take``), preserving the sequential
per-seed np RNG streams exactly.  This all works identically for both
program suites — the mesh plumbing never inspects what the programs
compute.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import List, Optional, Sequence, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import (next_pow2, pad_leading, round_up_multiple,
                                  tree_stack, tree_unstack)
from repro.fl.backend import CNNBackend, LMBackend
from repro.optim.optimizers import apply_updates


def _tree_select(keep, new, old):
    """Per-leaf ``where(keep, new, old)`` — identity step when masked out."""
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(keep, a, b), new, old)


# -- scenario update transforms (see repro/fl/scenarios.py) -------------------
#
#   new' = agg + gamma * (new - agg) + sigma * N(0, I)
#
# gamma = scale_gamma < 0 is scaled-gradient model poisoning, gamma = 0 is a
# free-rider republishing the aggregate, sigma > 0 is DP noise.  gamma=1 /
# sigma=0 is the identity only ALGEBRAICALLY (a + 1*(l-a) reorders the float
# ops), so callers skip unaffected dispatches entirely and the stacked
# program re-selects unaffected rows' original bits below.


def _perturb_key(seed: int, client: int, seq: int):
    """One PRNG key per (scenario seed, client, per-client update seq) —
    shared by the single and stacked programs, so they agree bit-for-bit."""
    key = jax.random.PRNGKey(seed)
    return jax.random.fold_in(jax.random.fold_in(key, client), seq)


def _perturb_tree(params, agg, gamma, sigma, key):
    leaves, treedef = jax.tree_util.tree_flatten(params)
    agg_leaves = jax.tree_util.tree_leaves(agg)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, leaf, a in zip(keys, leaves, agg_leaves):
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            out.append(leaf)
            continue
        v = a + gamma * (leaf - a)
        out.append(v + sigma * jax.random.normal(k, leaf.shape, leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


_PERTURB_ONE = jax.jit(_perturb_tree)
_PERTURB_STACKED = jax.jit(jax.vmap(_perturb_tree))


def perturb_update(agg, new, plan: dict, k: int):
    """Apply row ``k`` of a :meth:`repro.fl.scenarios.Scenario.update_plan`
    to one trained model (the sequential path / windows of one)."""
    key = _perturb_key(plan["seed"], int(plan["clients"][k]),
                       int(plan["seqs"][k]))
    return _PERTURB_ONE(new, agg, jnp.float32(plan["gammas"][k]),
                        jnp.float32(plan["sigmas"][k]), key)


def perturb_cohort_stacked_trees(agg_stacked, new_stacked, plan: dict):
    """Whole-window transform as ONE vmapped jitted program over the stacked
    K-client pytrees, then a per-leaf select that restores unaffected rows'
    exact bits (fault injection must not perturb honest clients)."""
    keys = jnp.stack([_perturb_key(plan["seed"], int(c), int(s))
                      for c, s in zip(plan["clients"], plan["seqs"])])
    transformed = _PERTURB_STACKED(new_stacked, agg_stacked,
                                   jnp.asarray(plan["gammas"]),
                                   jnp.asarray(plan["sigmas"]), keys)
    keep = jnp.asarray(plan["affected"])
    return jax.tree_util.tree_map(
        lambda t, o: jnp.where(
            keep.reshape(keep.shape + (1,) * (t.ndim - 1)), t, o),
        transformed, new_stacked)


def _conv_as_matmul(x, w):
    """SAME-padding stride-1 convolution as im2col + one GEMM.

    ``jax.vmap`` over per-client kernels turns ``lax.conv`` into a
    batch-grouped convolution that XLA:CPU executes on a slow generic path
    (measured ~2x slower than K serial convs).  The same contraction phrased
    as a matmul vmaps into a single batched GEMM — the fast path on CPU
    (Eigen) and the MXU-native form on TPU.  Math is identical to
    ``lax.conv_general_dilated`` up to float summation order.
    """
    kh, kw, cin, cout = w.shape
    ph, pw = kh // 2, kw // 2
    b, h, ww, c = x.shape
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    # (B, H, W, kh*kw, C): taps ordered (kh, kw) row-major to match the
    # HWIO kernel layout flattened as (kh*kw*cin, cout)
    patches = jnp.stack([xp[:, i:i + h, j:j + ww, :]
                         for i in range(kh) for j in range(kw)], axis=3)
    patches = patches.reshape(b * h * ww, kh * kw * c)
    y = patches @ w.reshape(kh * kw * cin, cout)
    return y.reshape(b, h, ww, cout)


def _max_pool_2x2(x):
    b, h, w, c = x.shape
    x = x[:, :h // 2 * 2, :w // 2 * 2]        # VALID-window truncation
    x = x.reshape(b, h // 2, 2, w // 2, 2, c)
    return jnp.max(x, axis=(2, 4))


# ---------------------------------------------------------------------------
# per-backend cohort program suites
# ---------------------------------------------------------------------------


class CohortPrograms:
    """Batched train/eval/signature program suite for one backend family.

    :class:`CohortBackend` supplies the *execution* discipline — stacking,
    padding, masking, vmap/lax.map fusion, jit, mesh ``shard_map`` — and
    delegates everything backend-specific to this interface.  A suite owns:

    traced (called inside the engine's jitted programs):
      * ``loss(params, x, y)``            scalar training loss on one batch
      * ``sum_loss(params, x, y, w, denom)``  sum-form loss: row-weighted
        loss sum over ``denom`` (the GLOBAL weighted count in this suite's
        loss units), so a psum over a data mesh axis reconstructs ``loss``
        on the full batch; must equal ``loss`` when ``w`` is all-ones and
        ``denom`` the local count
      * ``loss_denom(w, y)``              local count in loss units for a
        row-weight vector ``w`` (samples for CNN, tokens for LM)
      * ``eval_terms(params, xs, ys, ms)``   (num, den) masked-accuracy
        terms on one shard; ``masked_eval`` = num / max(den, 1)
      * ``eval_shared_terms(params, x, y, mask)``  (num (K,), den (K,))
        terms for ONE model on K stacked shards
      * ``sample_signature(params, xs)``  per-sample Eq. 3 signature rows,
        so the engine can take a padding-masked mean

    host-side (batch assembly, matching the sequential RNG streams exactly):
      * ``train_steps(ds, epochs)``       step count one client will run
      * ``client_batches(ds, seed, epochs)``  (xb (T, ...), yb (T, ...))
      * ``eval_single(ds, limit, kind)``  (x (n, ...), y, n) for one shard;
        ``kind`` is "eval" or "sig" (suites whose two paths sample
        differently — the LM backend — return different tokens per kind)
      * ``summarize_losses(losses, steps, epochs)``  the sequential path's
        per-client loss contract
      * ``evaluate_one(params, ds, limit)``  sequential single-model eval
        (the M=1 fast path of ``evaluate_many``)
    """

    backend_cls: Type = None
    # lax.map (dispatch fusion, per-iteration lowering kept) vs jax.vmap
    # (arithmetic batching) for the eval/signature programs: convs vmap onto
    # XLA:CPU's slow grouped path, transformers vmap onto batched GEMMs
    vmap_eval: bool = False
    # below this many candidate models, evaluate_many runs the sequential
    # per-model program: the pow2 model-axis padding + tree_stack overhead
    # outweigh fusion for tiny sweeps (suite-specific dispatch economics)
    eval_many_min_batch: int = 1

    def __init__(self, backend, kernel_policy: Optional[str] = None):
        self.backend = backend
        self.cfg = backend.cfg
        # concrete kernel policy for the suite's Eq. 3 hot paths: an explicit
        # argument wins, else inherit the backend's (backends without the
        # knob mean the incumbent pure-jnp math)
        if kernel_policy is None:
            self.kernel_policy = getattr(backend, "kernel_policy", "reference")
        else:
            from repro.kernels.dispatch import resolve_policy
            self.kernel_policy = resolve_policy(kernel_policy)

    @property
    def default_epochs(self) -> int:
        raise NotImplementedError

    # traced
    def loss(self, params, x, y):
        raise NotImplementedError

    def sum_loss(self, params, x, y, w, denom):
        raise NotImplementedError

    def loss_denom(self, w, y):
        raise NotImplementedError

    def eval_terms(self, params, xs, ys, ms):
        raise NotImplementedError

    def eval_shared_terms(self, params, x, y, mask):
        raise NotImplementedError

    def masked_eval(self, params, xs, ys, ms):
        """Masked accuracy on one shard — the division placed after the
        suite's sum-form terms (same math the 2-D data-mesh path psums)."""
        num, den = self.eval_terms(params, xs, ys, ms)
        return num / jnp.maximum(den, 1.0)

    def eval_shared(self, params, x, y, mask):
        """ONE model on K stacked shards, via the sum-form terms."""
        num, den = self.eval_shared_terms(params, x, y, mask)
        return num / jnp.maximum(den, 1.0)

    def sample_signature(self, params, xs):
        raise NotImplementedError

    # host-side
    def train_steps(self, ds, epochs: int) -> int:
        raise NotImplementedError

    def client_batches(self, ds, seed: int, epochs: int):
        raise NotImplementedError

    def eval_single(self, ds, limit: int, kind: str):
        raise NotImplementedError

    def summarize_losses(self, losses: np.ndarray, steps: Sequence[int],
                         epochs: int) -> List[float]:
        raise NotImplementedError

    def evaluate_one(self, params, ds, limit: int) -> float:
        raise NotImplementedError


class CNNCohortPrograms(CohortPrograms):
    """VGG-family programs (the paper's experimental setup).

    Training runs the matmul-form forward (`_conv_as_matmul`) so the vmapped
    cohort step lowers to batched GEMMs; evaluation and signatures keep the
    dense-conv lowering per client and rely on ``lax.map`` dispatch fusion
    (see the engine's ``_eval_impl`` note).
    """

    backend_cls = CNNBackend

    @property
    def default_epochs(self) -> int:
        return self.backend.local_epochs

    def _forward(self, params, x):
        """``cnn_forward`` in matmul form (see :func:`_conv_as_matmul`)."""
        for stack_params in params["convs"]:
            for p in stack_params:
                x = jax.nn.relu(_conv_as_matmul(x, p["w"]) + p["b"])
            x = _max_pool_2x2(x)
        x = x.reshape(x.shape[0], -1)
        for p in params["fcs"][:-1]:
            x = jax.nn.relu(x @ p["w"] + p["b"])
        p = params["fcs"][-1]
        return x @ p["w"] + p["b"]

    def _sample_losses(self, params, x, y):
        """(B,) per-sample cross-entropy in matmul form."""
        logits = self._forward(params, x)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return logz - ll

    def loss(self, params, x, y):
        return jnp.mean(self._sample_losses(params, x, y))

    def sum_loss(self, params, x, y, w, denom):
        """Row-weighted loss sum over the GLOBAL sample count: psum over a
        data mesh axis reconstructs the full-batch ``loss`` exactly."""
        return jnp.sum(self._sample_losses(params, x, y) * w) / denom

    def loss_denom(self, w, y):
        return jnp.sum(w)

    def eval_terms(self, params, xs, ys, ms):
        """Masked #correct terms on one shard, conv-form forward: eval is
        FLOP-light and per-client weights make a vmapped conv lower to
        XLA:CPU's slow grouped path, so dense-conv + dispatch fusion wins
        over arithmetic batching here."""
        from repro.models import cnn as cnn_mod
        logits, _ = cnn_mod.cnn_forward(params, xs, self.cfg)
        correct = (jnp.argmax(logits, -1) == ys).astype(jnp.float32)
        return jnp.sum(correct * ms), jnp.sum(ms)

    def eval_shared_terms(self, params, x, y, mask):
        """ONE model on K padded shards (publisher's convergence monitor).
        The params carry no cohort axis, so the K shards simply fold into
        the batch dimension of the conv-form forward — true batching."""
        from repro.models import cnn as cnn_mod
        k, n = y.shape
        flat = x.reshape((k * n,) + x.shape[2:])
        logits, _ = cnn_mod.cnn_forward(params, flat, self.cfg)
        correct = (jnp.argmax(logits.reshape(k, n, -1), -1) == y)
        correct = correct.astype(jnp.float32) * mask
        return jnp.sum(correct, axis=1), jnp.sum(mask, axis=1)

    def sample_signature(self, params, x):
        """Per-sample Eq. 3 zero fractions, conv-form, EARLY EXIT: only the
        convs up to ``signature_layer`` run — the classifier head and later
        stacks contribute nothing to the signature."""
        cfg = self.cfg
        conv_idx = 0
        for stack_params in params["convs"]:
            for p in stack_params:
                x = jax.lax.conv_general_dilated(
                    x, p["w"], window_strides=(1, 1), padding="SAME",
                    dimension_numbers=("NHWC", "HWIO", "NHWC"))
                x = jax.nn.relu(x + p["b"])
                if conv_idx == cfg.signature_layer:
                    # per-sample zero fractions through the kernel dispatch
                    # layer ("reference" -> the incumbent jnp.mean bits)
                    from repro.kernels import ops as kops
                    return kops.signature_per_channel(
                        x, tau=0.0, policy=self.kernel_policy)    # (N, ch)
                conv_idx += 1
            x = _max_pool_2x2(x)
        raise ValueError(f"signature_layer {cfg.signature_layer} out of "
                         f"range for {cfg.name}")

    def train_steps(self, ds, epochs: int) -> int:
        b = self.backend
        return epochs * max(len(ds) // b.batch_size, 1)

    def client_batches(self, ds, seed: int, epochs: int):
        """Replicates ``CNNBackend.train_local``'s exact per-client batch
        sampling (same np RNG stream per seed)."""
        b = self.backend
        rng = np.random.default_rng(seed)
        xs, ys = [], []
        for _ in range(epochs):
            xb, yb = b._batches(ds, rng)
            xs.append(xb)
            ys.append(yb)
        return jnp.concatenate(xs), jnp.concatenate(ys)

    def eval_single(self, ds, limit: int, kind: str):
        n = min(len(ds), limit)
        return jnp.asarray(ds.x[:n]), jnp.asarray(ds.y[:n]), n

    def summarize_losses(self, losses, steps, epochs) -> List[float]:
        """Sequential contract: mean loss over the client's LAST epoch."""
        per_epoch = [s // epochs for s in steps]
        return [float(np.mean(losses[i, s - per_epoch[i]:s]))
                for i, s in enumerate(steps)]

    def evaluate_one(self, params, ds, limit: int) -> float:
        return self.backend.evaluate(params, ds, limit)


class LMCohortPrograms(CohortPrograms):
    """Transformer (``LMBackend``) programs: the framework-scale path.

    Training vmaps the per-client SGD scan over the stacked param pytrees —
    the transformer step is already GEMM-shaped, so unlike the CNN path no
    lowering rewrite is needed; the win is one fused dispatch (and one
    shard_map program under a mesh) instead of K serial jitted calls.  Token
    batches are pre-sampled on the host with the SAME np RNG stream as
    ``LMBackend.train_local``/``evaluate``/``signature``, so cohort and
    sequential runs see identical data.  Signatures are the Eq. 3
    threshold-zero fractions of the designated signature layer (the
    final-norm hidden state, matching ``Runtime.want_signature``), computed
    per sample so the engine's padding mask keeps padded rows out.
    """

    backend_cls = LMBackend
    vmap_eval = True            # transformer forwards vmap onto batched GEMMs
    eval_many_min_batch = 3

    def __init__(self, backend, kernel_policy: Optional[str] = None):
        super().__init__(backend, kernel_policy)
        import dataclasses
        # eval/signature forwards don't need the fused aux signature (we
        # compute per-sample rows ourselves for maskability); the suite's
        # kernel policy decides whether they run the Pallas hot paths
        use_pallas = self.kernel_policy != "reference"
        self.runtime = dataclasses.replace(
            backend.runtime, want_signature=False, use_pallas=use_pallas,
            kernel_policy=self.kernel_policy)
        # per-sample Eq. 3 rows read tau/dims off this one (keeps the
        # backend's want_signature semantics but the suite's policy)
        self.sig_runtime = dataclasses.replace(
            backend.runtime, use_pallas=use_pallas,
            kernel_policy=self.kernel_policy)
        # the batched train step drops remat: rematerialization trades
        # compute for activation memory, the right call for production-size
        # models but pure overhead for FL-size ones (~1.3x extra forward
        # FLOPs); gradients are bit-comparable either way, which the
        # cohort-vs-sequential property tests pin down.  Training always
        # stays on the stock-XLA path: pallas_call has no VJP rule.
        self.train_runtime = dataclasses.replace(self.runtime, remat=False,
                                                 use_pallas=False)

    @property
    def default_epochs(self) -> int:
        return self.backend.local_steps

    def loss(self, params, x, y):
        """x (B, S+1) token rows; y (B, S) = x[:, 1:] (next-token labels)."""
        from repro.models import transformer as tfm
        batch = {"tokens": x[:, :-1], "labels": y}
        return tfm.loss_fn(params, batch, self.cfg, self.train_runtime)[0]

    def sum_loss(self, params, x, y, w, denom):
        """Row-weighted token-CE sum over the GLOBAL token count ``denom``
        (+ the MoE aux weighted by the local token fraction, so dense
        models — aux 0 — psum to exactly the full-batch ``loss`` and MoE
        models psum to the count-weighted mean of per-shard auxes)."""
        from repro.models import transformer as tfm
        m = jnp.broadcast_to(w[:, None], y.shape).astype(jnp.float32)
        batch = {"tokens": x[:, :-1], "labels": y, "mask": m}
        total, _ = tfm.loss_fn(params, batch, self.cfg, self.train_runtime)
        return total * jnp.sum(m) / denom

    def loss_denom(self, w, y):
        return jnp.sum(w) * y.shape[-1]

    def _row_correct(self, params, xs, ys):
        """(N, S) correctness grid for a padded token shard."""
        from repro.models import transformer as tfm
        logits, _, _ = tfm.forward(params, {"tokens": xs[:, :-1]}, self.cfg,
                                   self.runtime, mode="prefill")
        return (jnp.argmax(logits, -1) == ys).astype(jnp.float32)

    def eval_terms(self, params, xs, ys, ms):
        """Per-row next-token accuracy terms, padding-masked over rows.
        Rows all carry ``seq_len`` real positions, so the masked mean of
        row means equals the sequential path's grand mean."""
        per_row = jnp.mean(self._row_correct(params, xs, ys), axis=-1)
        return jnp.sum(per_row * ms), jnp.sum(ms)

    def eval_shared_terms(self, params, x, y, mask):
        """ONE model on K stacked token shards: fold K into the batch dim —
        true batching, same as the CNN suite."""
        k, n = x.shape[0], x.shape[1]
        flat = x.reshape((k * n,) + x.shape[2:])
        correct = self._row_correct(params, flat, y.reshape((k * n,) +
                                                            y.shape[2:]))
        per_row = jnp.mean(correct, axis=-1).reshape(k, n) * mask
        return jnp.sum(per_row, axis=1), jnp.sum(mask, axis=1)

    def sample_signature(self, params, xs):
        """(N, sig_dims) Eq. 3 rows from the designated signature layer."""
        from repro.models import transformer as tfm
        h, _, _ = tfm.forward_hidden(params, {"tokens": xs[:, :-1]}, self.cfg,
                                     self.runtime, mode="prefill")
        return tfm.per_sample_signature(h, self.sig_runtime)

    def train_steps(self, ds, epochs: int) -> int:
        # one step per "epoch" regardless of stream length (LMBackend
        # samples `epochs` fixed-size token batches)
        return epochs

    def client_batches(self, ds, seed: int, epochs: int):
        """Same np RNG stream as ``LMBackend.train_local``: one
        ``_sample`` call drawing (epochs, B, S+1) token windows."""
        toks = self.backend._sample(ds, np.random.default_rng(seed), epochs)
        return toks, toks[:, :, 1:]

    # sequential LMBackend.evaluate/signature fix their sampling seeds
    _EVAL_SEEDS = {"eval": 1, "sig": 2}

    def eval_single(self, ds, limit: int, kind: str):
        toks = self.backend._sample(ds, np.random.default_rng(
            self._EVAL_SEEDS[kind]), 1)[0]
        return toks, toks[:, 1:], int(toks.shape[0])

    def summarize_losses(self, losses, steps, epochs) -> List[float]:
        """Sequential contract: mean loss over ALL the client's steps."""
        return [float(np.mean(losses[i, :s])) for i, s in enumerate(steps)]

    def evaluate_one(self, params, ds, limit: int) -> float:
        return self.backend.evaluate(params, ds)


_PROGRAM_REGISTRY: List[Type[CohortPrograms]] = []


def register_cohort_programs(programs_cls: Type[CohortPrograms]) -> None:
    """Register a program suite; later registrations win on overlap."""
    if not isinstance(getattr(programs_cls, "backend_cls", None), type):
        raise TypeError(
            f"{programs_cls.__name__}.backend_cls must name the backend "
            "class the suite batches for")
    _PROGRAM_REGISTRY.insert(0, programs_cls)


register_cohort_programs(CNNCohortPrograms)
register_cohort_programs(LMCohortPrograms)


def _programs_for(backend) -> Optional[Type[CohortPrograms]]:
    for cls in _PROGRAM_REGISTRY:
        if isinstance(backend, cls.backend_cls):
            return cls
    return None


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class CohortBackend:
    """Batched train/eval/signature over a stacked K-client pytree.

    Wraps a per-client backend; ``capacity`` fixes the cohort axis so every
    flush compiles to the same program (short cohorts are padded with a
    repeat of the last client and fully masked out).  The backend-specific
    programs come from the :class:`CohortPrograms` registry.
    """

    def __init__(self, backend, capacity: Optional[int] = None,
                 eval_pad_quantum: int = 64, mesh=None,
                 clients_axis: str = "clients", data_axis: str = "data",
                 eval_cache_entries: int = 64, overlap: bool = True,
                 kernel_policy: Optional[str] = None):
        programs_cls = _programs_for(backend)
        if programs_cls is None:
            raise TypeError(
                f"no CohortPrograms registered for {type(backend).__name__}; "
                f"known: {[c.backend_cls.__name__ for c in _PROGRAM_REGISTRY]}")
        # third-party suites registered before the kernel_policy kwarg keep
        # working: only pass it through when the caller asked for one
        if kernel_policy is None:
            self.programs = programs_cls(backend)
        else:
            self.programs = programs_cls(backend, kernel_policy=kernel_policy)
        self.backend = backend
        self.capacity = capacity
        # padding quantum for eval/signature sample axes: shards pad to the
        # next power of two below it and to multiples of it above, keeping
        # the compiled-program count bounded with ragged validation shards
        self.eval_pad_quantum = eval_pad_quantum
        self.cfg = backend.cfg
        self.opt = backend.opt
        # LRU over padded eval/signature buffers: a long-running simulator
        # sweeps many shards; the cap bounds pinned device memory
        self._eval_data_cache: "OrderedDict" = OrderedDict()
        self.eval_cache_entries = max(int(eval_cache_entries), 1)
        # a 1x1 (or absent) mesh degrades to the exact single-device
        # programs — same jit cache, same numerics
        self.clients_axis = clients_axis
        self.data_axis = data_axis
        self.mesh = None
        self._n_data = 1
        n_clients_axis = 1
        if mesh is not None:
            if clients_axis not in mesh.shape:
                raise ValueError(
                    f"mesh axes {tuple(mesh.axis_names)} carry no "
                    f"{clients_axis!r} axis")
            n_clients_axis = int(dict(mesh.shape)[clients_axis])
            n_data = int(dict(mesh.shape).get(data_axis, 1))
            if n_clients_axis > 1 or n_data > 1:
                self.mesh = mesh
                self._n_data = n_data
        self._n_shards = n_clients_axis if self.mesh is not None else 1
        if self.mesh is None:
            self._train_jit = jax.jit(self._train_impl)
            self._train_uniform_jit = jax.jit(self._train_uniform_impl)
            self._eval_jit = jax.jit(self._eval_impl)
            self._eval_shared_jit = jax.jit(self._eval_shared_impl)
            self._eval_many_jit = jax.jit(self._eval_many_impl)
            self._sig_jit = jax.jit(self._sig_impl)
        else:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec
            c, r = PartitionSpec(clients_axis), PartitionSpec()

            def spmd(fn, in_specs, out_specs, check_rep=True):
                """Cohort SPMD: each device runs ``fn`` on its local client
                group (and, on a 2-D mesh, its local sample slice).  On the
                1-D mesh there are no collectives inside — aggregation
                happens in ``repro.core.aggregate``'s psum programs; the
                2-D programs psum their sum-form loss/metric terms over the
                data axis themselves."""
                return jax.jit(shard_map(fn, mesh=self.mesh,
                                         in_specs=in_specs,
                                         out_specs=out_specs,
                                         check_rep=check_rep))

            # pallas_call has no shard_map replication rule, so the
            # eval/signature programs (the ones that run kernels when the
            # suite's policy is not "reference") must opt out of
            # rep-checking; training always stays on the XLA path and
            # keeps the check
            ck = self.programs.kernel_policy == "reference"

            if self._n_data <= 1:
                self._train_jit = spmd(self._train_impl, (c, c, c, c), (c, c))
                self._train_uniform_jit = spmd(self._train_uniform_impl,
                                               (c, c, c), (c, c))
                self._eval_jit = spmd(self._eval_impl, (c, c, c, c), c,
                                      check_rep=ck)
                # shared model replicated, K val shards sharded over clients
                self._eval_shared_jit = spmd(self._eval_shared_impl,
                                             (r, c, c, c), c, check_rep=ck)
                # M candidate models sharded, the one val shard replicated
                self._eval_many_jit = spmd(self._eval_many_impl,
                                           (c, r, r, r), c, check_rep=ck)
                self._sig_jit = spmd(self._sig_impl, (c, c, c), c,
                                     check_rep=ck)
            else:
                # 2-D (clients, data): batch arrays split their sample dim
                # over `data` (dim 2 for train (K, T, B, ...), dim 1 for
                # eval (K, N, ...)); params replicate within a client group
                # and the programs psum their sum-form terms over `data`.
                # check_rep is off: the rep-tracking rules in this jax do
                # not cover remat/scan composition, and the psum-restored
                # replication of params is pinned by the equivalence tests.
                d = data_axis
                cb = PartitionSpec(clients_axis, None, d)
                ce = PartitionSpec(clients_axis, d)
                dv = PartitionSpec(d)
                self._train_jit = spmd(self._train2d_impl,
                                       (c, cb, cb, dv, c), (c, c),
                                       check_rep=False)
                self._train_uniform_jit = spmd(self._train2d_uniform_impl,
                                               (c, cb, cb, dv), (c, c),
                                               check_rep=False)
                self._eval_jit = spmd(self._eval2d_impl, (c, ce, ce, ce), c,
                                      check_rep=False)
                self._eval_shared_jit = spmd(self._eval2d_shared_impl,
                                             (r, ce, ce, ce), c,
                                             check_rep=False)
                # M models over clients, the ONE shard's samples over data
                self._eval_many_jit = spmd(self._eval2d_many_impl,
                                           (c, dv, dv, dv), c,
                                           check_rep=False)
                self._sig_jit = spmd(self._sig2d_impl, (c, ce, ce), c,
                                     check_rep=False)
        # host-side window assembly: double-buffered background pipeline
        # (prefetch_window/take) or inline when overlap is off
        from repro.data.pipeline import WindowAssembler
        shardings = None
        if self.mesh is not None:
            from repro.sharding.rules import (cohort_batch_sharding,
                                              data_shard_sharding)
            d_ax = data_axis if self._n_data > 1 else None
            shardings = {
                "batch": cohort_batch_sharding(self.mesh, clients_axis,
                                               d_ax, 2 if d_ax else None),
                "mask": cohort_batch_sharding(self.mesh, clients_axis),
                "bm": (data_shard_sharding(self.mesh, data_axis)
                       if d_ax else None),
            }
        self.assembler = WindowAssembler(self.programs, n_data=self._n_data,
                                         shardings=shardings, overlap=overlap)

    @staticmethod
    def supports(backend) -> bool:
        return _programs_for(backend) is not None

    def register_shards(self, train_shards: Sequence,
                        epochs: Optional[int] = None) -> None:
        """Pre-size the training step-axis pad target from the client
        shards and the epochs the caller will actually train with, so the
        very first flush already compiles the steady-state program.  The
        target must match the real step count: it is monotone, so an
        over-estimate (e.g. the backend's default epochs when the
        coordinator trains fewer) would permanently pad — and compute —
        every cohort scan to the inflated length.  (Eval pad targets are
        per-call: a global target would let one large shard — e.g. the
        final global-test sweep — permanently inflate every small-val-set
        dispatch.)"""
        epochs = epochs or self.programs.default_epochs
        self.assembler.register_shards(train_shards, epochs)

    @property
    def _pad_T(self) -> int:
        """Monotone step-axis pad target (owned by the window assembler)."""
        return self.assembler.pad_T

    def _round_chunk(self, n: int) -> int:
        """Pad target for a sample axis: next power of two below the
        quantum (tiny val shards don't pay quantum-multiple waste), quantum
        multiples above it (bounded compile count either way)."""
        c = self.eval_pad_quantum
        if n >= c:
            return round_up_multiple(n, c)
        return next_pow2(n)

    # -- jitted programs ----------------------------------------------------

    def _train_impl(self, stacked_params, xb, yb, mask):
        """xb (K, T, ...); yb (K, T, ...); mask (K, T) — one vmapped scan:
        the whole cohort advances one SGD step per scan tick."""

        def one_client(params, xs, ys, ms):
            opt_state = self.opt.init(params)

            def step(carry, batch):
                params, opt_state = carry
                x, y, m = batch
                loss, grads = jax.value_and_grad(self.programs.loss)(
                    params, x, y)
                updates, new_opt = self.opt.update(grads, opt_state, params)
                new_params = apply_updates(params, updates)
                params = _tree_select(m, new_params, params)
                opt_state = _tree_select(m, new_opt, opt_state)
                return (params, opt_state), jnp.where(m, loss, 0.0)

            (params, _), losses = jax.lax.scan(
                step, (params, opt_state), (xs, ys, ms))
            return params, losses

        return jax.vmap(one_client)(stacked_params, xb, yb, mask)

    def _train_uniform_impl(self, stacked_params, xb, yb):
        """Mask-free variant for cohorts whose clients all run the SAME
        number of steps (every LM window; CNN windows with equal shard
        geometry): no padded scan ticks exist, so the per-leaf select ops
        — two pytree-wide ``where`` sweeps per step — drop out entirely.
        Cohort-axis padding still composes: padded repeat clients just
        train redundantly and their rows are discarded by the caller."""

        def one_client(params, xs, ys):
            opt_state = self.opt.init(params)

            def step(carry, batch):
                params, opt_state = carry
                x, y = batch
                loss, grads = jax.value_and_grad(self.programs.loss)(
                    params, x, y)
                updates, opt_state = self.opt.update(grads, opt_state, params)
                return (apply_updates(params, updates), opt_state), loss

            (params, _), losses = jax.lax.scan(
                step, (params, opt_state), (xs, ys))
            return params, losses

        return jax.vmap(one_client)(stacked_params, xb, yb)

    def _eval_impl(self, stacked_params, x, y, mask):
        """K models on K padded shards: x (K, N, ...), mask (K, N).

        Fusion style is the program suite's call (``vmap_eval``):
        ``lax.map`` runs the K per-client forwards inside ONE compiled
        program (one dispatch, one sync) keeping each iteration's preferred
        lowering — right for convs, whose vmap form lowers to XLA:CPU's
        slow grouped path; ``jax.vmap`` batches the arithmetic — right for
        transformers, whose vmap form is batched GEMMs."""
        if self.programs.vmap_eval:
            return jax.vmap(self.programs.masked_eval)(
                stacked_params, x, y, mask)
        return jax.lax.map(
            lambda args: self.programs.masked_eval(*args),
            (stacked_params, x, y, mask))

    def _eval_shared_impl(self, params, x, y, mask):
        return self.programs.eval_shared(params, x, y, mask)

    def _eval_many_impl(self, stacked_params, x, y, mask):
        """M models on ONE padded shard (batched tip validation): fused
        per the suite's ``vmap_eval`` style, same as ``_eval_impl``."""
        if self.programs.vmap_eval:
            return jax.vmap(
                lambda p: self.programs.masked_eval(p, x, y, mask))(
                stacked_params)
        return jax.lax.map(
            lambda p: self.programs.masked_eval(p, x, y, mask),
            stacked_params)

    def _sig_impl(self, stacked_params, x, mask):
        """Masked Eq. 3-4 signatures: per-sample zero fractions from the
        programs suite, then a masked mean so padding samples never enter
        the signature."""

        def one(params, xs, ms):
            zf = self.programs.sample_signature(params, xs)
            w = ms[:, None]
            return jnp.sum(zf * w, axis=0) / jnp.maximum(jnp.sum(w), 1.0)

        if self.programs.vmap_eval:
            return jax.vmap(one)(stacked_params, x, mask)
        return jax.lax.map(lambda args: one(*args), (stacked_params, x, mask))

    # -- 2-D (clients, data) programs: sample dims sharded over `data`,
    # sum-form terms psum'd back so every device in a client group sees the
    # full-batch gradient / metric — the models stay in lockstep ------------

    def _train2d_impl(self, stacked_params, xb, yb, bm, mask):
        """xb (K, T, B_local, ...); bm (B_local,) batch-row weights; mask
        (K, T) step mask.  Per step: grads of the sum-form loss on the
        local sample slice, one psum over `data` per (grads, loss) — the
        full-batch SGD step, computed D ways."""
        ax = self.data_axis
        denom = jax.lax.psum(self.programs.loss_denom(bm, yb[0, 0]), ax)

        def one_client(params, xs, ys, ms):
            opt_state = self.opt.init(params)

            def step(carry, batch):
                params, opt_state = carry
                x, y, m = batch
                loss, grads = jax.value_and_grad(
                    lambda p: self.programs.sum_loss(p, x, y, bm, denom))(
                    params)
                loss = jax.lax.psum(loss, ax)
                grads = jax.lax.psum(grads, ax)
                updates, new_opt = self.opt.update(grads, opt_state, params)
                new_params = apply_updates(params, updates)
                params = _tree_select(m, new_params, params)
                opt_state = _tree_select(m, new_opt, opt_state)
                return (params, opt_state), jnp.where(m, loss, 0.0)

            (params, _), losses = jax.lax.scan(
                step, (params, opt_state), (xs, ys, ms))
            return params, losses

        return jax.vmap(one_client)(stacked_params, xb, yb, mask)

    def _train2d_uniform_impl(self, stacked_params, xb, yb, bm):
        """Mask-free data-sharded variant (see ``_train_uniform_impl``)."""
        ax = self.data_axis
        denom = jax.lax.psum(self.programs.loss_denom(bm, yb[0, 0]), ax)

        def one_client(params, xs, ys):
            opt_state = self.opt.init(params)

            def step(carry, batch):
                params, opt_state = carry
                x, y = batch
                loss, grads = jax.value_and_grad(
                    lambda p: self.programs.sum_loss(p, x, y, bm, denom))(
                    params)
                loss = jax.lax.psum(loss, ax)
                grads = jax.lax.psum(grads, ax)
                updates, opt_state = self.opt.update(grads, opt_state, params)
                return (apply_updates(params, updates), opt_state), loss

            (params, _), losses = jax.lax.scan(
                step, (params, opt_state), (xs, ys))
            return params, losses

        return jax.vmap(one_client)(stacked_params, xb, yb)

    def _eval2d_terms(self, fn, args):
        """Fused per-client terms + one psum pair over `data`."""
        if self.programs.vmap_eval:
            num, den = jax.vmap(fn)(*args)
        else:
            num, den = jax.lax.map(lambda a: fn(*a), args)
        num = jax.lax.psum(num, self.data_axis)
        den = jax.lax.psum(den, self.data_axis)
        return num / jnp.maximum(den, 1.0)

    def _eval2d_impl(self, stacked_params, x, y, mask):
        """K models on K shards, samples sharded over `data`: local terms,
        psum, divide — the masked mean over each client's FULL shard."""
        return self._eval2d_terms(self.programs.eval_terms,
                                  (stacked_params, x, y, mask))

    def _eval2d_shared_impl(self, params, x, y, mask):
        num, den = self.programs.eval_shared_terms(params, x, y, mask)
        num = jax.lax.psum(num, self.data_axis)
        den = jax.lax.psum(den, self.data_axis)
        return num / jnp.maximum(den, 1.0)

    def _eval2d_many_impl(self, stacked_params, x, y, mask):
        """M models over `clients`, the ONE shard's samples over `data`."""

        def one(p):
            return self.programs.eval_terms(p, x, y, mask)

        if self.programs.vmap_eval:
            num, den = jax.vmap(one)(stacked_params)
        else:
            num, den = jax.lax.map(one, stacked_params)
        num = jax.lax.psum(num, self.data_axis)
        den = jax.lax.psum(den, self.data_axis)
        return num / jnp.maximum(den, 1.0)

    def _sig2d_impl(self, stacked_params, x, mask):
        """Masked signatures with samples sharded over `data`."""
        ax = self.data_axis

        def one(params, xs, ms):
            zf = self.programs.sample_signature(params, xs)
            w = ms[:, None]
            return jnp.sum(zf * w, axis=0), jnp.sum(w)

        if self.programs.vmap_eval:
            num, den = jax.vmap(one)(stacked_params, x, mask)
        else:
            num, den = jax.lax.map(lambda a: one(*a),
                                   (stacked_params, x, mask))
        num = jax.lax.psum(num, ax)
        den = jax.lax.psum(den, ax)
        return num / jnp.maximum(den[:, None], 1.0)

    # -- host-side batch assembly -------------------------------------------
    # (window sampling/stacking/padding/device_put lives in
    # repro.data.pipeline.WindowAssembler so it can run double-buffered on
    # a background thread; the engine owns only the pad-target policy)

    def _cohort_target(self, k: int) -> int:
        """Cohort-axis pad target: next power of two (capped at
        ``capacity``) so short cohorts waste at most 2x compute while the
        jit cache stays bounded at log2(capacity) programs per shape
        family; under a mesh it additionally rounds up to a multiple of the
        clients-axis size, so the shard_map groups divide evenly for any
        ragged cohort."""
        target = next_pow2(k)
        if self.capacity is not None:
            target = min(max(target, 1), max(self.capacity, k))
        if self._n_shards > 1:
            target = round_up_multiple(target, self._n_shards)
        return max(target, k)

    def _pad_params(self, stacked, k: int, target: int):
        """Pad a stacked K-client pytree's client axis with repeats of the
        last client (fully masked / discarded downstream)."""
        if k >= target:
            return stacked
        reps = target - k
        return jax.tree_util.tree_map(
            lambda leaf: jnp.concatenate(
                [leaf, jnp.repeat(leaf[-1:], reps, axis=0)]), stacked)

    def prefetch_window(self, datasets: Sequence, seeds: Sequence[int],
                        epochs: Optional[int] = None) -> None:
        """Start assembling the given window's training batch on the
        assembler's background thread (sampling, stacking, padding,
        ``device_put``) so it overlaps whatever the device is running —
        the previous window, the Eq. 6 aggregation, tip validation.  The
        matching ``train_cohort_stacked`` call collects it; a mismatched or
        absent prefetch silently assembles inline (identical numerics — the
        per-seed np RNG streams don't depend on where sampling runs)."""
        epochs = epochs or self.programs.default_epochs
        self.assembler.prefetch(datasets, seeds, epochs,
                                self._cohort_target(len(datasets)))

    def _pad_cohort(self, stacked, xb, yb, mask):
        """Pad the cohort axis (see ``_cohort_target``) with fully-masked
        repeats — the eval/signature-path twin of the assembler's
        client-axis padding."""
        k = int(mask.shape[0])
        target = self._cohort_target(k)
        if k >= target:
            return stacked, xb, yb, mask, k
        reps = target - k
        stacked = jax.tree_util.tree_map(
            lambda leaf: jnp.concatenate(
                [leaf, jnp.repeat(leaf[-1:], reps, axis=0)]), stacked)
        xb = jnp.concatenate([xb, jnp.repeat(xb[-1:], reps, axis=0)])
        yb = jnp.concatenate([yb, jnp.repeat(yb[-1:], reps, axis=0)])
        mask = jnp.concatenate(
            [mask, jnp.zeros((reps,) + mask.shape[1:], mask.dtype)])
        return stacked, xb, yb, mask, k

    def _eval_arrays(self, datasets: Sequence, limit: int,
                     kind: str = "eval"):
        """Padded (x, y, mask) for a tuple of shards.  Per-DATASET LRU
        caching: each shard is padded to its own rounded size once; per call
        we stack the cached singles (topping up to the call-wide max if the
        batch mixes sizes), so arbitrary cohort compositions — the monitor's
        full val-set sweep, a window's subset — reuse the same buffers while
        the cache stays bounded at ``eval_cache_entries``."""
        singles, ns = [], []
        for ds in datasets:
            key = (id(ds), limit, kind)
            hit = self._eval_data_cache.get(key)
            if hit is None:
                x1, y1, n = self.programs.eval_single(ds, limit, kind)
                own = self._round_chunk(n)
                x1 = pad_leading(jnp.asarray(x1), own)
                y1 = pad_leading(jnp.asarray(y1), own)
                m1 = (jnp.arange(own) < n).astype(jnp.float32)
                # hold ds so the id() key stays unique for our lifetime
                hit = (ds, x1, y1, m1, n)
                self._eval_data_cache[key] = hit
            else:
                self._eval_data_cache.move_to_end(key)
            singles.append(hit)
            ns.append(hit[4])
        # evict AFTER the batch, clamped to the call's own width: evicting
        # inside the loop would let one wide sweep (e.g. the monitor's
        # n_clients val sets with n_clients > the cap) evict its own
        # entries mid-call and turn the cache into pure overhead
        cap = max(self.eval_cache_entries, len(datasets))
        while len(self._eval_data_cache) > cap:
            self._eval_data_cache.popitem(last=False)
        target = max(self._round_chunk(n) for n in ns)
        if self._n_data > 1:
            # sample axes shard over the data mesh axis: pad to a multiple
            # (masked rows, so the extra padding never enters a mean)
            target = round_up_multiple(target, self._n_data)
        x = jnp.stack([pad_leading(s[1], target) for s in singles])
        y = jnp.stack([pad_leading(s[2], target) for s in singles])
        mask = jnp.stack([pad_leading(s[3], target) for s in singles])
        return x, y, mask

    # -- public API ----------------------------------------------------------

    def train_cohort_stacked(self, stacked_params, datasets, seeds,
                             epochs: Optional[int] = None):
        """Train K clients as one program; returns (stacked params, losses).

        ``losses[k]`` matches the sequential path's per-backend contract
        (see ``CohortPrograms.summarize_losses``).
        """
        epochs = epochs or self.programs.default_epochs
        k = len(datasets)
        target = self._cohort_target(k)
        # collect the prefetched window (or assemble inline): batches are
        # already stacked, padded (steps / cohort / data-multiple batch
        # rows) and — under a mesh — device_put with the final layout, so
        # every host->mesh transfer happens once instead of bouncing
        # through device 0
        win = self.assembler.take(datasets, seeds, epochs, target)
        stacked_params = self._pad_params(stacked_params, k, target)
        if self.mesh is not None:
            from repro.sharding.rules import stacked_client_shardings
            stacked_params = jax.device_put(
                stacked_params, stacked_client_shardings(
                    stacked_params, self.mesh, self.clients_axis,
                    data_axis=self.data_axis if self._n_data > 1 else None))
        # mask-free fast path when no step padding exists: every client
        # (and therefore every cohort-padding repeat) runs exactly _pad_T
        # steps, so the masked and uniform programs are the same math
        if self._n_data > 1:
            if win.uniform:
                new_params, losses = self._train_uniform_jit(
                    stacked_params, win.xb, win.yb, win.bm)
            else:
                new_params, losses = self._train_jit(
                    stacked_params, win.xb, win.yb, win.bm, win.mask)
        elif win.uniform:
            new_params, losses = self._train_uniform_jit(stacked_params,
                                                         win.xb, win.yb)
        else:
            new_params, losses = self._train_jit(stacked_params, win.xb,
                                                 win.yb, win.mask)
        losses = np.asarray(losses)
        final = self.programs.summarize_losses(losses, win.steps, epochs)
        if k < losses.shape[0]:
            new_params = jax.tree_util.tree_map(lambda l: l[:k], new_params)
        return new_params, final

    def train_cohort(self, params_list, datasets, seeds,
                     epochs: Optional[int] = None):
        stacked, losses = self.train_cohort_stacked(
            tree_stack(params_list), datasets, seeds, epochs)
        return tree_unstack(stacked), losses

    def evaluate_cohort_stacked(self, stacked_params, datasets,
                                limit: int = 512) -> List[float]:
        """K models, each on its own (ragged) shard."""
        x, y, mask = self._eval_arrays(datasets, limit)
        k = x.shape[0]
        stacked_params, x, y, mask, k = self._pad_cohort(
            stacked_params, x, y, mask)
        accs = self._eval_jit(stacked_params, x, y, mask)
        return [float(a) for a in np.asarray(accs)[:k]]

    def evaluate_cohort(self, params_list, datasets,
                        limit: int = 512) -> List[float]:
        return self.evaluate_cohort_stacked(tree_stack(params_list), datasets,
                                            limit)

    def evaluate_shared(self, params, datasets, limit: int = 512
                        ) -> List[float]:
        """One model on K shards in one dispatch (publisher's monitor)."""
        x, y, mask = self._eval_arrays(datasets, limit)
        k = int(x.shape[0])
        if self._n_shards > 1 and k % self._n_shards:
            t = round_up_multiple(k, self._n_shards)
            x, y, mask = pad_leading(x, t), pad_leading(y, t), \
                pad_leading(mask, t)
        accs = self._eval_shared_jit(params, x, y, mask)
        return [float(a) for a in np.asarray(accs)[:k]]

    def evaluate_many(self, params_list, ds, limit: int = 512) -> List[float]:
        """M candidate models on one validation shard (tip selection).

        The model axis is padded to the next power of two (with repeats) so
        repeated tip sweeps reuse a handful of compiled programs.
        """
        m = len(params_list)
        if m == 0:
            return []
        if m <= self.programs.eval_many_min_batch:
            # tiny sweeps: the backend's own jitted program wins — no
            # stacking, no pow2 model-axis padding, and it shares the
            # sequential jit cache (threshold is suite-specific)
            return [self.programs.evaluate_one(p, ds, limit)
                    for p in params_list]
        m_pad = next_pow2(m)
        if self._n_shards > 1:
            m_pad = round_up_multiple(m_pad, self._n_shards)
        padded = list(params_list) + [params_list[-1]] * (m_pad - m)
        # sample axis padded to the shared eval target: compilations stay
        # bounded at log2(M) programs even with ragged validation shards
        x, y, mask = self._eval_arrays([ds], limit)
        accs = self._eval_many_jit(tree_stack(padded), x[0], y[0], mask[0])
        return [float(a) for a in np.asarray(accs)[:m]]

    def signature_cohort_stacked(self, stacked_params, datasets,
                                 limit: int = 128) -> np.ndarray:
        """(K, dims) Eq. 3 signatures, one masked batched dispatch."""
        x, _, mask = self._eval_arrays(datasets, limit, kind="sig")
        # pass mask in the label slot: _pad_cohort pads a (K, N) array there,
        # not a second full copy of the (K, N, ...) sample batch
        stacked_params, x, _, mask, k = self._pad_cohort(
            stacked_params, x, mask, mask)
        sigs = self._sig_jit(stacked_params, x, mask)
        return np.asarray(sigs)[:k]

    def signature_cohort(self, params_list, datasets,
                         limit: int = 128) -> np.ndarray:
        return self.signature_cohort_stacked(tree_stack(params_list),
                                             datasets, limit)

    def perturb_cohort_stacked(self, agg_stacked, new_stacked, plan: dict):
        """Scenario fault injection for a whole window (see
        repro/fl/scenarios.py): ``new' = agg + gamma*(new-agg) + sigma*N``
        as one vmapped jitted program; rows the plan marks unaffected keep
        their exact bits."""
        return perturb_cohort_stacked_trees(agg_stacked, new_stacked, plan)


# ---------------------------------------------------------------------------
# engine construction helpers (shared by the coordinator and all baselines)
# ---------------------------------------------------------------------------


def parse_mesh_spec(spec):
    """A mesh spec's (clients, data) request.  Accepts ``"auto"``,
    ``"CxD"`` strings (``"4x2"``, ``"8x1"``, ``"8"``), and 2-tuples whose
    clients slot may be ``"auto"`` (``("auto", 2)``, ``(4, 2)``)."""
    if isinstance(spec, str):
        parts = spec.lower().split("x")
        if len(parts) > 2 or not all(
                p == "auto" or p.isdigit() for p in parts):
            raise ValueError(
                f"mesh must be 'auto', 'CxD' (e.g. '4x2'), a (clients, "
                f"data) tuple, None or a Mesh: {spec!r}")
    elif isinstance(spec, (tuple, list)):
        parts = list(spec)
        if len(parts) != 2:
            raise ValueError(f"mesh tuple must be (clients, data): {spec!r}")
    else:
        raise TypeError(f"unsupported mesh spec: {spec!r}")
    clients = parts[0]
    data = int(parts[1]) if len(parts) > 1 else 1
    if clients != "auto":
        clients = int(clients)
    return clients, data


def resolve_cohort_mesh(mesh, cohort_size: int, clients_axis: str = "clients",
                        data_axis: str = "data"):
    """``"auto"`` -> a clients mesh clamped to this host's devices (never
    raises; 1 device degrades to the single-device engine); ``"CxD"`` (e.g.
    ``"4x2"``) or a ``(clients, data)`` tuple (clients may be ``"auto"`` ->
    ``cohort_size``) -> the 2-D (clients, data) mesh, clamped the same way;
    ``None`` -> single-device; a Mesh -> itself."""
    if mesh is None or hasattr(mesh, "axis_names"):
        return mesh
    clients, data = parse_mesh_spec(mesh)
    if clients == "auto":
        clients = cohort_size
    from repro.launch.mesh import make_cohort_mesh
    return make_cohort_mesh(clients, axis=clients_axis, data=data,
                            data_axis=data_axis)


def build_cohort_engine(backend, train_shards: Sequence, *,
                        cohort_size: int, mesh="auto",
                        clients_axis: str = "clients",
                        data_axis: str = "data",
                        epochs: Optional[int] = None,
                        overlap: bool = True,
                        kernel_policy: Optional[str] = None
                        ) -> Optional[CohortBackend]:
    """One-stop engine construction for any registered backend family:
    resolves the mesh spec (1-D or 2-D, see :func:`resolve_cohort_mesh`),
    builds the engine, and pre-registers the training shards so the first
    flush compiles the steady-state program.  Returns ``None`` when cohort
    execution is off (``cohort_size <= 1``) or the backend has no
    registered program suite — callers then run the sequential path."""
    if cohort_size <= 1 or not CohortBackend.supports(backend):
        return None
    engine = CohortBackend(
        backend, capacity=cohort_size,
        mesh=resolve_cohort_mesh(mesh, cohort_size, clients_axis, data_axis),
        clients_axis=clients_axis, data_axis=data_axis, overlap=overlap,
        kernel_policy=kernel_policy)
    engine.register_shards(train_shards, epochs=epochs)
    return engine
