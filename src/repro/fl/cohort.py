"""Vectorized cohort execution engine: K clients as ONE batched XLA program.

The simulator's event heap decides *when* each client's round runs in
simulated time; this module decides *how* the container executes the work.
Instead of K serial ``train_local`` / ``evaluate`` / ``signature`` calls, a
:class:`CohortBackend` stacks the K clients' parameter pytrees along a
leading client axis (``tree_stack``) and runs local training, evaluation and
signature extraction as single batched jitted programs.  Training — the
FLOP-heavy path — is ``jax.vmap``-batched with the convolutions rewritten
as im2col GEMMs (see ``_conv_as_matmul``); evaluation and signatures are
FLOP-light, so they are ``lax.map``-fused into one dispatch while keeping
the dense-conv lowering per client.

Ragged shards are handled by padding + masking:

  * training: every client's (epochs x n_batches) step sequence is padded to
    a common length ``T``; masked steps compute a gradient on zero-padding
    but the pytree select keeps the pre-step params/optimizer state, so
    padding NEVER leaks into the trained weights.
  * evaluation/signature: sample axes are padded to a common length and the
    accuracy / Eq. 3 zero-fraction means are masked, so padded samples carry
    zero weight.

Shape discipline (CPU/TPU friendly): the cohort axis is padded to powers of
two capped at ``capacity``, the training step axis to a monotone registered
maximum, and eval/signature sample axes to per-call targets quantized by
``eval_pad_quantum`` — so steady-state dispatches hit a bounded set of
compiled programs instead of retracing.

SPMD over a device mesh: passing ``mesh`` (any ``jax.sharding.Mesh`` whose
``clients_axis`` axis has more than one device — see
``repro.launch.mesh.make_cohort_mesh``) turns every batched program into one
``shard_map`` SPMD program: the stacked client axis is sharded over the mesh
so each device runs the vmapped train step (and the lax.map-fused
eval/signature programs) on its own client group, with no cross-device
communication inside a window — client rounds are embarrassingly parallel;
the cross-device work is the window's Eq. 6 aggregation, which
``repro.core.aggregate`` phrases as psum collectives over the same axis.
Cohort padding rounds up to a mesh-size multiple so the groups divide
evenly; masking keeps the padding out of every result exactly as on one
device.  ``mesh=None`` (or a 1-device mesh) is bit-for-bit today's
single-device path.  Extra mesh axes (``data``/``model`` from
``repro.launch.mesh``) compose: these programs only consume ``clients_axis``
and replicate over the rest.

Currently implemented for :class:`repro.fl.backend.CNNBackend` (the
paper-faithful VGG path used by the coordinator, baselines and benchmarks);
``CohortBackend.supports`` lets callers fall back to the sequential path for
other backends.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import (next_pow2, pad_leading, round_up_multiple,
                                  tree_stack, tree_unstack)
from repro.data.synthetic import Dataset
from repro.fl.backend import CNNBackend
from repro.optim.optimizers import apply_updates


def _tree_select(keep, new, old):
    """Per-leaf ``where(keep, new, old)`` — identity step when masked out."""
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(keep, a, b), new, old)


def _conv_as_matmul(x, w):
    """SAME-padding stride-1 convolution as im2col + one GEMM.

    ``jax.vmap`` over per-client kernels turns ``lax.conv`` into a
    batch-grouped convolution that XLA:CPU executes on a slow generic path
    (measured ~2x slower than K serial convs).  The same contraction phrased
    as a matmul vmaps into a single batched GEMM — the fast path on CPU
    (Eigen) and the MXU-native form on TPU.  Math is identical to
    ``lax.conv_general_dilated`` up to float summation order.
    """
    kh, kw, cin, cout = w.shape
    ph, pw = kh // 2, kw // 2
    b, h, ww, c = x.shape
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    # (B, H, W, kh*kw, C): taps ordered (kh, kw) row-major to match the
    # HWIO kernel layout flattened as (kh*kw*cin, cout)
    patches = jnp.stack([xp[:, i:i + h, j:j + ww, :]
                         for i in range(kh) for j in range(kw)], axis=3)
    patches = patches.reshape(b * h * ww, kh * kw * c)
    y = patches @ w.reshape(kh * kw * cin, cout)
    return y.reshape(b, h, ww, cout)


def _max_pool_2x2(x):
    b, h, w, c = x.shape
    x = x[:, :h // 2 * 2, :w // 2 * 2]        # VALID-window truncation
    x = x.reshape(b, h // 2, 2, w // 2, 2, c)
    return jnp.max(x, axis=(2, 4))


class CohortBackend:
    """Batched train/eval/signature over a stacked K-client pytree.

    Wraps a per-client backend; ``capacity`` fixes the cohort axis so every
    flush compiles to the same program (short cohorts are padded with a
    repeat of the last client and fully masked out).
    """

    def __init__(self, backend: CNNBackend, capacity: Optional[int] = None,
                 eval_pad_quantum: int = 64, mesh=None,
                 clients_axis: str = "clients"):
        if not self.supports(backend):
            raise TypeError(
                f"CohortBackend supports CNNBackend, got {type(backend)}")
        self.backend = backend
        self.capacity = capacity
        # padding quantum for eval/signature sample axes: shards pad to the
        # next power of two below it and to multiples of it above, keeping
        # the compiled-program count bounded with ragged validation shards
        self.eval_pad_quantum = eval_pad_quantum
        self.cfg = backend.cfg
        self.opt = backend.opt
        self._pad_T = 0            # monotone step-axis pad target
        self._eval_data_cache: Dict = {}
        # a 1-device (or absent) clients axis degrades to the exact
        # single-device programs — same jit cache, same numerics
        self.clients_axis = clients_axis
        self.mesh = None
        if mesh is not None:
            if clients_axis not in mesh.shape:
                raise ValueError(
                    f"mesh axes {tuple(mesh.axis_names)} carry no "
                    f"{clients_axis!r} axis")
            if int(dict(mesh.shape)[clients_axis]) > 1:
                self.mesh = mesh
        self._n_shards = (int(dict(self.mesh.shape)[clients_axis])
                          if self.mesh is not None else 1)
        if self.mesh is None:
            self._train_jit = jax.jit(self._train_impl)
            self._eval_jit = jax.jit(self._eval_impl)
            self._eval_shared_jit = jax.jit(self._eval_shared_impl)
            self._eval_many_jit = jax.jit(self._eval_many_impl)
            self._sig_jit = jax.jit(self._sig_impl)
        else:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec
            c, r = PartitionSpec(clients_axis), PartitionSpec()

            def spmd(fn, in_specs, out_specs):
                """Client-axis SPMD: each device runs ``fn`` on its local
                client group; there are no collectives inside — aggregation
                happens in ``repro.core.aggregate``'s psum programs."""
                return jax.jit(shard_map(fn, mesh=self.mesh,
                                         in_specs=in_specs,
                                         out_specs=out_specs))

            self._train_jit = spmd(self._train_impl, (c, c, c, c), (c, c))
            self._eval_jit = spmd(self._eval_impl, (c, c, c, c), c)
            # shared model replicated, K val shards sharded over clients
            self._eval_shared_jit = spmd(self._eval_shared_impl,
                                         (r, c, c, c), c)
            # M candidate models sharded, the one val shard replicated
            self._eval_many_jit = spmd(self._eval_many_impl,
                                       (c, r, r, r), c)
            self._sig_jit = spmd(self._sig_impl, (c, c, c), c)

    @staticmethod
    def supports(backend) -> bool:
        return isinstance(backend, CNNBackend)

    def register_shards(self, train_shards: Sequence[Dataset],
                        epochs: Optional[int] = None) -> None:
        """Pre-size the training step-axis pad target from the client
        shards and the epochs the caller will actually train with, so the
        very first flush already compiles the steady-state program.  The
        target must match the real step count: it is monotone, so an
        over-estimate (e.g. the backend's default epochs when the
        coordinator trains fewer) would permanently pad — and compute —
        every cohort scan to the inflated length.  (Eval pad targets are
        per-call: a global target would let one large shard — e.g. the
        final global-test sweep — permanently inflate every small-val-set
        dispatch.)"""
        b = self.backend
        epochs = epochs or b.local_epochs
        for ds in train_shards:
            n_batches = max(len(ds) // b.batch_size, 1)
            self._pad_T = max(self._pad_T, epochs * n_batches)

    def _round_chunk(self, n: int) -> int:
        """Pad target for a sample axis: next power of two below the
        quantum (tiny val shards don't pay quantum-multiple waste), quantum
        multiples above it (bounded compile count either way)."""
        c = self.eval_pad_quantum
        if n >= c:
            return round_up_multiple(n, c)
        return next_pow2(n)

    # -- jitted programs ----------------------------------------------------

    def _forward(self, params, x, want_signature: bool = False):
        """``cnn_forward`` in matmul form (see :func:`_conv_as_matmul`);
        the signature, when requested, is per-sample (B, channels) so the
        caller can take a padding-masked mean."""
        cfg = self.cfg
        sig = None
        conv_idx = 0
        for stack_params in params["convs"]:
            for p in stack_params:
                x = jax.nn.relu(_conv_as_matmul(x, p["w"]) + p["b"])
                if want_signature and conv_idx == cfg.signature_layer:
                    sig = jnp.mean((x == 0.0).astype(jnp.float32),
                                   axis=(1, 2))                  # (B, ch)
                conv_idx += 1
            x = _max_pool_2x2(x)
        x = x.reshape(x.shape[0], -1)
        for p in params["fcs"][:-1]:
            x = jax.nn.relu(x @ p["w"] + p["b"])
        p = params["fcs"][-1]
        return x @ p["w"] + p["b"], sig

    def _loss(self, params, x, y):
        logits, _ = self._forward(params, x)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - ll)

    def _train_impl(self, stacked_params, xb, yb, mask):
        """xb (K, T, B, H, W, C); yb (K, T, B); mask (K, T) — one vmapped
        scan: the whole cohort advances one SGD step per scan tick."""

        def one_client(params, xs, ys, ms):
            opt_state = self.opt.init(params)

            def step(carry, batch):
                params, opt_state = carry
                x, y, m = batch
                loss, grads = jax.value_and_grad(self._loss)(params, x, y)
                updates, new_opt = self.opt.update(grads, opt_state, params)
                new_params = apply_updates(params, updates)
                params = _tree_select(m, new_params, params)
                opt_state = _tree_select(m, new_opt, opt_state)
                return (params, opt_state), jnp.where(m, loss, 0.0)

            (params, _), losses = jax.lax.scan(
                step, (params, opt_state), (xs, ys, ms))
            return params, losses

        return jax.vmap(one_client)(stacked_params, xb, yb, mask)

    def _masked_correct(self, params, xs, ys, ms):
        """Masked #correct on one shard, conv-form forward (see note in
        ``_eval_impl`` on why eval does NOT use the matmul form)."""
        from repro.models import cnn as cnn_mod
        logits, _ = cnn_mod.cnn_forward(params, xs, self.cfg)
        correct = (jnp.argmax(logits, -1) == ys).astype(jnp.float32)
        return jnp.sum(correct * ms) / jnp.maximum(jnp.sum(ms), 1.0)

    def _eval_impl(self, stacked_params, x, y, mask):
        """K models on K padded shards: x (K, N, ...), mask (K, N).

        Evaluation is FLOP-light and per-client weights make a vmapped conv
        lower to XLA:CPU's slow grouped path, so the win here is dispatch
        fusion, not arithmetic batching: ``lax.map`` runs the K conv-form
        forwards inside ONE compiled program (one dispatch, one sync) while
        each iteration keeps the fast dense-conv lowering."""
        return jax.lax.map(
            lambda args: self._masked_correct(*args),
            (stacked_params, x, y, mask))

    def _eval_shared_impl(self, params, x, y, mask):
        """ONE model on K padded shards (publisher's convergence monitor).
        The params carry no cohort axis, so the K shards simply fold into
        the batch dimension of the conv-form forward — true batching."""
        from repro.models import cnn as cnn_mod
        k, n = y.shape
        flat = x.reshape((k * n,) + x.shape[2:])
        logits, _ = cnn_mod.cnn_forward(params, flat, self.cfg)
        correct = (jnp.argmax(logits.reshape(k, n, -1), -1) == y)
        correct = correct.astype(jnp.float32) * mask
        return jnp.sum(correct, axis=1) / jnp.maximum(jnp.sum(mask, axis=1),
                                                      1.0)

    def _eval_many_impl(self, stacked_params, x, y, mask):
        """M models on ONE padded shard (batched tip validation): fused
        into one program via ``lax.map`` for the same reason as
        ``_eval_impl``."""
        return jax.lax.map(
            lambda p: self._masked_correct(p, x, y, mask), stacked_params)

    def _sig_forward(self, params, x):
        """Per-sample Eq. 3 zero fractions, conv-form, EARLY EXIT: only the
        convs up to ``signature_layer`` run — the classifier head and later
        stacks contribute nothing to the signature."""
        cfg = self.cfg
        conv_idx = 0
        for stack_params in params["convs"]:
            for p in stack_params:
                x = jax.lax.conv_general_dilated(
                    x, p["w"], window_strides=(1, 1), padding="SAME",
                    dimension_numbers=("NHWC", "HWIO", "NHWC"))
                x = jax.nn.relu(x + p["b"])
                if conv_idx == cfg.signature_layer:
                    return jnp.mean((x == 0.0).astype(jnp.float32),
                                    axis=(1, 2))                  # (N, ch)
                conv_idx += 1
            x = _max_pool_2x2(x)
        raise ValueError(f"signature_layer {cfg.signature_layer} out of "
                         f"range for {cfg.name}")

    def _sig_impl(self, stacked_params, x, mask):
        """Masked Eq. 3-4 signatures: per-sample zero fractions, then a
        masked mean so padding samples never enter the signature."""

        def one(args):
            params, xs, ms = args
            zf = self._sig_forward(params, xs)
            w = ms[:, None]
            return jnp.sum(zf * w, axis=0) / jnp.maximum(jnp.sum(w), 1.0)

        return jax.lax.map(one, (stacked_params, x, mask))

    # -- host-side batch assembly -------------------------------------------

    def _prepare_train(self, datasets: Sequence[Dataset], seeds: Sequence[int],
                       epochs: int):
        """Replicates ``CNNBackend.train_local``'s exact per-client batch
        sampling (same np RNG stream per seed), then pads the step axis."""
        b = self.backend
        xs_all, ys_all, steps = [], [], []
        for ds, seed in zip(datasets, seeds):
            rng = np.random.default_rng(seed)
            xs, ys = [], []
            for _ in range(epochs):
                xb, yb = b._batches(ds, rng)
                xs.append(xb)
                ys.append(yb)
            xs_all.append(jnp.concatenate(xs))
            ys_all.append(jnp.concatenate(ys))
            steps.append(int(xs_all[-1].shape[0]))

        self._pad_T = max(self._pad_T, *steps)
        T = self._pad_T
        xb = jnp.stack([pad_leading(x, T) for x in xs_all])
        yb = jnp.stack([pad_leading(y, T) for y in ys_all])
        mask = jnp.stack([
            jnp.arange(T) < s for s in jnp.asarray(steps)]).astype(jnp.float32)
        return xb, yb, mask, steps

    def _pad_cohort(self, stacked, xb, yb, mask):
        """Pad the cohort axis to the next power of two (capped at
        ``capacity``) with fully-masked repeats: short cohorts waste at most
        2x compute while the jit cache stays bounded at log2(capacity)
        programs per shape family.  Under a mesh the target additionally
        rounds up to a multiple of the clients-axis size, so the shard_map
        groups divide evenly for any ragged cohort."""
        k = int(mask.shape[0])
        target = next_pow2(k)
        if self.capacity is not None:
            target = min(max(target, 1), max(self.capacity, k))
        if self._n_shards > 1:
            target = round_up_multiple(target, self._n_shards)
        if k >= target:
            return stacked, xb, yb, mask, k
        reps = target - k
        stacked = jax.tree_util.tree_map(
            lambda leaf: jnp.concatenate(
                [leaf, jnp.repeat(leaf[-1:], reps, axis=0)]), stacked)
        xb = jnp.concatenate([xb, jnp.repeat(xb[-1:], reps, axis=0)])
        yb = jnp.concatenate([yb, jnp.repeat(yb[-1:], reps, axis=0)])
        mask = jnp.concatenate(
            [mask, jnp.zeros((reps,) + mask.shape[1:], mask.dtype)])
        return stacked, xb, yb, mask, k

    def _eval_arrays(self, datasets: Sequence[Dataset], limit: int):
        """Padded (x, y, mask) for a tuple of shards.  Per-DATASET caching:
        each shard is padded to its own rounded size once; per call we stack
        the cached singles (topping up to the call-wide max if the batch
        mixes sizes), so arbitrary cohort compositions — the monitor's full
        val-set sweep, a window's subset — reuse the same buffers."""
        ns = [min(len(ds), limit) for ds in datasets]
        target = max(self._round_chunk(n) for n in ns)
        singles = []
        for ds, n in zip(datasets, ns):
            key = (id(ds), limit)
            hit = self._eval_data_cache.get(key)
            if hit is None:
                own = self._round_chunk(n)
                x1 = pad_leading(jnp.asarray(ds.x[:n]), own)
                y1 = pad_leading(jnp.asarray(ds.y[:n]), own)
                m1 = (jnp.arange(own) < n).astype(jnp.float32)
                # hold ds so the id() key stays unique for our lifetime
                hit = (ds, x1, y1, m1)
                self._eval_data_cache[key] = hit
            singles.append(hit)
        x = jnp.stack([pad_leading(s[1], target) for s in singles])
        y = jnp.stack([pad_leading(s[2], target) for s in singles])
        mask = jnp.stack([pad_leading(s[3], target) for s in singles])
        return x, y, mask

    # -- public API ----------------------------------------------------------

    def train_cohort_stacked(self, stacked_params, datasets, seeds,
                             epochs: Optional[int] = None):
        """Train K clients as one program; returns (stacked params, losses).

        ``losses[k]`` matches the sequential path's contract: the mean loss
        over client k's LAST local epoch.
        """
        epochs = epochs or self.backend.local_epochs
        xb, yb, mask, steps = self._prepare_train(datasets, seeds, epochs)
        stacked_params, xb, yb, mask, k = self._pad_cohort(
            stacked_params, xb, yb, mask)
        if self.mesh is not None:
            # place params AND batch arrays client-sharded BEFORE entering
            # jit, so every host->mesh transfer happens once with the final
            # layout instead of bouncing through device 0
            from repro.sharding.rules import (cohort_pspec,
                                              stacked_client_shardings)
            from jax.sharding import NamedSharding
            stacked_params = jax.device_put(
                stacked_params, stacked_client_shardings(
                    stacked_params, self.mesh, self.clients_axis))
            sh = NamedSharding(self.mesh, cohort_pspec(self.clients_axis))
            xb, yb, mask = (jax.device_put(a, sh) for a in (xb, yb, mask))
        new_params, losses = self._train_jit(stacked_params, xb, yb, mask)
        losses = np.asarray(losses)
        per_epoch = [s // epochs for s in steps]
        final = [float(np.mean(losses[i, s - per_epoch[i]:s]))
                 for i, s in enumerate(steps)]
        if k < losses.shape[0]:
            new_params = jax.tree_util.tree_map(lambda l: l[:k], new_params)
        return new_params, final

    def train_cohort(self, params_list, datasets, seeds,
                     epochs: Optional[int] = None):
        stacked, losses = self.train_cohort_stacked(
            tree_stack(params_list), datasets, seeds, epochs)
        return tree_unstack(stacked), losses

    def evaluate_cohort_stacked(self, stacked_params, datasets,
                                limit: int = 512) -> List[float]:
        """K models, each on its own (ragged) shard."""
        x, y, mask = self._eval_arrays(datasets, limit)
        k = x.shape[0]
        stacked_params, x, y, mask, k = self._pad_cohort(
            stacked_params, x, y, mask)
        accs = self._eval_jit(stacked_params, x, y, mask)
        return [float(a) for a in np.asarray(accs)[:k]]

    def evaluate_cohort(self, params_list, datasets,
                        limit: int = 512) -> List[float]:
        return self.evaluate_cohort_stacked(tree_stack(params_list), datasets,
                                            limit)

    def evaluate_shared(self, params, datasets, limit: int = 512
                        ) -> List[float]:
        """One model on K shards in one dispatch (publisher's monitor)."""
        x, y, mask = self._eval_arrays(datasets, limit)
        k = int(x.shape[0])
        if self._n_shards > 1 and k % self._n_shards:
            t = round_up_multiple(k, self._n_shards)
            x, y, mask = pad_leading(x, t), pad_leading(y, t), \
                pad_leading(mask, t)
        accs = self._eval_shared_jit(params, x, y, mask)
        return [float(a) for a in np.asarray(accs)[:k]]

    def evaluate_many(self, params_list, ds: Dataset,
                      limit: int = 512) -> List[float]:
        """M candidate models on one validation shard (tip selection).

        The model axis is padded to the next power of two (with repeats) so
        repeated tip sweeps reuse a handful of compiled programs.
        """
        m = len(params_list)
        if m == 0:
            return []
        if m == 1:
            # one candidate: the backend's conv-form program wins — no
            # stacking, no padding, and it shares the sequential jit cache
            return [self.backend.evaluate(params_list[0], ds, limit)]
        m_pad = next_pow2(m)
        if self._n_shards > 1:
            m_pad = round_up_multiple(m_pad, self._n_shards)
        padded = list(params_list) + [params_list[-1]] * (m_pad - m)
        # sample axis padded to the shared eval target: compilations stay
        # bounded at log2(M) programs even with ragged validation shards
        x, y, mask = self._eval_arrays([ds], limit)
        accs = self._eval_many_jit(tree_stack(padded), x[0], y[0], mask[0])
        return [float(a) for a in np.asarray(accs)[:m]]

    def signature_cohort_stacked(self, stacked_params, datasets,
                                 limit: int = 128) -> np.ndarray:
        """(K, channels) Eq. 3 signatures, one masked batched dispatch."""
        x, _, mask = self._eval_arrays(datasets, limit)
        # pass mask in the label slot: _pad_cohort pads a (K, N) array there,
        # not a second full copy of the (K, N, H, W, C) images
        stacked_params, x, _, mask, k = self._pad_cohort(
            stacked_params, x, mask, mask)
        sigs = self._sig_jit(stacked_params, x, mask)
        return np.asarray(sigs)[:k]

    def signature_cohort(self, params_list, datasets,
                         limit: int = 128) -> np.ndarray:
        return self.signature_cohort_stacked(tree_stack(params_list),
                                             datasets, limit)
