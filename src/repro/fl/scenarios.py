"""Adversarial & systems-heterogeneity fault injection (robustness suite).

The paper's headline differentiator is trusted verification on the DAG, so
this layer attacks it: a :class:`Scenario` injects faults into the federated
loop of both the DAG-AFL coordinator and every baseline harness —

  malicious   label-flipped shards (y -> C-1-y) and/or scaled-gradient model
              poisoning (``new' = agg + gamma * (new - agg)``, gamma < 0
              ascends the loss), optionally tampering published tx metadata
              AFTER the hash is recorded (what Eq. 7 must catch)
  lazy        free-riders (BLADE-FL): republish the Eq. 6 aggregate
              untouched (``lazy_mode="copy"``, gamma = 0) or their own
              previous model (``lazy_mode="stale"``)
  dp          Gaussian noise on every published update (sigma * N(0, I))
  straggler   heavy-tailed (Pareto) round-duration multipliers for a subset
              of clients
  dropout     wireless failures that abort a publish mid-round — the round's
              work is lost and the client retries

Determinism contract
--------------------
Every stochastic choice draws from a *private* ``np.random.default_rng``
keyed by ``(scenario seed, fault kind, client, per-client sequence)`` — never
from the host run's RNG — and injection sites skip entirely when no fault
applies, so a scenario whose rates are all zero is **bit-identical** to the
honest run (property-tested), and fault event counts at a fixed seed are
exactly reproducible (what the CI robustness gate pins).  The per-client
sequence counters advance in client-round order on both the sequential and
the cohort-batched engines, so counts do not depend on ``cohort_size``.

The update transforms themselves run on the batched cohort engine
(:meth:`repro.fl.cohort.CohortBackend.perturb_cohort_stacked`): one vmapped
jitted program per window with a per-leaf ``where(affected, ...)`` select,
so unaffected clients inside an attacked window keep their exact bits.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Optional, Sequence

import numpy as np

# stable sub-stream ids for the per-(seed, kind, client, seq) RNGs; renaming
# or renumbering these changes every scenario's event stream
_KIND = {"roles": 0, "duration": 1, "dropout": 2, "tamper": 3, "update": 4}


@dataclass(frozen=True)
class ScenarioConfig:
    """Knobs for one fault-injection scenario (all rates default honest)."""

    name: str = "honest"
    seed: int = 0
    # -- malicious / poisoning clients
    malicious_frac: float = 0.0
    attack: str = "label_flip"        # "label_flip" | "scale" | "label_flip+scale"
    scale_gamma: float = -4.0         # gamma for the "scale" model-poisoning
    tamper_rate: float = 0.0          # P(a malicious publish edits its stored
                                      # metadata after hashing)
    # -- lazy / free-riding clients
    lazy_frac: float = 0.0
    lazy_mode: str = "copy"           # "copy" (republish aggregate) | "stale"
    # -- differential-privacy noise on every published update
    dp_sigma: float = 0.0
    # -- stragglers: heavy-tailed round durations
    straggler_frac: float = 0.0
    straggler_tail: float = 1.3       # Pareto shape (lower = heavier tail)
    straggler_scale: float = 4.0      # multiplier scale on the Pareto draw
    straggler_cap: float = 50.0       # cap so one draw can't hide the rest
    # -- wireless dropouts: a publish aborts with this probability
    dropout_rate: float = 0.0


#: The benchmark/CI scenario matrix.  ``robustness.py --scenario <name>``
#: and ``run.py --scenario <name>`` resolve names here.
SCENARIOS: Dict[str, ScenarioConfig] = {
    "poison": ScenarioConfig(name="poison", malicious_frac=0.25,
                             attack="label_flip+scale", scale_gamma=-4.0,
                             tamper_rate=0.5),
    "lazy": ScenarioConfig(name="lazy", lazy_frac=0.25, lazy_mode="copy"),
    "dp": ScenarioConfig(name="dp", dp_sigma=0.05),
    "straggler": ScenarioConfig(name="straggler", straggler_frac=0.25),
    "dropout": ScenarioConfig(name="dropout", dropout_rate=0.3),
}


class Scenario:
    """Runtime fault injector + deterministic event-count bookkeeping.

    One instance belongs to ONE run (the counters are the run's audit
    trail); construct a fresh one per run — :func:`as_scenario` does this
    when handed a :class:`ScenarioConfig` or a registry name.
    """

    def __init__(self, cfg: ScenarioConfig, n_clients: int):
        self.cfg = cfg
        self.n_clients = n_clients
        order = [int(c) for c in
                 np.random.default_rng((cfg.seed, _KIND["roles"]))
                 .permutation(n_clients)]
        n_mal = int(round(cfg.malicious_frac * n_clients))
        n_lazy = int(round(cfg.lazy_frac * n_clients))
        n_strag = int(round(cfg.straggler_frac * n_clients))
        # malicious and lazy are disjoint (front of the permutation);
        # stragglers come off the back — a systems property that may
        # coincide with either behavioural role
        self.malicious: FrozenSet[int] = frozenset(order[:n_mal])
        self.lazy: FrozenSet[int] = frozenset(order[n_mal:n_mal + n_lazy])
        self.stragglers: FrozenSet[int] = frozenset(order[::-1][:n_strag])
        self._seq: Dict[tuple, int] = {}
        # event counters — deterministic at a fixed (seed, geometry), the
        # quantities the CI robustness gate compares across two runs
        self.updates_scaled = 0
        self.updates_lazy = 0
        self.updates_noised = 0
        self.publishes_dropped = 0
        self.straggler_draws = 0
        self.clients_poisoned = 0
        self.tampered: List[str] = []

    # -- private event streams ----------------------------------------------

    def _rng(self, kind: str, client: int) -> np.random.Generator:
        """Fresh generator for this (kind, client) pair's next event; the
        per-pair sequence counter makes draws independent of interleaving."""
        seq = self._seq.get((kind, client), 0)
        self._seq[(kind, client)] = seq + 1
        return np.random.default_rng(
            (self.cfg.seed, _KIND[kind], client, seq))

    # -- data poisoning (before any training) --------------------------------

    def poison_data(self, client_data: List[Dict]) -> List[Dict]:
        """Label-flip malicious clients' train+val shards (y -> C-1-y with
        the GLOBAL class count, so the flip is a consistent wrong task).
        Returns a new list; honest clients' entries are the same objects."""
        if not self.malicious or "label_flip" not in self.cfg.attack:
            return client_data
        ys = [np.asarray(cd["train"].y) for cd in client_data
              if hasattr(cd.get("train"), "y")]
        if not ys:          # token-stream backends: label flipping is a no-op
            return client_data
        n_classes = int(max(y.max() for y in ys)) + 1
        out = []
        for c, cd in enumerate(client_data):
            if c not in self.malicious:
                out.append(cd)
                continue
            flipped = dict(cd)
            for split in ("train", "val"):
                ds = cd.get(split)
                if ds is not None and hasattr(ds, "y"):
                    y = np.asarray(ds.y)
                    flipped[split] = dataclasses.replace(
                        ds, y=(n_classes - 1 - y).astype(y.dtype))
            out.append(flipped)
            self.clients_poisoned += 1
        return out

    # -- update transforms (after local training) ----------------------------

    def update_plan(self, clients: Sequence[int]) -> Optional[Dict]:
        """Per-client coefficients for ``new' = agg + gamma*(new - agg) +
        sigma*N(0,I)`` over one dispatch (a window on the cohort engine, a
        single round otherwise).  Returns None when NO client is affected —
        callers then skip the transform program entirely, which is what
        makes the zero-rate scenario bit-identical (gamma=1/sigma=0 is only
        the identity algebraically)."""
        cfg = self.cfg
        k = len(clients)
        gammas = np.ones(k, np.float32)
        sigmas = np.zeros(k, np.float32)
        affected = np.zeros(k, bool)
        seqs = np.zeros(k, np.int64)
        for i, c in enumerate(clients):
            seq = self._seq.get(("update", c), 0)
            self._seq[("update", c)] = seq + 1
            seqs[i] = seq
            if c in self.malicious and "scale" in cfg.attack:
                gammas[i] = cfg.scale_gamma
                affected[i] = True
                self.updates_scaled += 1
            if c in self.lazy and cfg.lazy_mode == "copy":
                gammas[i] = 0.0        # free-rider: republish the aggregate
                affected[i] = True
                self.updates_lazy += 1
            if cfg.dp_sigma > 0.0:
                sigmas[i] = cfg.dp_sigma
                affected[i] = True
                self.updates_noised += 1
        if not affected.any():
            return None
        return {"seed": cfg.seed, "clients": np.asarray(clients, np.int64),
                "seqs": seqs, "gammas": gammas, "sigmas": sigmas,
                "affected": affected}

    def wants_stale(self, client: int) -> bool:
        """lazy_mode='stale' free-riders republish their own previous model
        (host-side swap — there is nothing to compute)."""
        return client in self.lazy and self.cfg.lazy_mode == "stale"

    # -- systems faults -------------------------------------------------------

    def duration_multiplier(self, client: int) -> float:
        """Heavy-tailed slowdown for straggler clients' simulated round
        durations; exactly 1.0 (no draw, no float op) for everyone else."""
        if client not in self.stragglers:
            return 1.0
        cfg = self.cfg
        rng = self._rng("duration", client)
        self.straggler_draws += 1
        mult = 1.0 + cfg.straggler_scale * rng.pareto(cfg.straggler_tail)
        return float(min(mult, cfg.straggler_cap))

    def drops_publish(self, client: int) -> bool:
        """Wireless dropout: True aborts this publish (the caller discards
        the round's result and reschedules the client)."""
        if self.cfg.dropout_rate <= 0.0:
            return False
        if self._rng("dropout", client).random() < self.cfg.dropout_rate:
            self.publishes_dropped += 1
            return True
        return False

    # -- post-publish metadata tampering --------------------------------------

    def maybe_tamper(self, ledger, tx_id: str) -> bool:
        """A malicious client edits its just-published transaction's stored
        metadata (inflating model_accuracy) WITHOUT recomputing the Eq. 7
        hash — the attack trusted verification exists to catch.  Tip
        selection scores candidates by locally-measured accuracy, not the
        self-reported metadata field, so tampering never perturbs the run's
        trajectory: detection counts stay deterministic."""
        cfg = self.cfg
        if cfg.tamper_rate <= 0.0:
            return False
        tx = ledger.get_tx(tx_id)
        client = tx.metadata.client_id
        if client not in self.malicious:
            return False
        if self._rng("tamper", client).random() >= cfg.tamper_rate:
            return False
        tx.metadata = dataclasses.replace(
            tx.metadata,
            model_accuracy=min(0.999, tx.metadata.model_accuracy + 0.5))
        self.tampered.append(tx_id)
        return True

    # -- audit trail -----------------------------------------------------------

    def counts(self) -> Dict[str, int]:
        """Deterministic fault-event counts (the robustness gate compares
        these across two same-seed runs)."""
        return {"clients_malicious": len(self.malicious),
                "clients_lazy": len(self.lazy),
                "clients_straggler": len(self.stragglers),
                "clients_poisoned": self.clients_poisoned,
                "updates_scaled": self.updates_scaled,
                "updates_lazy": self.updates_lazy,
                "updates_noised": self.updates_noised,
                "publishes_dropped": self.publishes_dropped,
                "straggler_draws": self.straggler_draws,
                "txs_tampered": len(self.tampered)}


def as_scenario(obj, n_clients: int) -> Optional[Scenario]:
    """Coerce a config field to a live injector: None passes through, a
    registry name or :class:`ScenarioConfig` builds a fresh :class:`Scenario`
    and a prebuilt :class:`Scenario` is used as-is (callers that want to
    read the counters afterwards pass the instance)."""
    if obj is None or isinstance(obj, Scenario):
        return obj
    if isinstance(obj, str):
        obj = SCENARIOS[obj]
    return Scenario(obj, n_clients)


def dag_attack_metrics(ledger, scenario: Scenario) -> Dict[str, float]:
    """Post-run quarantine metrics over the (unpruned) DAG.

    * ``poisoned_tip_approval_rate`` — of all approval edges published by
      HONEST clients, the fraction pointing at a malicious client's tx: how
      often tip selection was fooled into building on a poisoned lineage.
    * ``orphaned_malicious_frac`` — fraction of malicious txs never approved
      by any honest tx (quarantined lineages).  ``orphaned_honest_frac`` is
      the same quantity for honest txs — the natural orphan floor (the last
      global round's txs have had no chance to be approved), so compare the
      two rather than reading either absolutely.

    Pruned txs aren't walkable, so run the robustness benchmark on the
    append-only ledger (``ledger_checkpoint_every=0``).
    """
    mal = scenario.malicious
    mal_ids, honest_ids = set(), set()
    for tx in ledger.transactions():
        c = tx.metadata.client_id
        if c < 0:
            continue                      # genesis
        (mal_ids if c in mal else honest_ids).add(tx.tx_id)
    honest_edges = edges_to_mal = 0
    approved_mal, approved_honest = set(), set()
    for tx in ledger.transactions():
        c = tx.metadata.client_id
        if c < 0 or c in mal:
            continue
        for p in tx.parents:
            honest_edges += 1
            if p in mal_ids:
                edges_to_mal += 1
                approved_mal.add(p)
            elif p in honest_ids:
                approved_honest.add(p)
    return {
        "malicious_published": len(mal_ids),
        "honest_published": len(honest_ids),
        "poisoned_tip_approval_rate": edges_to_mal / max(honest_edges, 1),
        "orphaned_malicious_frac": (1.0 - len(approved_mal)
                                    / max(len(mal_ids), 1)),
        "orphaned_honest_frac": (1.0 - len(approved_honest)
                                 / max(len(honest_ids), 1)),
    }
