"""Live-traffic consensus serving: frontier -> replica publication.

DAG-AFL's deliverable at any instant is the Eq. 6 consensus over the current
tip frontier, but the frontier is a moving target — every client publish
reshapes it.  This module turns that moving target into something queryable
while training is still in flight:

* :class:`ConsensusPublisher` rides the event loop on a configurable cadence
  (``ServingConfig.every`` simulated seconds) and materializes the frontier
  into an immutable, versioned :class:`ServingReplica` — the Eq. 6 aggregate
  plus the exact tip tx-ids, pinned ModelStore refs, the ledger head seq and
  the sim-time stamp it was cut at.  Replicas live in a double buffer with an
  atomic active-index flip, so a query can never observe a half-written
  replica: the back slot is only made active once the replica object is
  fully formed, and the previous replica stays intact for readers that
  already grabbed it.
* Replica refs are protected from :class:`repro.core.dag.BoundedDAGLedger`
  eviction the same way the coordinator protects pruned-while-latest models:
  the coordinator routes every prune-driven eviction through the publisher,
  which defers refs pinned by a live replica and releases them on the swap
  that unpins them.
* :class:`QueryStream` replays a deterministic seeded Poisson trace of
  batched queries against whatever replica is live, concurrently with
  training (same event heap, zero training-state mutation).  Per query it
  records staleness as BOTH a ledger-seq lag (``head_seq`` advances exactly
  once per publish, so these counters are deterministic event counts — the
  gateable quantity) and a sim-time lag (the paper-facing latency figure).

Why staleness is measured in ledger seqs: wall-clock is non-reproducible
and sim-time lag depends on continuous cost draws, but the number of
transactions the frontier advanced past a replica is a pure function of the
event schedule — same seed, same config, same lag histogram, every run.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.aggregate import tree_mean
from repro.runtime import serve_runtime


# -- configuration -----------------------------------------------------------


@dataclass(frozen=True)
class ServingConfig:
    """Knobs for the publisher + query stream (see module docstring)."""

    every: float = 5.0          # publish cadence, simulated seconds
    query_rate: float = 1.0     # Poisson arrivals per simulated second
    query_batch: int = 8        # requests folded into one batched dispatch
    seed: int = 1234            # query-trace RNG (independent of training)
    backend: str = "auto"       # "auto" | "cnn" | "lm"
    prompt_len: int = 16        # LM driver: prompt tokens per request
    new_tokens: int = 8         # LM driver: greedy-decoded continuation
    kernel_policy: Optional[str] = None  # LM driver kernel dispatch


# -- replica + parity helpers ------------------------------------------------


@dataclass(frozen=True)
class ServingReplica:
    """One immutable published snapshot of the consensus frontier."""

    version: int                      # 0-based publish ordinal
    params: object                    # Eq. 6 aggregate over the frontier
    frontier: Tuple[str, ...]         # tip tx-ids the aggregate was cut from
    model_refs: Tuple[str, ...]       # pinned ModelStore refs (one per tip)
    ledger_seq: int                   # ledger.head_seq() at materialization
    published_at: float               # simulated publish time


def consensus_over_refs(store, refs):
    """Eq. 6 over an explicit ref list (the replica's pinned frontier)."""
    return tree_mean([store.get(r) for r in refs])


def frontier_snapshot(ledger) -> Tuple[Tuple[str, ...], Tuple[str, ...]]:
    """(tip tx-ids, their model refs) for the CURRENT frontier."""
    tips = tuple(ledger.tips())
    return tips, tuple(ledger.get_tx(t).model_ref for t in tips)


def trees_bitwise_equal(a, b) -> bool:
    """Exact (bit-level) pytree equality — the parity predicate: a replica
    IS the Eq. 6 aggregate, so recomputing over its pinned refs must match
    to the last bit, not to a tolerance."""
    import jax
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


def replica_parity(replica: ServingReplica, store) -> bool:
    """Does the replica's params equal a fresh Eq. 6 over its own refs?"""
    return trees_bitwise_equal(replica.params,
                               consensus_over_refs(store, replica.model_refs))


# -- publisher ---------------------------------------------------------------


class ConsensusPublisher:
    """Materializes the tip frontier into double-buffered replicas.

    Single-writer (the event loop is serial), many-reader.  ``publish()``
    builds the new :class:`ServingReplica` completely in the back slot and
    only then flips ``_active`` — one reference assignment, so ``replica()``
    always returns either the old or the new snapshot, never a mixture.
    A publish tick that finds the frontier unchanged (``head_seq`` hasn't
    moved ⟺ no appends ⟺ identical tip set) is a counted no-op — the live
    replica already IS that frontier.
    """

    def __init__(self, ledger, store, loop, every: float,
                 stop: Optional[Callable[[], bool]] = None,
                 on_swap: Optional[Callable[[ServingReplica], None]] = None):
        if every <= 0.0:
            raise ValueError(f"publish cadence must be > 0, got {every!r}")
        self.ledger = ledger
        self.store = store
        self.loop = loop
        self.every = float(every)
        self._stop = stop
        self._on_swap = on_swap
        self._slots: List[Optional[ServingReplica]] = [None, None]
        self._active = 0
        # refs the coordinator asked to evict while a replica pinned them;
        # released (and actually evicted) by the first swap that unpins them
        self._deferred: set = set()
        self.publishes = 0            # replicas actually materialized
        self.publishes_noop = 0       # ticks that found the frontier unmoved
        self.evictions_deferred = 0
        self.evictions_released = 0

    # -- reader side ---------------------------------------------------------

    def replica(self) -> Optional[ServingReplica]:
        """The live replica (None only before the first publish)."""
        return self._slots[self._active]

    def pinned_refs(self) -> set:
        """ModelStore refs pinned by EITHER buffer slot: the back slot's
        previous replica stays readable until the next swap, so its refs
        are pinned too."""
        refs = set()
        for rep in self._slots:
            if rep is not None:
                refs.update(rep.model_refs)
        return refs

    # -- eviction protection --------------------------------------------------

    def guard_evict(self, ref: str) -> bool:
        """Coordinator hook: returns True iff the publisher takes ownership
        of evicting ``ref`` (it is pinned by a live replica); the caller
        must then NOT evict it itself."""
        if ref in self.pinned_refs():
            self._deferred.add(ref)
            self.evictions_deferred += 1
            return True
        return False

    def _release_unpinned(self) -> None:
        pinned = self.pinned_refs()
        for ref in sorted(self._deferred - pinned):
            self.store.evict(ref)
            self._deferred.discard(ref)
            self.evictions_released += 1

    # -- writer side ----------------------------------------------------------

    def publish(self) -> Optional[ServingReplica]:
        """Materialize the current frontier into the back slot and flip."""
        head = self.ledger.head_seq()
        live = self.replica()
        if live is not None and live.ledger_seq == head:
            self.publishes_noop += 1
            return None
        frontier, refs = frontier_snapshot(self.ledger)
        replica = ServingReplica(
            version=self.publishes,
            params=consensus_over_refs(self.store, refs),
            frontier=frontier, model_refs=refs,
            ledger_seq=head, published_at=self.loop.now)
        back = 1 - self._active
        self._slots[back] = replica       # fully formed before ...
        self._active = back               # ... the atomic flip
        self.publishes += 1
        self._release_unpinned()
        if self._on_swap is not None:
            self._on_swap(replica)
        return replica

    def start(self) -> None:
        """Publish v0 immediately (the genesis frontier — queries arriving
        before the first cadence tick must find A replica), then ride the
        event loop every ``self.every`` simulated seconds."""
        self.publish()
        self.loop.schedule_every(self.every, self.publish, stop=self._stop)

    def report(self) -> Dict:
        live = self.replica()
        return {
            "replica_versions": self.publishes,
            "publishes_noop": self.publishes_noop,
            "evictions_deferred": self.evictions_deferred,
            "evictions_released": self.evictions_released,
            "final_frontier_size": 0 if live is None else len(live.frontier),
            "final_replica_seq": -1 if live is None else live.ledger_seq,
        }


# -- query drivers -----------------------------------------------------------


class CNNQueryDriver:
    """Batched eval requests against the replica (CNN backend): each query
    scores a rotating deterministic window of the query pool."""

    def __init__(self, backend, query_ds, query_batch: int = 8):
        from repro.data.synthetic import Dataset
        self.backend = backend
        self.ds = query_ds
        self.batch = max(1, min(int(query_batch), len(query_ds)))
        self._Dataset = Dataset
        self._cursor = 0
        self.queries = 0
        self.acc_sum = 0.0

    def serve(self, replica: ServingReplica) -> Dict:
        n = len(self.ds)
        start = (self._cursor * self.batch) % max(n - self.batch + 1, 1)
        self._cursor += 1
        window = self._Dataset(self.ds.x[start:start + self.batch],
                               self.ds.y[start:start + self.batch])
        acc = self.backend.evaluate(replica.params, window, limit=self.batch)
        self.queries += 1
        self.acc_sum += acc
        return {"accuracy": acc}

    def report(self) -> Dict:
        return {"driver": "cnn",
                "query_accuracy_mean":
                    self.acc_sum / self.queries if self.queries else 0.0}


class LMQueryDriver:
    """Prefill + KV-cache greedy decode against the replica (LM backend),
    through the same jitted programs as ``repro.launch.serve`` — honoring
    the kernel dispatch policy via :func:`repro.runtime.serve_runtime`."""

    def __init__(self, cfg, query_batch: int = 4, prompt_len: int = 16,
                 new_tokens: int = 8, seed: int = 0,
                 kernel_policy: Optional[str] = None):
        from repro.launch.serve import greedy_decode, make_serving_fns
        self.cfg = cfg
        self.batch = int(query_batch)
        self.prompt_len = int(prompt_len)
        self.new_tokens = max(2, int(new_tokens))
        self.rng = np.random.default_rng(seed)
        self._greedy = greedy_decode
        self.prefill, self.decode = make_serving_fns(
            cfg, serve_runtime(kernel_policy))
        self.queries = 0
        self.tokens_generated = 0

    def make_batch(self, prompts: np.ndarray) -> Dict:
        import jax.numpy as jnp
        b = {"tokens": jnp.asarray(prompts)}
        if self.cfg.encoder is not None:
            b["enc_embed"] = jnp.zeros(
                (prompts.shape[0], self.cfg.encoder.n_ctx, self.cfg.d_model))
        return b

    def decode_prompts(self, params, prompts: np.ndarray):
        """Greedy continuation tokens for explicit prompts (also the parity
        probe: run the same prompts against a directly-aggregated model)."""
        out = self._greedy(self.prefill, self.decode, self.cfg, params,
                           self.make_batch(prompts), self.new_tokens)
        return np.asarray(out["tokens"])

    def serve(self, replica: ServingReplica) -> Dict:
        prompts = self.rng.integers(
            0, self.cfg.vocab_size, (self.batch, self.prompt_len))
        tokens = self.decode_prompts(replica.params, prompts)
        self.queries += 1
        self.tokens_generated += int(tokens.size)
        return {"tokens": tokens}

    def report(self) -> Dict:
        return {"driver": "lm", "tokens_generated": self.tokens_generated}


def make_query_driver(scfg: ServingConfig, backend, query_data):
    """Build the right driver for ``scfg.backend`` ("auto" sniffs the
    backend type: LMBackend -> decode driver, anything else -> eval)."""
    kind = scfg.backend
    if kind == "auto":
        from repro.fl.backend import LMBackend
        kind = "lm" if isinstance(backend, LMBackend) else "cnn"
    if kind == "lm":
        policy = scfg.kernel_policy
        if policy is None:
            policy = getattr(backend, "kernel_policy", None)
        return LMQueryDriver(backend.cfg, query_batch=scfg.query_batch,
                             prompt_len=scfg.prompt_len,
                             new_tokens=scfg.new_tokens, seed=scfg.seed,
                             kernel_policy=policy)
    if kind == "cnn":
        return CNNQueryDriver(backend, query_data,
                              query_batch=scfg.query_batch)
    raise ValueError(f"unknown serving backend {scfg.backend!r}")


# -- query stream ------------------------------------------------------------


class QueryStream:
    """Deterministic seeded Poisson query trace against the live replica.

    Arrival gaps are exponential draws from an own-seeded generator, pulled
    one at a time on the event loop (``EventLoop.schedule_stream``), so the
    trace is a pure function of (seed, rate) and the surrounding event
    schedule.  Serving is read-only: no training state, no shared RNG.
    """

    def __init__(self, publisher: ConsensusPublisher, driver, loop, ledger,
                 query_rate: float, seed: int,
                 stop: Optional[Callable[[], bool]] = None):
        if query_rate <= 0.0:
            raise ValueError(f"query_rate must be > 0, got {query_rate!r}")
        self.publisher = publisher
        self.driver = driver
        self.loop = loop
        self.ledger = ledger
        self.rate = float(query_rate)
        self.rng = np.random.default_rng(seed)
        self._stop = stop
        self.arrivals = 0
        self.queries = 0
        self.skipped = 0              # arrivals before any replica existed
        self.seq_lags: List[int] = []
        self.time_lags: List[float] = []
        self.version_hist: Dict[int, int] = {}
        self.wall_s = 0.0

    def start(self) -> None:
        self.loop.schedule_stream(
            lambda: self.rng.exponential(1.0 / self.rate),
            self._serve_one, stop=self._stop)

    def _serve_one(self) -> None:
        self.arrivals += 1
        rep = self.publisher.replica()
        if rep is None:
            self.skipped += 1
            return
        # staleness at ARRIVAL time: how far the frontier moved past the
        # replica, in append seqs (deterministic) and simulated seconds
        self.seq_lags.append(self.ledger.head_seq() - rep.ledger_seq)
        self.time_lags.append(self.loop.now - rep.published_at)
        self.version_hist[rep.version] = \
            self.version_hist.get(rep.version, 0) + 1
        # wall-clock spent INSIDE the driver only — reported as throughput,
        # never gated, and never fed back into simulated event times
        t0 = time.time()      # repro-lint: disable=DET003
        self.driver.serve(rep)
        self.wall_s += time.time() - t0   # repro-lint: disable=DET003
        self.queries += 1

    def report(self) -> Dict:
        lags = self.seq_lags
        return {
            "queries": self.queries,
            "arrivals": self.arrivals,
            "skipped": self.skipped,
            "replica_version_hist": {str(k): v for k, v in
                                     sorted(self.version_hist.items())},
            "distinct_versions_served": len(self.version_hist),
            "max_seq_lag": max(lags) if lags else 0,
            "mean_seq_lag": float(np.mean(lags)) if lags else 0.0,
            "max_time_lag": max(self.time_lags) if self.time_lags else 0.0,
            "mean_time_lag": (float(np.mean(self.time_lags))
                              if self.time_lags else 0.0),
            # wall-clock throughput: reported for eyeballing, NEVER gated
            "query_wall_s": self.wall_s,
            "queries_per_s": self.queries / self.wall_s if self.wall_s else 0.0,
            **self.driver.report(),
        }
