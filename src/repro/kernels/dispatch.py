"""Platform-aware kernel dispatch policy.

Every wrapper in :mod:`repro.kernels.ops` executes under one of three
concrete *policies*:

  ``"compiled"``   lower the Pallas kernel to Mosaic (TPU) — the real
                   kernel, fused reads, VMEM accumulators.
  ``"interpret"``  run the same kernel body through the Pallas
                   interpreter (any backend; the CPU-container CI path).
                   Same memory-access structure, per-block Python grid.
  ``"reference"``  skip Pallas entirely and run the pure-jnp oracle
                   (``kernels/ref.py`` or the inline jnp math) — the
                   stock-XLA incumbent path, bit-for-bit.

``"auto"`` resolves to a concrete policy at call/construction time:
an explicit non-auto argument wins, then the ``REPRO_KERNEL_POLICY``
environment variable, then the platform default from
``jax.default_backend()`` (TPU -> ``"compiled"``, anything else ->
``"interpret"``).  Resolution is pure host logic — call it outside jit
(backend constructors do) or at trace time; either way the chosen branch
is baked into the compiled program.

Why this lives in its own module: ``ops.py`` and every kernel file need
the resolver, and ``ops.py`` imports the kernel files — a resolver inside
``ops.py`` would make the kernel files import their own importer.
"""
from __future__ import annotations

import os

KERNEL_POLICIES = ("auto", "compiled", "interpret", "reference")

# environment override for the "auto" policy (itself may be "auto")
POLICY_ENV = "REPRO_KERNEL_POLICY"


def _validate(policy: str, source: str) -> str:
    if policy not in KERNEL_POLICIES:
        raise ValueError(
            f"unknown kernel policy {policy!r} (from {source}); "
            f"expected one of {KERNEL_POLICIES}")
    return policy


def resolve_policy(policy=None) -> str:
    """Resolve a policy request to a concrete ``"compiled"`` /
    ``"interpret"`` / ``"reference"``.

    ``None`` and ``"auto"`` consult ``REPRO_KERNEL_POLICY`` and then the
    platform; an explicit concrete policy is validated and returned as-is
    (the env var never overrides an explicit argument).
    """
    p = _validate("auto" if policy is None else str(policy), "argument")
    if p != "auto":
        return p
    env = os.environ.get(POLICY_ENV, "").strip().lower()
    if env:
        p = _validate(env, f"${POLICY_ENV}")
        if p != "auto":
            return p
    import jax
    return "compiled" if jax.default_backend() == "tpu" else "interpret"


def resolve_interpret(interpret=None, policy=None) -> bool:
    """The ``interpret=`` flag a ``pallas_call`` site should use.

    An explicit ``interpret`` argument is the override of last resort and
    always wins; otherwise every policy except ``"compiled"`` interprets
    (``"reference"`` never reaches a ``pallas_call``, so mapping it to the
    interpreter is the safe degenerate answer).
    """
    if interpret is not None:
        return bool(interpret)
    return resolve_policy(policy) != "compiled"


def policy_from_runtime(runtime) -> str:
    """The concrete policy a model hot path should run under.

    ``use_pallas=False`` (the default ``Runtime``) means the incumbent
    stock-XLA math: policy ``"reference"``, bit-for-bit today's numbers.
    ``use_pallas=True`` resolves the runtime's ``kernel_policy`` request;
    a legacy non-None ``pallas_interpret`` forces interpret/compiled.
    """
    if runtime is None or not getattr(runtime, "use_pallas", False):
        return "reference"
    legacy = getattr(runtime, "pallas_interpret", None)
    if legacy is not None:
        return "interpret" if legacy else "compiled"
    return resolve_policy(getattr(runtime, "kernel_policy", "auto"))
