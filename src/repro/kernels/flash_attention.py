"""Flash attention Pallas TPU kernel: causal / sliding-window / soft-cap / GQA.

TPU-native design (not a CUDA port): the grid is (batch, q_head, q_block,
kv_block) with the kv_block dim innermost — TPU executes grid steps
sequentially per core, so the online-softmax state (m, l, acc) lives in VMEM
scratch and persists across kv steps.  Block shapes are MXU-aligned
(multiples of 128 on the contracting dims); the probability matrix never
leaves VMEM, which is exactly the HBM-traffic term the roofline analysis
shows dominating the pure-JAX chunked path.

Fully-masked kv blocks (beyond the causal frontier or outside the sliding
window) are skipped with ``pl.when`` — the causal speedup the XLA scan path
cannot express.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -2.0e9


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int, softcap: float,
            bq: int, bk: int, nk: int, seq_len: int):
    i = pl.program_id(2)              # q block
    j = pl.program_id(3)              # kv block (innermost, sequential)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = i * bq
    k_start = j * bk

    # block-level skip: block fully above the causal diagonal or fully
    # outside the sliding window
    live = jnp.bool_(True)
    if causal:
        live = live & (k_start <= q_start + bq - 1)
    if window > 0:
        live = live & (k_start + bk - 1 >= q_start - window + 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = cols < seq_len                                # kv padding
        ok &= rows < seq_len
        if causal:
            ok &= rows >= cols
        if window > 0:
            ok &= (rows - cols) < window
        s = jnp.where(ok, s, _NEG)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = -1,
                         softcap: float = 0.0, block_q: int = 128,
                         block_k: int = 128, interpret=None):
    """q (B,H,Sq,hd); k,v (B,K,Sk,hd) with H % K == 0 (GQA).

    Returns (B,H,Sq,hd) in q.dtype.  Sq must equal Sk (self-attention over
    the same positions); callers pad to block multiples.
    ``interpret=None`` resolves from the platform dispatch policy.
    """
    from repro.kernels.dispatch import resolve_interpret
    interpret = resolve_interpret(interpret)
    B, H, S, hd = q.shape
    K = k.shape[1]
    G = H // K
    bq = min(block_q, S)
    bk = min(block_k, S)
    nq = -(-S // bq)
    nk = -(-S // bk)
    pad_q = nq * bq - S
    pad_k = nk * bk - S
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    kernel = functools.partial(
        _kernel, scale=1.0 / math.sqrt(hd), causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, nk=nk, seq_len=S)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * bq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),   # acc
            pltpu.VMEM((bq,), jnp.float32),      # m
            pltpu.VMEM((bq,), jnp.float32),      # l
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S] if pad_q else out
