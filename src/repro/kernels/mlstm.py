"""Chunkwise mLSTM Pallas TPU kernel (stabilized matrix-memory recurrence).

TPU-native mapping of the xLSTM paper's mLSTM kernel: the grid is
(batch, head, chunk) with chunks innermost; the matrix memory C (dk, dv),
normalizer n (dk,) and stabilizer m live in VMEM scratch across chunk steps.
Within a chunk the intra-term is the (L, L) decay-masked attention the MXU
likes; HBM sees q/k/v/gates once and h once — no inter-chunk state traffic.

Matches ``repro.models.xlstm.mlstm_chunkwise`` (the lax.scan formulation)
and the step-by-step recurrent oracle to float tolerance.  Forward/inference
path (training keeps the XLA scan; a custom VJP would be needed here).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, i_ref, f_ref,
            h_ref, c_out_ref, n_out_ref, m_out_ref,
            C, nvec, mval, *, L: int, dk: int, dv: int, n_chunks: int,
            seq_len: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        C[...] = jnp.zeros_like(C)
        nvec[...] = jnp.zeros_like(nvec)
        mval[...] = jnp.full_like(mval, _NEG)

    scale = 1.0 / math.sqrt(dk)
    q = q_ref[0, 0].astype(jnp.float32) * scale       # (L, dk)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    ig = i_ref[0, 0].astype(jnp.float32)              # (L,)
    fg = f_ref[0, 0].astype(jnp.float32)

    # padded steps (beyond seq_len): forget->1 (logf=0), input->-inf
    pos = j * L + jax.lax.broadcasted_iota(jnp.int32, (L,), 0)
    valid = pos < seq_len
    logf = jnp.where(valid, jax.nn.log_sigmoid(fg), 0.0)
    ig = jnp.where(valid, ig, _NEG)

    b = jnp.cumsum(logf)                              # (L,)
    g = b[L - 1]
    m_prev = mval[0]

    # intra-chunk decay D[t,s] = b_t - b_s + i_s (s <= t)
    D = b[:, None] - b[None, :] + ig[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    D = jnp.where(tri, D, -jnp.inf)
    m_intra = jnp.max(D, axis=1)
    m_t = jnp.maximum(b + m_prev, m_intra)            # (L,)

    w_inter = jnp.exp(b + m_prev - m_t)
    num_inter = jax.lax.dot_general(
        q, C[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * w_inter[:, None]
    den_inter = (q @ nvec[...]) * w_inter             # (L,)

    logits = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    decay = jnp.where(tri, jnp.exp(D - m_t[:, None]), 0.0)
    Wn = decay * logits
    num = num_inter + jax.lax.dot_general(
        Wn, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    den = den_inter + jnp.sum(Wn, axis=1)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[:, None]
    h_ref[0, 0] = h.astype(h_ref.dtype)

    # state update
    m_next = jnp.maximum(g + m_prev, jnp.max(g - b + ig))
    w_c = jnp.exp(g + m_prev - m_next)
    w_s = jnp.exp(g - b + ig - m_next)                # (L,)
    C[...] = C[...] * w_c + jax.lax.dot_general(
        k * w_s[:, None], v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    nvec[...] = nvec[...] * w_c + jnp.sum(k * w_s[:, None], axis=0)
    mval[0] = m_next

    @pl.when(j == n_chunks - 1)
    def _emit():
        c_out_ref[0, 0] = C[...]
        n_out_ref[0, 0] = nvec[...]
        m_out_ref[0, 0] = mval[...]


def mlstm_chunkwise_bshd(q, k, v, i_gate, f_gate, *, chunk: int = 128,
                         interpret=None):
    """q,k (B,S,H,dk); v (B,S,H,dv); gates (B,S,H) raw.

    Fresh state (C=0, n=0, m=-inf). Returns (h (B,S,H,dv),
    state {C (B,H,dk,dv), n (B,H,dk), m (B,H)}).
    ``interpret=None`` resolves from the platform dispatch policy.
    """
    from repro.kernels.dispatch import resolve_interpret
    interpret = resolve_interpret(interpret)
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    L = min(chunk, S)
    n_chunks = -(-S // L)
    pad = n_chunks * L - S
    if pad:
        q, k, v = (jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for a in (q, k, v))
        i_gate = jnp.pad(i_gate, ((0, 0), (0, pad), (0, 0)))
        f_gate = jnp.pad(f_gate, ((0, 0), (0, pad), (0, 0)))
    Sp = n_chunks * L
    # layout (B, H, S, *) for head-major blocks
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    it = i_gate.transpose(0, 2, 1)
    ft = f_gate.transpose(0, 2, 1)

    kernel = functools.partial(_kernel, L=L, dk=dk, dv=dv, n_chunks=n_chunks,
                               seq_len=S)
    h, C, n, m = pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, L, dk), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, L, dk), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, L, dv), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, L), lambda b, h, j: (b, h, j)),
            pl.BlockSpec((1, 1, L), lambda b, h, j: (b, h, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, dv), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, dk), lambda b, h, j: (b, h, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, h, j: (b, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sp, dv), q.dtype),
            jax.ShapeDtypeStruct((B, H, dk, dv), jnp.float32),
            jax.ShapeDtypeStruct((B, H, dk), jnp.float32),
            jax.ShapeDtypeStruct((B, H, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((dk, dv), jnp.float32),
            pltpu.VMEM((dk,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt, it, ft)
    h = h.transpose(0, 2, 1, 3)
    if pad:
        h = h[:, :S]
    return h, {"C": C, "n": n, "m": m[..., 0]}
