"""Platform-aware kernel dispatch layer over the Pallas kernels.

Model code calls these through ``Runtime(use_pallas=True)``; every wrapper
takes a ``policy`` (see :mod:`repro.kernels.dispatch`) deciding how the op
executes:

  ``"compiled"``   the Pallas kernel lowered to Mosaic (TPU),
  ``"interpret"``  the same kernel through the Pallas interpreter (the
                   CPU-container CI path),
  ``"reference"``  the pure-jnp oracle (``kernels/ref.py`` / inline jnp)
                   — bit-for-bit the stock-XLA incumbent math,
  ``"auto"``/None  resolved from ``$REPRO_KERNEL_POLICY`` and then
                   ``jax.default_backend()`` (TPU -> compiled, else
                   interpret).

``interpret=`` remains as an explicit last-resort override of the
policy's compile/interpret choice; call sites outside ``kernels/`` should
pass ``policy`` instead (lint rule KER001 enforces this).

Bit-stability contract for ``signature``/``signature_per_channel``: the
Eq. 3 signatures feed tip selection through the similarity contract, so a
1-ulp drift changes which parents a client approves and therefore the DAG
topology.  The kernel path accumulates raw 0/1 flag COUNTS (exact
integers in f32) and normalises them with ``counts * (1/n)`` — the same
multiply-by-reciprocal XLA lowers ``jnp.mean`` to — so kernel and
reference signatures agree bit-for-bit, padding tail included, for every
``d % n_sig`` (pinned by tests/test_kernel_dispatch.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.dispatch import (KERNEL_POLICIES, POLICY_ENV,  # noqa: F401
                                    policy_from_runtime, resolve_interpret,
                                    resolve_policy)
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.mlstm import mlstm_chunkwise_bshd
from repro.kernels.selective_scan import selective_scan_bsd
from repro.kernels.signature import signature_td
from repro.kernels.slstm import slstm_scan_bsd


def flash_attention(q, k, v, *, causal: bool = True, window: int = -1,
                    softcap: float = 0.0, policy=None, interpret=None):
    """(B,S,H,hd) layout wrapper used by repro.models.attention."""
    p = resolve_policy(policy)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if p == "reference" and interpret is None:
        from repro.kernels.ref import flash_attention_ref
        out = flash_attention_ref(qt, kt, vt, causal=causal, window=window,
                                  softcap=softcap)
    else:
        out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                                   softcap=softcap,
                                   interpret=resolve_interpret(interpret, p))
    return out.transpose(0, 2, 1, 3)


def selective_scan(x, dt, A, Bc, Cc, h0, *, chunk: int = 256,
                   policy=None, interpret=None):
    """Drop-in for repro.models.mamba.selective_scan_ref."""
    p = resolve_policy(policy)
    if p == "reference" and interpret is None:
        from repro.kernels.ref import selective_scan_seq_ref
        return selective_scan_seq_ref(x, dt, A, Bc, Cc, h0)
    return selective_scan_bsd(x, dt, A, Bc, Cc, h0, chunk=chunk,
                              interpret=resolve_interpret(interpret, p))


def _threshold_flags(x, tau: float):
    """0/1 flag tensor with the kernels' tau semantics: ``tau <= 0`` is the
    EXACT-zero count (the CNN path), ``tau > 0`` the |x| < tau band (the
    LM path, matching ``models.layers.activation_signature``)."""
    if tau <= 0.0:
        flags = (x == 0.0)
    else:
        flags = jnp.abs(x.astype(jnp.float32)) < tau
    return flags.astype(jnp.float32)


def signature(x, *, tau: float = 0.05, n_sig: int = 64,
              policy=None, interpret=None):
    """Activation (..., d) -> bucketed Eq. 3 signature vector (n_sig,).

    Bit-identical to ``models.layers.activation_signature`` (for
    ``tau > 0``; ``tau <= 0`` swaps in the exact-zero flags) on every
    policy: the reference path runs its literal math, the kernel path
    reduces exact flag counts in VMEM and applies the identical
    ``* (1 / (T * w))`` normalisation — zero-padded tail channels simply
    contribute zero counts, exactly as zero-padded flag columns do.
    """
    d = x.shape[-1]
    flat = x.reshape(-1, d)
    t = flat.shape[0]
    pad = (-d) % n_sig
    w = (d + pad) // n_sig
    p = resolve_policy(policy)
    if p == "reference" and interpret is None:
        flags = _threshold_flags(flat, tau)              # (T, d)
        if pad:
            flags = jnp.pad(flags, ((0, 0), (0, pad)))
        return jnp.mean(flags.reshape(t, n_sig, w), axis=(0, 2))
    counts = signature_td(flat, tau=tau, mean=False,
                          interpret=resolve_interpret(interpret, p))
    if pad:
        counts = jnp.pad(counts, (0, pad))
    bucket_sums = jnp.sum(counts.reshape(n_sig, w), axis=1)
    # multiply-by-reciprocal, NOT division: jnp.mean lowers to
    # sum * (1/n), and the two roundings differ by 1 ulp on ~3% of
    # fraction values — enough to flip tip selections
    return bucket_sums * (1.0 / np.float32(t * w))


def signature_per_channel(x, *, tau: float = 0.0, policy=None,
                          interpret=None):
    """Per-sample per-channel threshold fractions: (N, ..., C) -> (N, C).

    The CNN suites' Eq. 3 rows: for each sample the fraction of exact
    zeros (ReLU kill rate) over the spatial axes, per channel.
    Bit-identical to ``jnp.mean((x == 0.0).astype(f32), axis=spatial)``
    on every policy (same exact-count + multiply-by-reciprocal argument
    as :func:`signature`).
    """
    n, c = x.shape[0], x.shape[-1]
    p = resolve_policy(policy)
    if p == "reference" and interpret is None:
        flags = _threshold_flags(x, tau)
        return jnp.mean(flags, axis=tuple(range(1, x.ndim - 1)))
    flat = x.reshape(n, -1, c)
    hw = flat.shape[1]
    it = resolve_interpret(interpret, p)
    counts = jax.vmap(
        lambda row: signature_td(row, tau=tau, mean=False, interpret=it))(
        flat)
    return counts * (1.0 / np.float32(hw))


def slstm_scan(gates_x, R, c0, n0, h0, m0, *, chunk: int = 256,
               policy=None, interpret=None):
    """R-resident sLSTM recurrence (inference path)."""
    p = resolve_policy(policy)
    if p == "reference" and interpret is None:
        from repro.kernels.ref import slstm_scan_ref
        return slstm_scan_ref(gates_x, R, c0, n0, h0, m0)
    return slstm_scan_bsd(gates_x, R, c0, n0, h0, m0, chunk=chunk,
                          interpret=resolve_interpret(interpret, p))


def mlstm_chunkwise(q, k, v, i_gate, f_gate, *, chunk: int = 128,
                    policy=None, interpret=None):
    """Chunkwise mLSTM with VMEM-resident matrix memory (inference path)."""
    p = resolve_policy(policy)
    if p == "reference" and interpret is None:
        from repro.kernels.ref import mlstm_chunkwise_ref
        return mlstm_chunkwise_ref(q, k, v, i_gate, f_gate)
    return mlstm_chunkwise_bshd(q, k, v, i_gate, f_gate, chunk=chunk,
                                interpret=resolve_interpret(interpret, p))
