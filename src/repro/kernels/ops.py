"""Jitted public wrappers around the Pallas kernels.

Model code calls these through ``Runtime(use_pallas=True)``; on this CPU
container they run in interpret mode (``interpret=True``), on TPU the same
call sites compile to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.selective_scan import selective_scan_bsd
from repro.kernels.signature import signature_td
from repro.kernels.mlstm import mlstm_chunkwise_bshd
from repro.kernels.slstm import slstm_scan_bsd


def flash_attention(q, k, v, *, causal: bool = True, window: int = -1,
                    softcap: float = 0.0, interpret: bool = True):
    """(B,S,H,hd) layout wrapper used by repro.models.attention."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               softcap=softcap, interpret=interpret)
    return out.transpose(0, 2, 1, 3)


def selective_scan(x, dt, A, Bc, Cc, h0, *, chunk: int = 256,
                   interpret: bool = True):
    """Drop-in for repro.models.mamba.selective_scan_ref."""
    return selective_scan_bsd(x, dt, A, Bc, Cc, h0, chunk=chunk,
                              interpret=interpret)


def signature(x, *, tau: float = 0.05, n_sig: int = 64,
              interpret: bool = True):
    """Activation (..., d) -> bucketed signature vector (n_sig,)."""
    d = x.shape[-1]
    flat = x.reshape(-1, d)
    per_channel = signature_td(flat, tau=tau, interpret=interpret)
    pad = (-d) % n_sig
    if pad:
        per_channel = jnp.pad(per_channel, (0, pad))
    return jnp.mean(per_channel.reshape(n_sig, -1), axis=1)


def slstm_scan(gates_x, R, c0, n0, h0, m0, *, chunk: int = 256,
               interpret: bool = True):
    """R-resident sLSTM recurrence (inference path)."""
    return slstm_scan_bsd(gates_x, R, c0, n0, h0, m0, chunk=chunk,
                          interpret=interpret)


def mlstm_chunkwise(q, k, v, i_gate, f_gate, *, chunk: int = 128,
                    interpret: bool = True):
    """Chunkwise mLSTM with VMEM-resident matrix memory (inference path)."""
    return mlstm_chunkwise_bshd(q, k, v, i_gate, f_gate, chunk=chunk,
                                interpret=interpret)
