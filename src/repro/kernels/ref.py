"""Pure-jnp oracles for every Pallas kernel (the allclose contract)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

_NEG = -2.0e9


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = -1,
                        softcap: float = 0.0):
    """q (B,H,S,hd); k,v (B,K,S,hd); GQA by head repetition. f32 math."""
    B, H, S, hd = q.shape
    K = k.shape[1]
    G = H // K
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    rows = jnp.arange(S)[:, None]
    cols = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= rows >= cols
    if window > 0:
        ok &= (rows - cols) < window
    s = jnp.where(ok, s, _NEG)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def selective_scan_seq_ref(x, dt, A, Bc, Cc, h0):
    """Plain sequential scan oracle. Shapes as in selective_scan_bsd."""
    def step(h, xs):
        xt, dtt, bt, ct = xs
        da = jnp.exp(dtt[..., None] * A)
        h = da * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.sum(h * ct[:, None, :], axis=-1)
        return h, y

    xs = tuple(a.transpose(1, 0, 2) for a in (x, dt, Bc, Cc))
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2), h


def signature_ref(x, tau: float = 0.05):
    """x (T, d) -> per-channel zero-fraction (d,)."""
    if tau <= 0.0:
        flags = (x == 0.0)
    else:
        flags = jnp.abs(x) < tau
    return jnp.mean(flags.astype(jnp.float32), axis=0)


def mlstm_chunkwise_ref(q, k, v, i_gate, f_gate):
    """Fresh-state oracle for the chunkwise mLSTM kernel: the sequential
    recurrent formulation from ``models.xlstm`` (the same ground truth the
    kernel parity tests compare against), started from C=0, n=0, m=-inf."""
    from repro.models.xlstm import mlstm_recurrent_ref
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    st0 = {"C": jnp.zeros((B, H, dk, dv)), "n": jnp.zeros((B, H, dk)),
           "m": jnp.full((B, H), -1e30)}
    return mlstm_recurrent_ref(q, k, v, i_gate, f_gate, st0)


def slstm_scan_ref(gates_x, R, c0, n0, h0, m0):
    """Sequential oracle for the sLSTM kernel (same math as models.xlstm)."""
    d = R.shape[0]

    def step(carry, gx_t):
        c, n, h, m = carry
        gates = gx_t + h @ R
        i_t, f_t, z_t, o_t = jnp.split(gates, 4, axis=-1)
        m_new = jnp.maximum(f_t + m, i_t)
        iprime = jnp.exp(i_t - m_new)
        fprime = jnp.exp(f_t + m - m_new)
        c = fprime * c + iprime * jnp.tanh(z_t)
        n = fprime * n + iprime
        h = jax.nn.sigmoid(o_t) * c / jnp.maximum(n, 1e-6)
        return (c, n, h, m_new), h

    (c, n, h, m), hs = jax.lax.scan(step, (c0, n0, h0, m0),
                                    gates_x.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2), (c, n, h, m)
