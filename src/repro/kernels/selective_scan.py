"""Selective-scan (Mamba SSM) Pallas TPU kernel.

TPU adaptation of the CUDA selective-scan: the grid is (batch, n_chunks)
with chunks innermost, so the recurrent state h (d_in, N) persists in VMEM
scratch across chunk steps — HBM sees each input element once and each
output element once, with zero intermediate state traffic (the CUDA kernel's
shared-memory trick mapped onto the TPU memory hierarchy).  Within a chunk
the recurrence is a ``fori_loop`` over timesteps on (d_in, N) vector
registers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref,
            h_ref, *, chunk: int, n_chunks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = h0_ref[0]                       # (d_in, N)

    A = a_ref[...]                                   # (d_in, N)

    def step(t, _):
        xt = x_ref[0, t]                             # (d_in,)
        dtt = dt_ref[0, t]                           # (d_in,)
        bt = b_ref[0, t]                             # (N,)
        ct = c_ref[0, t]                             # (N,)
        h = h_ref[...]
        da = jnp.exp(dtt[:, None] * A)               # (d_in, N)
        h = da * h + (dtt * xt)[:, None] * bt[None, :]
        h_ref[...] = h
        y_ref[0, t] = jnp.sum(h * ct[None, :], axis=1).astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(j == n_chunks - 1)
    def _emit():
        hout_ref[0] = h_ref[...]


def selective_scan_bsd(x, dt, A, Bc, Cc, h0, *, chunk: int = 256,
                       interpret=None):
    """x, dt (B,S,d_in) f32; A (d_in,N); Bc,Cc (B,S,N); h0 (B,d_in,N).

    Returns (y (B,S,d_in), h_last (B,d_in,N)).
    ``interpret=None`` resolves from the platform dispatch policy.
    """
    from repro.kernels.dispatch import resolve_interpret
    interpret = resolve_interpret(interpret)
    B, S, d_in = x.shape
    N = A.shape[1]
    c = min(chunk, S)
    n_chunks = -(-S // c)
    pad = n_chunks * c - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
    Sp = n_chunks * c

    kernel = functools.partial(_kernel, chunk=c, n_chunks=n_chunks)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(B, n_chunks),
        in_specs=[
            pl.BlockSpec((1, c, d_in), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, c, d_in), lambda b, j: (b, j, 0)),
            pl.BlockSpec((d_in, N), lambda b, j: (0, 0)),
            pl.BlockSpec((1, c, N), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, c, N), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, d_in, N), lambda b, j: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, d_in), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, d_in, N), lambda b, j: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sp, d_in), x.dtype),
            jax.ShapeDtypeStruct((B, d_in, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d_in, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bc, Cc, h0)
    return (y[:, :S] if pad else y), h_last
