"""DAG-AFL feature-signature Pallas TPU kernel (paper Eq. 3-4 adaptation).

Computes the per-channel threshold-zero fraction of an activation matrix
(T, d) as a block-tiled VMEM reduction: the grid walks T blocks sequentially
while a (d,) VMEM scratch accumulates counts — the activation tensor is read
from HBM exactly once and no intermediate (T, d) flag tensor is ever
materialised (the pure-jnp path writes one).  The CNN path's exact-zero count
is the tau=0 special case.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, out_ref, acc_ref, *, tau: float, block_t: int,
            n_blocks: int, total_t: int, mean: bool):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                    # (bt, d)
    rows = i * block_t + jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
    valid = rows < total_t
    if tau <= 0.0:
        flags = (x == 0.0) & valid
    else:
        flags = (jnp.abs(x) < tau) & valid
    acc_ref[...] = acc_ref[...] + jnp.sum(flags.astype(jnp.float32), axis=0)

    @pl.when(i == n_blocks - 1)
    def _emit():
        if mean:
            out_ref[...] = acc_ref[...] / total_t
        else:
            out_ref[...] = acc_ref[...]


def signature_td(x, *, tau: float = 0.05, block_t: int = 256,
                 mean: bool = True, interpret=None):
    """x (T, d) -> per-channel zero-fraction (d,) f32.

    ``mean=False`` emits the raw per-channel counts instead of fractions:
    0/1 flag sums are exact integers in f32 (up to 2**24), so callers can
    bucket and normalise them with the exact float ops of the jnp path
    they must stay bit-consistent with (see ``ops.signature``) — whereas
    a fraction cannot be multiplied back into an exact count.

    ``interpret=None`` resolves from the platform dispatch policy
    (``kernels.dispatch``): compiled on TPU, interpreted elsewhere.
    """
    from repro.kernels.dispatch import resolve_interpret
    interpret = resolve_interpret(interpret)
    T, d = x.shape
    bt = min(block_t, T)
    n_blocks = -(-T // bt)
    pad = n_blocks * bt - T
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)), constant_values=1.0)

    kernel = functools.partial(_kernel, tau=tau, block_t=bt,
                               n_blocks=n_blocks, total_t=T, mean=mean)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((bt, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((d,), jnp.float32)],
        interpret=interpret,
    )(x)
