"""sLSTM recurrence Pallas TPU kernel (inference path).

The xLSTM paper's CUDA kernel keeps the recurrent gate matrix R in shared
memory across timesteps; the TPU analogue holds R (d, 4d) in VMEM scratch
for the whole grid row, so HBM traffic is O(S*d) for the gate inputs and
outputs instead of O(S*d^2) for per-step R re-reads — on xlstm-125m
train_4k the per-step R stream was ~60% of the memory roofline term
(EXPERIMENTS.md §Perf H1 iteration 3).

The input-side projection (x @ W + b) is already hoisted out of the loop
(one batched matmul) by the caller, so the kernel consumes precomputed
``gates_x`` and only applies the recurrent part.  Forward-only: training
keeps the XLA scan (a custom VJP would be needed to differentiate through
``pallas_call``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(gx_ref, r_ref, c0_ref, n0_ref, h0_ref, m0_ref,
            hs_ref, c_ref, n_ref, h_ref, m_ref,
            r_vmem, state, *, chunk: int, n_chunks: int, d: int,
            seq_len: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        r_vmem[...] = r_ref[...]                 # R resident for all chunks
        state[0, :] = c0_ref[0]
        state[1, :] = n0_ref[0]
        state[2, :] = h0_ref[0]
        state[3, :] = m0_ref[0]

    R = r_vmem[...]

    def step(t, _):
        c = state[0, :]
        n = state[1, :]
        h = state[2, :]
        m = state[3, :]
        gates = gx_ref[0, t] + h @ R             # (4d,)
        i_t = gates[:d]
        f_t = gates[d:2 * d]
        z_t = gates[2 * d:3 * d]
        o_t = gates[3 * d:]
        m_new = jnp.maximum(f_t + m, i_t)
        iprime = jnp.exp(i_t - m_new)
        fprime = jnp.exp(f_t + m - m_new)
        c_new = fprime * c + iprime * jnp.tanh(z_t)
        n_new = fprime * n + iprime
        h_new = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1e-6)
        # padded timesteps beyond seq_len must not mutate the carried state
        valid = (j * chunk + t) < seq_len
        state[0, :] = jnp.where(valid, c_new, c)
        state[1, :] = jnp.where(valid, n_new, n)
        state[2, :] = jnp.where(valid, h_new, h)
        state[3, :] = jnp.where(valid, m_new, m)
        hs_ref[0, t] = h_new.astype(hs_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(j == n_chunks - 1)
    def _emit():
        c_ref[0] = state[0, :]
        n_ref[0] = state[1, :]
        h_ref[0] = state[2, :]
        m_ref[0] = state[3, :]


def slstm_scan_bsd(gates_x, R, c0, n0, h0, m0, *, chunk: int = 256,
                   interpret=None):
    """gates_x (B,S,4d) f32; R (d,4d); states (B,d).

    Returns (hs (B,S,d), (c,n,h,m) final states).
    ``interpret=None`` resolves from the platform dispatch policy.
    """
    from repro.kernels.dispatch import resolve_interpret
    interpret = resolve_interpret(interpret)
    B, S, d4 = gates_x.shape
    d = d4 // 4
    c = min(chunk, S)
    n_chunks = -(-S // c)
    pad = n_chunks * c - S
    if pad:
        gates_x = jnp.pad(gates_x, ((0, 0), (0, pad), (0, 0)))
    Sp = n_chunks * c

    kernel = functools.partial(_kernel, chunk=c, n_chunks=n_chunks, d=d,
                               seq_len=S)
    hs, cf, nf, hf, mf = pl.pallas_call(
        kernel,
        grid=(B, n_chunks),
        in_specs=[
            pl.BlockSpec((1, c, d4), lambda b, j: (b, j, 0)),
            pl.BlockSpec((d, d4), lambda b, j: (0, 0)),
            pl.BlockSpec((1, d), lambda b, j: (b, 0)),
            pl.BlockSpec((1, d), lambda b, j: (b, 0)),
            pl.BlockSpec((1, d), lambda b, j: (b, 0)),
            pl.BlockSpec((1, d), lambda b, j: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, d), lambda b, j: (b, 0)),
            pl.BlockSpec((1, d), lambda b, j: (b, 0)),
            pl.BlockSpec((1, d), lambda b, j: (b, 0)),
            pl.BlockSpec((1, d), lambda b, j: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sp, d), gates_x.dtype),
            jax.ShapeDtypeStruct((B, d), jnp.float32),
            jax.ShapeDtypeStruct((B, d), jnp.float32),
            jax.ShapeDtypeStruct((B, d), jnp.float32),
            jax.ShapeDtypeStruct((B, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d4), jnp.float32),
                        pltpu.VMEM((4, d), jnp.float32)],
        interpret=interpret,
    )(gates_x, R, c0, n0, h0, m0)
    return (hs[:, :S] if pad else hs), (cf, nf, hf, mf)
