"""Multi-pod dry-run: prove every (arch x input-shape x mesh) combination
lowers, compiles, and fits — and extract the roofline terms.

MUST set the host-device count before ANY other import (jax locks the device
count on first init).
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import functools         # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config  # noqa: E402
from repro.configs.base import ArchConfig, InputShape         # noqa: E402
from repro.launch.hlo_analysis import analyze_hlo  # noqa: E402
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,  # noqa: E402
                               make_production_mesh)
from repro.models import transformer as tfm                   # noqa: E402
from repro.sharding.rules import (MeshPlan, batch_shardings,  # noqa: E402
                                  cache_shardings, opt_state_shardings,
                                  param_shardings, small_model_plan)
from repro.runtime import Runtime                             # noqa: E402
from repro.train.step import (make_serve_decode, make_serve_prefill,  # noqa: E402
                              make_train_step)

_COLLECTIVE_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)",
)

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def input_specs(cfg: ArchConfig, shape: InputShape):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    f32 = jnp.float32
    i32 = jnp.int32
    if shape.mode == "train":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                 "labels": jax.ShapeDtypeStruct((B, S), i32)}
    elif shape.mode == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    else:  # decode
        batch = {"token": jax.ShapeDtypeStruct((B, 1), i32),
                 "pos": jax.ShapeDtypeStruct((), i32)}
    if cfg.encoder is not None and shape.mode in ("train", "prefill"):
        batch["enc_embed"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder.n_ctx, cfg.d_model), f32)
    if cfg.mrope_sections is not None and shape.mode in ("train", "prefill"):
        batch["positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
    return batch


def collective_bytes(hlo_text: str):
    """Sum result-shape bytes of every collective op in the partitioned HLO."""
    totals = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        totals[kind] = totals.get(kind, 0) + n * _DTYPE_BYTES[dtype]
    return totals


def model_flops(cfg: ArchConfig, shape: InputShape) -> float:
    """Useful-FLOPs yardstick: 6·N_active·tokens (train), 2·N_active·tokens
    (forward-only)."""
    n = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.mode != "decode" else 1)
    mult = 6.0 if shape.mode == "train" else 2.0
    return mult * n * tokens


def build_step(cfg: ArchConfig, shape: InputShape, mesh, plan: MeshPlan):
    """Returns (jitted_fn, example_args as ShapeDtypeStructs)."""
    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(lambda k: tfm.init_params(k, cfg), key)
    params_sh = param_shardings(params_shape, cfg, mesh, plan)
    repl = NamedSharding(mesh, P())
    bsize = int(np.prod([mesh.shape[a] for a in plan.batch_axes]))
    runtime = Runtime(want_signature=(shape.mode == "train"),
                      batch_axes=plan.batch_axes, batch_axis_size=bsize,
                      mesh=mesh)

    if shape.mode == "train":
        # H3 (auto plan): gradient accumulation for the giant archs — layer-
        # scan activation carries scale by 1/microbatches
        mb = 1
        if not plan.enable_fsdp or plan.enable_tp is False:
            mb = 1
        if getattr(plan, "_microbatches", 0):
            mb = plan._microbatches
        step, opt = make_train_step(cfg, runtime=runtime, microbatches=mb)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        opt_sh = opt_state_shardings(opt_shape, params_sh, mesh)
        batch = input_specs(cfg, shape)
        batch_sh = batch_shardings(batch, mesh, plan)
        jitted = jax.jit(step,
                         in_shardings=(params_sh, opt_sh, batch_sh),
                         out_shardings=(params_sh, opt_sh, None))
        return jitted, (params_shape, opt_shape, batch)

    if shape.mode == "prefill":
        fn = make_serve_prefill(cfg, runtime=runtime)
        batch = input_specs(cfg, shape)
        batch_sh = batch_shardings(batch, mesh, plan)
        jitted = jax.jit(fn, in_shardings=(params_sh, batch_sh))
        return jitted, (params_shape, batch)

    # decode
    fn = make_serve_decode(cfg, runtime=runtime)
    caches_shape = jax.eval_shape(
        functools.partial(tfm.init_cache, cfg, shape.global_batch,
                          shape.seq_len))
    cache_sh = cache_shardings(caches_shape, cfg, mesh, plan)
    spec = input_specs(cfg, shape)
    token_sh = batch_shardings({"tokens": spec["token"]}, mesh, plan)["tokens"]
    jitted = jax.jit(fn,
                     in_shardings=(params_sh, token_sh, cache_sh, repl),
                     out_shardings=(None, None, cache_sh))
    return jitted, (params_shape, spec["token"], caches_shape, spec["pos"])


def make_plan(cfg: ArchConfig, multi_pod: bool, plan_mode: str = "baseline",
              shape=None) -> MeshPlan:
    """``auto`` = the beyond-paper plan assembled from the §Perf hillclimbs:

    H1  small archs (<3B): pure data parallelism — TP collectives cost
        orders of magnitude more than the model's compute (61x on
        xlstm-125m).  Applied only when the global batch divides the
        widened batch axes (a 256-way batch axis with batch 32 replicates
        everything — measured 90x WORSE; see §Perf refuted-hypotheses).
    H2  decode: no FSDP (per-token weight gathers dominated), bf16 weights,
        2-D expert sharding, 2-D lookup tables.  Prefill keeps the baseline
        plan: its token count amortises FSDP gathers (serving plan measured
        0.26x on deepseek prefill).
    H3  giant-arch training (>100B): gradient accumulation (microbatches=4)
        trades ~1.5x collective for ~4x activation memory — the fit-first
        compromise; bf16 params halve the re-gather cost on real TPUs.
    """
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    n_batch_chips = 256 if not multi_pod else 512
    if plan_mode == "auto":
        if cfg.param_count() < 3e9 and shape is not None \
                and shape.global_batch % n_batch_chips == 0:
            return small_model_plan(batch_axes, "model", cfg.param_count())
        if shape is not None and shape.mode == "train" \
                and cfg.param_count() > 1e11:
            plan = MeshPlan(batch_axes=batch_axes)
            object.__setattr__(plan, "_microbatches", 4)
            return plan
        if shape is not None and shape.mode == "decode":
            return MeshPlan(batch_axes=batch_axes, enable_fsdp=False,
                            expert_data_shard=cfg.moe is not None,
                            dense_2d_shard=True)
    return MeshPlan(batch_axes=batch_axes)


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            out_dir: str = "experiments/dryrun", verbose: bool = True,
            plan_mode: str = "baseline", tag_suffix: str = ""):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if plan_mode == "auto" and shape.mode == "decode":
        # serving weights in bf16 (inference-standard; halves HBM + traffic)
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    if plan_mode == "auto" and shape.mode == "train" \
            and cfg.param_count() > 1e11:
        # giant-arch training: bf16 param storage halves the FSDP all-gather
        # traffic that gradient accumulation multiplies (bf16 master weights
        # + bf16 moments; stochastic-rounding caveat noted in EXPERIMENTS)
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16",
                                  moment_dtype="bfloat16")
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    plan = make_plan(cfg, multi_pod, plan_mode, shape)

    t0 = time.time()
    record = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
              "mesh": dict(mesh.shape), "n_chips": n_chips, "ok": False,
              "plan": plan_mode}
    try:
        jitted, args = build_step(cfg, shape, mesh, plan)
        with mesh:
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        analysis = analyze_hlo(hlo)
        coll = {k: float(v) for k, v in analysis.colls.items()}
        coll_total = float(analysis.collective_bytes)
        flops = float(analysis.flops)
        bytes_acc = float(analysis.bytes)
        mf = model_flops(cfg, shape)
        raw = {"flops": float(cost.get("flops", 0.0)),
               "bytes accessed": float(cost.get("bytes accessed", 0.0))}

        # memory_analysis fields (per device)
        mem_fields = {}
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            mem_fields[f] = int(getattr(mem, f, 0) or 0)
        args_b = mem_fields["argument_size_in_bytes"]
        temp_b = mem_fields["temp_size_in_bytes"]

        # roofline terms (cost_analysis is the per-partition SPMD module)
        t_compute = flops / PEAK_FLOPS_BF16
        t_memory = bytes_acc / HBM_BW
        t_coll = coll_total / ICI_BW
        terms = {"compute_s": t_compute, "memory_s": t_memory,
                 "collective_s": t_coll}
        dominant = max(terms, key=terms.get)

        record.update({
            "ok": True,
            "xla_cost_analysis_raw": raw,
            "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
            "hlo_flops_per_chip": flops,
            "hlo_bytes_per_chip": bytes_acc,
            "collective_bytes_per_chip": coll_total,
            "collectives": coll,
            "memory_analysis": mem_fields,
            "model_flops_global": mf,
            "model_flops_per_chip": mf / n_chips,
            "useful_flop_ratio": (mf / n_chips) / flops if flops else None,
            "roofline": terms,
            "dominant": dominant,
            "step_time_bound_s": max(terms.values()),
            "hbm_gib_per_chip": (args_b + temp_b) / 2 ** 30,
        })
        if verbose:
            print(f"[{arch} x {shape_name}{' x multipod' if multi_pod else ''}] "
                  f"OK lower={t_lower:.1f}s compile={t_compile:.1f}s")
            print(f"  mem/chip: args={args_b/2**30:.2f}GiB "
                  f"temp={temp_b/2**30:.2f}GiB")
            print(f"  flops/chip={flops:.3e} bytes/chip={bytes_acc:.3e} "
                  f"coll/chip={coll_total:.3e}")
            print(f"  terms: compute={t_compute*1e3:.2f}ms "
                  f"memory={t_memory*1e3:.2f}ms coll={t_coll*1e3:.2f}ms "
                  f"-> {dominant} dominates; useful-flop ratio="
                  f"{record['useful_flop_ratio'] and round(record['useful_flop_ratio'],3)}")
    except Exception as e:  # noqa: BLE001
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[{arch} x {shape_name}] FAILED: {record['error']}")

    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape_name}" + ("__multipod" if multi_pod else "")         + tag_suffix
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(record, f, indent=2, default=str)
    return record


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS) + [None])
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) baseline")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--plan", default="baseline",
                    choices=["baseline", "auto"],
                    help="auto = beyond-paper sharding optimizations")
    args = ap.parse_args()

    if args.all:
        results = []
        for arch in ARCH_IDS:
            for shape in INPUT_SHAPES:
                results.append(run_one(
                    arch, shape, args.multi_pod, args.out,
                    plan_mode=args.plan,
                    tag_suffix="__opt" if args.plan == "auto" else ""))
        ok = sum(r["ok"] for r in results)
        print(f"\n{ok}/{len(results)} combinations lowered+compiled")
        raise SystemExit(0 if ok == len(results) else 1)

    assert args.arch and args.shape, "--arch and --shape (or --all)"
    rec = run_one(args.arch, args.shape, args.multi_pod, args.out,
                  plan_mode=args.plan,
                  tag_suffix="__opt" if args.plan == "auto" else "")
    raise SystemExit(0 if rec["ok"] else 1)


if __name__ == "__main__":
    main()
