"""Trip-count-aware cost analysis over compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, which
undercounts scanned layer stacks by the trip count (verified in this repo).
This analyzer parses the HLO text, builds the computation call graph, and
multiplies per-computation costs through ``fusion``/``call``/``while`` sites
(using the ``known_trip_count`` backend config XLA attaches to static loops).

Cost model per instruction:
  flops  : dot = 2 * prod(result_shape) * contraction_size; convolution =
           2 * prod(result) * prod(kernel_spatial) * in_channels (approx);
           elementwise ignored (negligible next to matmuls here).
  bytes  : matmul-centric HBM-traffic model (TPU roofline practice):
           dot/convolution operands + results (weights and activations
           streamed through the MXU), gather results (embedding lookups),
           dynamic-slice results, and 2x dynamic-update-slice updates (KV
           cache read-modify-write).  Elementwise chains, masks, converts
           and copies are assumed fused on TPU (XLA CPU materialises many
           of them — counting those would charge the TPU roofline for CPU
           lowering artifacts, observed at 10-30x the true traffic).
  colls  : result bytes per collective kind (all-reduce / all-gather /
           reduce-scatter / all-to-all / collective-permute), trip-count
           multiplied like everything else.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_ZERO_COST_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast", "after-all", "partition-id", "replica-id",
                  "opt-barrier",
                  # dtype converts: XLA CPU materialises f32 copies of bf16
                  # buffers (no native bf16 ALUs); on TPU converts fuse into
                  # the consuming op, so they carry no HBM traffic of their
                  # own — excluded from the TPU roofline bytes model.
                  "convert"}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.+)$")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->\s*.+\{$")
_CALL_RE = re.compile(r"(?:calls|to_apply|body)=(%[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d.strip()]
    return m.group(1), dims


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    colls: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.colls.items():
            self.colls[k] = self.colls.get(k, 0.0) + v * mult

    @property
    def collective_bytes(self) -> float:
        return sum(self.colls.values())


@dataclass
class _Instr:
    name: str
    type_str: str
    op: str
    rest: str


def _call_body(instr: _Instr) -> str:
    """Text after the op's call paren.  Splitting on ``op + "("`` (not the
    first "(") keeps tiled-layout annotations in the result-type prefix —
    e.g. ``f32[64,32]{1,0:T(8,128)}`` in post-optimization TPU HLO — from
    being mistaken for the operand list."""
    parts = instr.rest.split(instr.op + "(", 1)
    return parts[1] if len(parts) > 1 else ""


def _parse_computations(text: str) -> Dict[str, List[_Instr]]:
    comps: Dict[str, List[_Instr]] = {}
    cur: Optional[str] = None
    entry_marker = None
    for line in text.splitlines():
        stripped = line.strip()
        header = _COMP_HEADER_RE.match(stripped)
        if header:
            cur = header.group(1)
            comps[cur] = []
            if stripped.startswith("ENTRY"):
                entry_marker = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # rhs = "type op(operands), attrs..."; type is an array type with
        # optional layout, or a (possibly one-level-nested) tuple type
        sm = re.match(
            r"((?:\((?:[^()]|\([^()]*\))*\)"
            r"|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+([\w\-]+)\(", rhs)
        if not sm:
            continue
        comps[cur].append(_Instr(name, sm.group(1), sm.group(2), rhs))
    if entry_marker:
        comps["__entry__"] = comps[entry_marker]
    return comps


def _dot_flops(instr: _Instr, symbols: Dict[str, str]) -> float:
    out = _shape_dims(instr.type_str)
    if out is None:
        return 0.0
    _, out_dims = out
    result = 1.0
    for d in out_dims:
        result *= d
    # contraction size from lhs operand shape + lhs_contracting_dims;
    # operands appear as "dot(<type> %lhs, <type> %rhs)" in compiled HLO,
    # so take the %-names inside the call parens ("),": operand types may
    # carry parens in TPU tile annotations, a bare ")" cuts too early)
    names = re.findall(r"(%[\w.\-]+)", _call_body(instr).split("),", 1)[0])
    lhs_type = symbols.get(names[0], "") if names else ""
    lhs = _shape_dims(lhs_type)
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
    contraction = 1.0
    if lhs and cm:
        for idx in cm.group(1).split(","):
            if idx.strip():
                i = int(idx)
                if i < len(lhs[1]):
                    contraction *= lhs[1][i]
    return 2.0 * result * contraction


def _conv_flops(instr: _Instr, symbols: Dict[str, str]) -> float:
    out = _shape_dims(instr.type_str)
    if out is None:
        return 0.0
    result = 1.0
    for d in out[1]:
        result *= d
    ops = re.findall(r"(%[\w.\-]+)", _call_body(instr))
    kernel = _shape_dims(symbols.get(ops[1], "")) if len(ops) > 1 else None
    k = 1.0
    if kernel:
        for d in kernel[1][:-1]:          # spatial x in_channels (approx)
            k *= d
    return 2.0 * result * k


def analyze_hlo(text: str) -> Cost:
    comps = _parse_computations(text)
    memo: Dict[str, Cost] = {}

    # fusion computations that only convert dtypes (XLA CPU's wrapped bf16
    # converts): zero HBM traffic on TPU, where converts fuse into consumers
    convert_like = {
        name for name, instrs in comps.items()
        if instrs and all(i.op in _ZERO_COST_OPS or i.op == "convert"
                          for i in instrs)
    }

    def cost_of(comp_name: str, stack=()) -> Cost:
        if comp_name in memo:
            return memo[comp_name]
        if comp_name in stack or comp_name not in comps:
            return Cost()
        total = Cost()
        symbols: Dict[str, str] = {}
        for ins in comps[comp_name]:
            symbols[ins.name] = ins.type_str
        for ins in comps[comp_name]:
            op = ins.op
            if op in _ZERO_COST_OPS:
                continue

            def operand_names():
                return re.findall(r"(%[\w.\-]+)",
                                  _call_body(ins).split("),", 1)[0])

            own = Cost()
            if op == "dynamic-update-slice":
                names = operand_names()
                upd = _shape_bytes(symbols.get(names[1], "")) if len(names) > 1 else 0
                own.bytes = 2 * upd
            elif op in ("dynamic-slice", "gather", "scatter", "reduce",
                        "reduce-window"):
                own.bytes = _shape_bytes(ins.type_str)
            elif op == "dot":
                own.flops = _dot_flops(ins, symbols)
                own.bytes = _shape_bytes(ins.type_str)
                for oname in operand_names():
                    own.bytes += _shape_bytes(symbols.get(oname, ""))
            elif op == "convolution":
                own.flops = _conv_flops(ins, symbols)
                own.bytes = _shape_bytes(ins.type_str)
                for oname in operand_names():
                    own.bytes += _shape_bytes(symbols.get(oname, ""))
            for coll in _COLLECTIVES:
                if op == coll or op.startswith(coll + "-"):
                    own.colls[coll] = float(_shape_bytes(ins.type_str))
            total.add(own)
            # call graph
            if op == "fusion" or op == "call" or op == "custom-call":
                cm = _CALL_RE.search(ins.rest)
                if cm:
                    callee = cost_of(cm.group(1), stack + (comp_name,))
                    total.add(Cost(flops=callee.flops, colls=callee.colls))
            elif op == "while":
                bm = _CALL_RE.search(ins.rest)
                tm = _TRIP_RE.search(ins.rest)
                trips = float(tm.group(1)) if tm else 1.0
                if bm:
                    total.add(cost_of(bm.group(1), stack + (comp_name,)),
                              mult=trips)
                cm2 = _COND_RE.search(ins.rest)
                if cm2:
                    total.add(cost_of(cm2.group(1), stack + (comp_name,)),
                              mult=trips)
            elif op == "conditional":
                bm = _BRANCHES_RE.search(ins.rest)
                if bm:
                    branch_costs = [cost_of(b.strip(), stack + (comp_name,))
                                    for b in bm.group(1).split(",")]
                    if branch_costs:
                        best = max(branch_costs, key=lambda c: c.flops + c.bytes)
                        total.add(best)
        memo[comp_name] = total
        return total

    return cost_of("__entry__")
