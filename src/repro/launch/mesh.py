"""Production mesh builders.

Functions (not module constants) so importing never touches jax device
state.  Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod: (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis joins "data" for batch/FSDP sharding (DCN-side data parallelism).
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, found {len(devices)}; the dry-run must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before any "
            "jax import")
    dev = np.asarray(devices[:n]).reshape(shape)
    from jax.sharding import Mesh
    return Mesh(dev, axes)


def make_host_mesh(data: int = 1, model: int = 1, strict: bool = True):
    """Small mesh over however many devices this host actually has (tests).

    ``strict=False`` degrades instead of raising: the ``data`` axis shrinks
    first (the ``model`` axis is kept while it fits, since shrinking it
    changes which collectives a program needs); a ``model`` axis larger than
    the host shrinks too rather than raise."""
    import jax
    avail = len(jax.devices())
    if data * model > avail:
        if strict:
            raise RuntimeError(f"need {data * model} devices, have {avail}")
        model = min(model, avail)
        data = max(avail // model, 1)
    n = data * model
    devices = jax.devices()[:n]
    from jax.sharding import Mesh
    return Mesh(np.asarray(devices).reshape(data, model), ("data", "model"))


def make_cohort_mesh(n_clients: int, axis: str = "clients",
                     data: int = 1, data_axis: str = "data"):
    """Client-axis mesh for the SPMD cohort engine, clamped to the devices
    this host actually has — it NEVER raises for lack of devices.

    ``data=1`` (default) builds the 1-D ``(clients,)`` mesh.  ``data=D``
    builds the 2-D ``(clients, data)`` mesh: each client group's TRAINING
    DATA (the per-step batch axis) additionally shards ``D`` ways, with
    per-group gradient psums re-replicating the client models (see
    ``repro.fl.cohort``).  Clamping degrades cleanly: the ``data`` axis
    shrinks to the host first, then the client axis to whatever devices
    remain — so a 1-device host always yields a 1-device 1-D mesh, which
    the cohort engine treats as "no mesh" (the exact single-device ``vmap``
    path), and callers can use this unconditionally as their default.  Ask
    for more devices with ``XLA_FLAGS=--xla_force_host_platform_device_
    count=N`` (set before any jax import) on CPU, e.g. in CI."""
    import jax
    avail = len(jax.devices())
    d = max(1, min(int(data), avail))
    c = max(1, min(int(n_clients), avail // d))
    from jax.sharding import Mesh
    if d == 1:
        # exact back-compat 1-D mesh: no vestigial size-1 data axis
        return Mesh(np.asarray(jax.devices()[:c]), (axis,))
    dev = np.asarray(jax.devices()[:c * d]).reshape(c, d)
    return Mesh(dev, (axis, data_axis))


# TPU v5e hardware constants (roofline targets)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes / s per chip
ICI_BW = 50e9                   # bytes / s per link
