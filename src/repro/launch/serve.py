"""Serving launcher: batched prefill + greedy decode with a KV cache.

The prefill/decode program construction and the greedy KV-cache decode loop
live here as reusable functions (``make_serving_fns`` / ``greedy_decode`` /
``extend_caches``) — the live-traffic consensus-serving path
(:mod:`repro.fl.serving`) drives the same programs against DAG frontier
replicas that this CLI drives against freshly initialized params.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import transformer as tfm
from repro.models.attention import cache_seq_axis
from repro.runtime import Runtime
from repro.train.step import make_serve_decode, make_serve_prefill


def extend_caches(caches, cfg, extra: int):
    """Grow every attention cache by ``extra`` slots along its SEQUENCE
    axis.  The axis is derived from the cache spec
    (:data:`repro.models.attention.KV_CACHE_TRAILING_DIMS`, counted from the
    trailing end), not hardcoded: prefill-collected caches carry a leading
    stacked-layer axis, per-layer caches do not, and both layouts must
    extend correctly."""
    out = []
    for si, stage in enumerate(cfg.stages):
        d = {}
        for j, spec in enumerate(stage.pattern):
            cc = dict(caches[si][f"l{j}"])
            if spec.kind == "attn":
                for kk in ("k", "v", "ckv", "krope"):
                    if kk in cc:
                        pad = [(0, 0)] * cc[kk].ndim
                        pad[cache_seq_axis(kk, cc[kk].ndim)] = (0, extra)
                        cc[kk] = jnp.pad(cc[kk], pad)
            d[f"l{j}"] = cc
        out.append(d)
    return out


def make_serving_fns(cfg, runtime: Optional[Runtime] = None):
    """The jitted (prefill, decode) pair for one arch config.  ``runtime``
    carries the kernel-dispatch policy (see :func:`repro.runtime.
    serve_runtime`); the decode step has no static arguments — every input
    (params, token, caches, pos) is traced."""
    runtime = Runtime() if runtime is None else runtime
    prefill = jax.jit(make_serve_prefill(cfg, runtime))
    decode = jax.jit(make_serve_decode(cfg, runtime))
    return prefill, decode


def greedy_decode(prefill_fn, decode_fn, cfg, params, batch,
                  new_tokens: int):
    """Prefill ``batch`` then greedy-decode ``new_tokens`` against the KV
    cache.  Returns {tokens (B, new_tokens) int32, prefill_s, decode_s};
    both clock reads are synced on the device results."""
    prompt_len = batch["tokens"].shape[1]
    t0 = time.time()
    last_logits, caches = prefill_fn(params, batch)
    caches = extend_caches(caches, cfg, new_tokens)
    jax.block_until_ready(last_logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(last_logits, -1).astype(jnp.int32)[:, None]
    generated = [tok]
    t0 = time.time()
    for step in range(new_tokens - 1):
        pos = jnp.int32(prompt_len + step)
        tok, logits, caches = decode_fn(params, tok, caches, pos)
        tok = tok[:, None] if tok.ndim == 1 else tok
        generated.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    return {"tokens": jnp.concatenate(generated, axis=1),
            "prefill_s": t_prefill, "decode_s": t_decode}


def serve(cfg, batch: int, prompt_len: int, new_tokens: int, seed: int = 0):
    prefill, decode = make_serving_fns(cfg)
    key = jax.random.PRNGKey(seed)
    params = tfm.init_params(key, cfg)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)
    b = {"tokens": prompts}
    if cfg.encoder is not None:
        b["enc_embed"] = jax.random.normal(
            key, (batch, cfg.encoder.n_ctx, cfg.d_model)) * 0.1

    r = greedy_decode(prefill, decode, cfg, params, b, new_tokens)
    return {
        "prefill_s": r["prefill_s"],
        "decode_s": r["decode_s"],
        "decode_tok_per_s": batch * (new_tokens - 1) / max(r["decode_s"],
                                                           1e-9),
        "tokens": r["tokens"],
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="internlm2-1.8b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(reduced(cfg), compute_dtype="float32")
    r = serve(cfg, args.batch, args.prompt, args.new_tokens)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt} "
          f"new={args.new_tokens}")
    print(f"prefill={r['prefill_s']*1e3:.1f}ms decode={r['decode_s']*1e3:.1f}ms "
          f"({r['decode_tok_per_s']:.1f} tok/s)")
    print("sample:", r["tokens"][0, :12].tolist())


if __name__ == "__main__":
    main()
