"""Serving launcher: batched prefill + greedy decode with a KV cache."""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import transformer as tfm
from repro.runtime import Runtime
from repro.train.step import make_serve_decode, make_serve_prefill


def extend_caches(caches, cfg, extra: int):
    out = []
    for si, stage in enumerate(cfg.stages):
        d = {}
        for j, spec in enumerate(stage.pattern):
            cc = dict(caches[si][f"l{j}"])
            if spec.kind == "attn":
                for kk in ("k", "v", "ckv", "krope"):
                    if kk in cc:
                        pad = [(0, 0)] * cc[kk].ndim
                        pad[2] = (0, extra)
                        cc[kk] = jnp.pad(cc[kk], pad)
            d[f"l{j}"] = cc
        out.append(d)
    return out


def serve(cfg, batch: int, prompt_len: int, new_tokens: int, seed: int = 0):
    runtime = Runtime()
    prefill = jax.jit(make_serve_prefill(cfg, runtime))
    decode = jax.jit(make_serve_decode(cfg, runtime),
                     static_argnames=())
    key = jax.random.PRNGKey(seed)
    params = tfm.init_params(key, cfg)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)
    b = {"tokens": prompts}
    if cfg.encoder is not None:
        b["enc_embed"] = jax.random.normal(
            key, (batch, cfg.encoder.n_ctx, cfg.d_model)) * 0.1

    t0 = time.time()
    last_logits, caches = prefill(params, b)
    caches = extend_caches(caches, cfg, new_tokens)
    jax.block_until_ready(last_logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(last_logits, -1).astype(jnp.int32)[:, None]
    generated = [tok]
    t0 = time.time()
    for step in range(new_tokens - 1):
        pos = jnp.int32(prompt_len + step)
        tok, logits, caches = decode(params, tok, caches, pos)
        tok = tok[:, None] if tok.ndim == 1 else tok
        generated.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    toks = jnp.concatenate(generated, axis=1)
    return {
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tok_per_s": batch * (new_tokens - 1) / max(t_decode, 1e-9),
        "tokens": toks,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="internlm2-1.8b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(reduced(cfg), compute_dtype="float32")
    r = serve(cfg, args.batch, args.prompt, args.new_tokens)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt} "
          f"new={args.new_tokens}")
    print(f"prefill={r['prefill_s']*1e3:.1f}ms decode={r['decode_s']*1e3:.1f}ms "
          f"({r['decode_tok_per_s']:.1f} tok/s)")
    print("sample:", r["tokens"][0, :12].tolist())


if __name__ == "__main__":
    main()
