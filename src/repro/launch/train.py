"""Training launcher: single-host execution of any --arch config.

``--reduced`` runs the 2-layer family member (CPU-friendly); without it the
full config is used (requires accelerators).  ``--dagafl N`` federates N
clients through the DAG-AFL coordinator instead of single-stream training.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced
from repro.data.pipeline import TokenPipeline
from repro.models import transformer as tfm
from repro.runtime import Runtime
from repro.train.checkpoint import save_checkpoint
from repro.train.step import make_train_step


def train_single(cfg, args):
    runtime = Runtime(want_signature=True, use_pallas=args.pallas,
                      kernel_policy=args.kernel_policy or "auto")
    step, opt = make_train_step(cfg, runtime=runtime)
    jstep = jax.jit(step)
    key = jax.random.PRNGKey(args.seed)
    params = tfm.init_params(key, cfg)
    opt_state = opt.init(params)
    pipe = TokenPipeline(cfg.vocab_size, args.batch, args.seq, seed=args.seed)
    it = iter(pipe)
    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_dict(next(it)).items()}
        if cfg.encoder is not None:
            batch["enc_embed"] = jnp.zeros(
                (args.batch, cfg.encoder.n_ctx, cfg.d_model), jnp.float32)
        params, opt_state, m = jstep(params, opt_state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            # jstep dispatches asynchronously: sync before reading the clock
            # or tok/s measures dispatch latency, not compute
            jax.block_until_ready((params, m))
            dt = time.time() - t0
            tok_s = args.batch * args.seq * (i + 1) / max(dt, 1e-9)
            print(f"step {i:5d} loss={float(m['loss']):.4f} "
                  f"grad_norm={float(m['grad_norm']):.3f} tok/s={tok_s:,.0f}")
    if args.checkpoint:
        save_checkpoint(args.checkpoint, params, step=args.steps)
        print(f"saved {args.checkpoint}")
    return params


def train_dagafl(cfg, args):
    from repro.core import DagAflConfig, DagAflCoordinator
    from repro.core.simulator import CostModel, make_profiles
    from repro.data import make_lm_dataset
    from repro.fl.backend import LMBackend

    backend = LMBackend(cfg, lr=args.lr, local_steps=args.local_steps,
                        batch_size=args.batch, seq_len=args.seq,
                        kernel_policy=args.kernel_policy or None)
    streams = [make_lm_dataset(vocab=cfg.vocab_size, n_tokens=50_000,
                               order=1.5 + 0.5 * c, seed=c)
               for c in range(args.dagafl)]
    client_data = [{"train": s, "val": s, "test": s} for s in streams]
    global_test = make_lm_dataset(vocab=cfg.vocab_size, n_tokens=50_000,
                                  seed=999)
    dcfg = DagAflConfig(n_clients=args.dagafl, max_rounds=args.rounds,
                        local_epochs=args.local_steps, seed=args.seed,
                        kernel_policy=args.kernel_policy or None)
    coord = DagAflCoordinator(backend, client_data, global_test, dcfg,
                              CostModel(), make_profiles(args.dagafl))
    res = coord.run()
    print(res.row())
    print("chain:", res.extra)
    return res


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="internlm2-1.8b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--kernel-policy", default="",
                    choices=["", "auto", "compiled", "interpret", "reference"],
                    help="kernel dispatch policy for the Pallas hot paths "
                         "(empty = incumbent stock-XLA math; see "
                         "repro.kernels.dispatch)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--dagafl", type=int, default=0,
                    help="federate N clients via DAG-AFL")
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--local-steps", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(reduced(cfg), compute_dtype="float32")
    print(f"arch={cfg.name} params≈{cfg.param_count()/1e6:.1f}M")
    if args.dagafl:
        train_dagafl(cfg, args)
    else:
        train_single(cfg, args)


if __name__ == "__main__":
    main()
