from repro.models import transformer
from repro.models.cnn import cnn_accuracy, cnn_forward, cnn_loss, init_cnn
from repro.models.transformer import (decode_step, forward, init_cache,
                                      init_params, loss_fn, prefill)

__all__ = ["transformer", "init_params", "init_cache", "forward", "loss_fn",
           "prefill", "decode_step", "init_cnn", "cnn_forward", "cnn_loss",
           "cnn_accuracy"]
