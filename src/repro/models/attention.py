"""Attention blocks: GQA (windowed / soft-capped / biased / M-RoPE) and MLA.

Three score paths keep the traced memory realistic for the dry-run:
  - ``_dense_attn``   : materialised scores, small sequences & cross-attn;
  - ``_chunked_attn`` : online-softmax scan over kv chunks (flash-style HLO
                        memory), full-causal long sequences;
  - ``_banded_attn``  : scan over q blocks with a static kv band, sliding
                        window layers (flops ~ S*(W+bq) instead of S^2).

Decode (q_len = 1) uses dense scores over the cache; MLA decode uses the
absorbed form (scores in latent space, no per-head key expansion) and caches
only ``c_kv`` + the shared RoPE key — the MLA memory saving the paper's
DeepSeek-V2 source motivates.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec
from repro.models.layers import apply_norm, apply_rope, dense_init, init_norm, softcap

_NEG = -2.0e9
_DENSE_MAX = 2048          # above this, use chunked/banded paths
_KV_CHUNK = 1024
_Q_BLOCK = 512


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_attn(key, cfg: ArchConfig, spec: LayerSpec, dtype):
    d = cfg.d_model
    if cfg.mla is not None:
        m = cfg.mla
        keys = jax.random.split(key, 6)
        q_head = m.qk_nope_dim + m.qk_rope_dim
        p = {}
        if m.q_lora_rank:
            p["wq_a"] = dense_init(keys[0], d, m.q_lora_rank, dtype)
            p["q_norm"] = init_norm(cfg.norm, m.q_lora_rank, dtype)
            p["wq_b"] = dense_init(keys[1], m.q_lora_rank,
                                   cfg.n_heads * q_head, dtype)
        else:
            p["wq"] = dense_init(keys[0], d, cfg.n_heads * q_head, dtype)
        p["wkv_a"] = dense_init(keys[2], d, m.kv_lora_rank + m.qk_rope_dim, dtype)
        p["kv_norm"] = init_norm(cfg.norm, m.kv_lora_rank, dtype)
        p["wkv_b"] = dense_init(keys[3], m.kv_lora_rank,
                                cfg.n_heads * (m.qk_nope_dim + m.v_head_dim), dtype)
        p["wo"] = dense_init(keys[4], cfg.n_heads * m.v_head_dim, d, dtype)
        return p
    keys = jax.random.split(key, 5)
    p = {
        "wq": dense_init(keys[0], d, cfg.q_dim, dtype),
        "wk": dense_init(keys[1], d, cfg.kv_dim, dtype),
        "wv": dense_init(keys[2], d, cfg.kv_dim, dtype),
        "wo": dense_init(keys[3], cfg.q_dim, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dtype)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dtype)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dtype)
    if spec.cross_attn:
        p["xwq"] = dense_init(keys[4], d, cfg.q_dim, dtype)
        kx = jax.random.split(keys[4], 3)
        p["xwk"] = dense_init(kx[0], d, cfg.kv_dim, dtype)
        p["xwv"] = dense_init(kx[1], d, cfg.kv_dim, dtype)
        p["xwo"] = dense_init(kx[2], cfg.q_dim, d, dtype)
    return p


# The KV-cache layout spec: number of trailing dims AFTER the sequence axis
# for each cache entry ("k"/"v": (n_kv_heads, head_dim); MLA "ckv"/"krope":
# (rank,)).  Any number of leading axes may be stacked in front (the layer
# axis the stage scan adds, or none at all), so code that grows a cache
# along its sequence axis must derive the axis from this spec — counting
# from the END — never hardcode an index from the front.
KV_CACHE_TRAILING_DIMS = {"k": 2, "v": 2, "ckv": 1, "krope": 1}


def cache_seq_axis(key: str, ndim: int) -> int:
    """Sequence axis of a KV-cache entry, for any number of leading axes."""
    return ndim - 1 - KV_CACHE_TRAILING_DIMS[key]


def init_kv_cache(cfg: ArchConfig, spec: LayerSpec, batch: int, max_seq: int,
                  dtype=None, leading: tuple = ()):
    """Zero cache for one attention layer (stacked over ``leading``)."""
    dtype = jnp.dtype(cfg.cache_dtype) if dtype is None else dtype
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "ckv": jnp.zeros(leading + (batch, max_seq, m.kv_lora_rank), dtype),
            "krope": jnp.zeros(leading + (batch, max_seq, m.qk_rope_dim), dtype),
        }
    shape = leading + (batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# masks and score paths
# ---------------------------------------------------------------------------


def _mask_bias(q_pos, k_pos, causal: bool, window: int):
    """(Sq, Sk) additive bias from 1-D position vectors (sequence positions
    are uniform across the batch in every path, so the mask never carries a
    batch dim — this keeps the traced mask O(S^2), not O(B*S^2))."""
    diff = q_pos[:, None] - k_pos[None, :]
    ok = jnp.ones(diff.shape, bool)
    if causal:
        ok &= diff >= 0
    if window > 0:
        ok &= diff < window
    return jnp.where(ok, 0.0, _NEG).astype(jnp.float32)


def _sdpa(q, k, v, bias, cap: float):
    """q (B,Sq,H,hd) k,v (B,Sk,K,hd) bias (Sq,Sk) -> (B,Sq,H,hd).

    k/v stay in their storage dtype (bf16 caches) — the MXU accumulates in
    f32 via ``preferred_element_type``, so no cache-wide f32 convert is ever
    materialised (that convert dominated decode HBM traffic before).
    """
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qs = (q.astype(jnp.float32) * (1.0 / math.sqrt(hd))).astype(k.dtype)
    qs = qs.reshape(B, Sq, K, G, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qs, k,
                        preferred_element_type=jnp.float32)
    scores = softcap(scores, cap)
    scores = scores + bias
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def _dense_attn(q, k, v, q_pos, k_pos, causal, window, cap):
    bias = _mask_bias(q_pos, k_pos, causal, window)          # (B,Sq,Sk)
    return _sdpa(q, k, v, bias, cap)


def _chunked_attn(q, k, v, q_pos, k_pos, causal, cap, chunk=_KV_CHUNK):
    """Online-softmax scan over kv chunks. Full causal, O(S*chunk) memory."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    K = k.shape[2]
    G = H // K
    n_chunks = -(-Sk // chunk)
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # pad keys at +inf-like positions so the causal mask kills them
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=10 ** 9)
    kc = k.reshape(B, n_chunks, chunk, K, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, K, hd).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(n_chunks, chunk)

    qf = ((q.astype(jnp.float32) * (1.0 / math.sqrt(hd)))
          .astype(k.dtype).reshape(B, Sq, K, G, hd))

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, pb = xs
        s = jnp.einsum("bqkgd,bckd->bkgqc", qf, kb,
                       preferred_element_type=jnp.float32)
        s = softcap(s, cap)
        bias = _mask_bias(q_pos, pb, causal, -1)             # (Sq,C)
        s = s + bias
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, K, G, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)


def _banded_attn(q, k, v, q_pos, k_pos, window, cap, q_block=_Q_BLOCK):
    """Sliding-window causal attention: scan over q blocks, static kv band."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    K = k.shape[2]
    band = window + q_block
    nq = -(-Sq // q_block)
    pad_q = nq * q_block - Sq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad_q), constant_values=-(10 ** 9))
    if Sk < band:
        k = jnp.pad(k, ((0, 0), (0, band - Sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, band - Sk), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, band - Sk), constant_values=-(10 ** 9))
        Sk = band
    qb = q.reshape(B, nq, q_block, H, hd).transpose(1, 0, 2, 3, 4)
    qp = q_pos.reshape(nq, q_block)
    idx = jnp.arange(nq)

    def per_block(i, qblk, qpos_blk):
        start = jnp.maximum(i * q_block + q_block - band, 0)
        start = jnp.minimum(start, Sk - band)
        kb = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
        pb = jax.lax.dynamic_slice_in_dim(k_pos, start, band, axis=0)
        bias = _mask_bias(qpos_blk, pb, True, window)        # (bq,band)
        return _sdpa(qblk, kb, vb, bias, cap)

    out = jax.lax.map(lambda xs: per_block(*xs), (idx, qb, qp))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_block, H, hd)
    return out[:, :Sq]


def scaled_attention(q, k, v, q_pos, k_pos, *, causal: bool, window: int,
                     cap: float, runtime=None):
    """Dispatch over score paths (and the Pallas kernel when enabled).

    q_pos (Sq,), k_pos (Sk,): 1-D global sequence positions.
    """
    Sq, Sk = q.shape[1], k.shape[1]
    if runtime is not None and getattr(runtime, "use_pallas", False) \
            and causal and Sq == Sk:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=True, window=window,
                                    softcap=cap,
                                    policy=kops.policy_from_runtime(runtime))
    if window > 0 and causal and Sq == Sk and Sq > _DENSE_MAX:
        return _banded_attn(q, k, v, q_pos, k_pos, window, cap)
    if max(Sq, Sk) <= _DENSE_MAX or Sq != Sk:
        return _dense_attn(q, k, v, q_pos, k_pos, causal, window, cap)
    return _chunked_attn(q, k, v, q_pos, k_pos, causal, cap)


# ---------------------------------------------------------------------------
# GQA attention layer (full-sequence and decode)
# ---------------------------------------------------------------------------


def _project_qkv(params, x, cfg: ArchConfig, compute_dtype):
    xc = x.astype(compute_dtype)
    q = xc @ params["wq"].astype(compute_dtype)
    k = xc @ params["wk"].astype(compute_dtype)
    v = xc @ params["wv"].astype(compute_dtype)
    if "bq" in params:
        q = q + params["bq"].astype(compute_dtype)
        k = k + params["bk"].astype(compute_dtype)
        v = v + params["bv"].astype(compute_dtype)
    B, S = x.shape[:2]
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    return q, k, v


def attn_forward(params, x, *, cfg: ArchConfig, spec: LayerSpec, positions,
                 window: int, runtime=None):
    """Full-sequence self-attention (train / prefill). Returns (out, kv)."""
    compute = jnp.dtype(cfg.compute_dtype)
    if cfg.mla is not None:
        return _mla_forward(params, x, cfg=cfg, positions=positions,
                            window=window, runtime=runtime)
    q, k, v = _project_qkv(params, x, cfg, compute)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    pos1d = jnp.arange(x.shape[1], dtype=jnp.int32)
    out = scaled_attention(q, k, v, pos1d, pos1d, causal=True, window=window,
                           cap=cfg.attn_softcap, runtime=runtime)
    out = out.reshape(x.shape[0], x.shape[1], cfg.q_dim)
    out = (out.astype(compute) @ params["wo"].astype(compute)).astype(x.dtype)
    cache_dt = jnp.dtype(cfg.cache_dtype)
    return out, {"k": k.astype(cache_dt), "v": v.astype(cache_dt)}


def attn_decode(params, x, cache, pos, *, cfg: ArchConfig, spec: LayerSpec,
                window: int, runtime=None):
    """One-token decode against a cache. x (B,1,d); pos scalar int32."""
    compute = jnp.dtype(cfg.compute_dtype)
    if cfg.mla is not None:
        return _mla_decode(params, x, cache, pos, cfg=cfg, window=window)
    B = x.shape[0]
    S = cache["k"].shape[1]
    q, k_new, v_new = _project_qkv(params, x, cfg, compute)
    positions = jnp.full((B, 1), pos, jnp.int32)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k_new = apply_rope(k_new, positions, cfg.rope_theta, cfg.mrope_sections)
    k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1)
    k_pos = jnp.arange(S, dtype=jnp.int32)
    # mask out not-yet-written slots and out-of-window slots
    valid = k_pos <= pos
    if window > 0:
        valid &= k_pos > pos - window
    bias = jnp.where(valid, 0.0, _NEG).astype(jnp.float32)
    out = _sdpa(q, k, v, bias[None], cfg.attn_softcap)
    out = out.reshape(B, 1, cfg.q_dim)
    out = (out.astype(compute) @ params["wo"].astype(compute)).astype(x.dtype)
    return out, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# cross-attention (whisper decoder)
# ---------------------------------------------------------------------------


def cross_attn_forward(params, x, enc_k, enc_v, *, cfg: ArchConfig):
    compute = jnp.dtype(cfg.compute_dtype)
    B, S = x.shape[:2]
    q = (x.astype(compute) @ params["xwq"].astype(compute)).reshape(
        B, S, cfg.n_heads, cfg.head_dim)
    bias = jnp.zeros((S, enc_k.shape[1]), jnp.float32)
    out = _sdpa(q, enc_k, enc_v, bias, 0.0)
    out = out.reshape(B, S, cfg.q_dim)
    return (out.astype(compute) @ params["xwo"].astype(compute)).astype(x.dtype)


def cross_kv(params, enc_out, *, cfg: ArchConfig):
    compute = jnp.dtype(cfg.compute_dtype)
    B, S = enc_out.shape[:2]
    k = (enc_out.astype(compute) @ params["xwk"].astype(compute)).reshape(
        B, S, cfg.n_kv_heads, cfg.head_dim)
    v = (enc_out.astype(compute) @ params["xwv"].astype(compute)).reshape(
        B, S, cfg.n_kv_heads, cfg.head_dim)
    cache_dt = jnp.dtype(cfg.cache_dtype)
    return k.astype(cache_dt), v.astype(cache_dt)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------


def _mla_q(params, x, cfg: ArchConfig, compute):
    m = cfg.mla
    B, S = x.shape[:2]
    xc = x.astype(compute)
    if "wq_a" in params:
        qa = xc @ params["wq_a"].astype(compute)
        qa = apply_norm(params["q_norm"], qa, cfg.norm, cfg.norm_eps)
        q = qa.astype(compute) @ params["wq_b"].astype(compute)
    else:
        q = xc @ params["wq"].astype(compute)
    q = q.reshape(B, S, cfg.n_heads, m.qk_nope_dim + m.qk_rope_dim)
    return q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]


def _mla_latents(params, x, cfg: ArchConfig, positions, compute):
    m = cfg.mla
    xc = x.astype(compute)
    kv_a = xc @ params["wkv_a"].astype(compute)
    ckv = apply_norm(params["kv_norm"], kv_a[..., :m.kv_lora_rank],
                     cfg.norm, cfg.norm_eps)
    krope = kv_a[..., m.kv_lora_rank:]                        # (B,S,rd)
    krope = apply_rope(krope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return ckv, krope


def _mla_wkvb_split(params, cfg: ArchConfig, compute):
    m = cfg.mla
    w = params["wkv_b"].astype(compute).reshape(
        m.kv_lora_rank, cfg.n_heads, m.qk_nope_dim + m.v_head_dim)
    return w[..., :m.qk_nope_dim], w[..., m.qk_nope_dim:]    # (r,H,nd),(r,H,vd)


def _mla_forward(params, x, *, cfg: ArchConfig, positions, window, runtime=None):
    m = cfg.mla
    compute = jnp.dtype(cfg.compute_dtype)
    B, S = x.shape[:2]
    q_nope, q_rope = _mla_q(params, x, cfg, compute)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv, krope = _mla_latents(params, x, cfg, positions, compute)
    wk, wv = _mla_wkvb_split(params, cfg, compute)
    # expand keys/values (chunk-recomputed inside scaled_attention paths by
    # concatenating rope and nope sections into a single head_dim)
    k_nope = jnp.einsum("bsr,rhd->bshd", ckv, wk)
    v = jnp.einsum("bsr,rhd->bshd", ckv, wv)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope[:, :, None, :],
                                  (B, S, cfg.n_heads, m.qk_rope_dim))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    pos1d = jnp.arange(S, dtype=jnp.int32)
    # pad v to q/k head_dim for the shared kernel, then strip
    vd = m.v_head_dim
    hd = q.shape[-1]
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, hd - vd))) if hd > vd else v
    out = scaled_attention(q, k, v_pad, pos1d, pos1d, causal=True,
                           window=window, cap=0.0, runtime=runtime)
    out = out[..., :vd].reshape(B, S, cfg.n_heads * vd)
    out = (out.astype(compute) @ params["wo"].astype(compute)).astype(x.dtype)
    cache_dt = jnp.dtype(cfg.cache_dtype)
    return out, {"ckv": ckv.astype(cache_dt), "krope": krope.astype(cache_dt)}


def _mla_decode(params, x, cache, pos, *, cfg: ArchConfig, window: int):
    """Absorbed MLA decode: scores and values stay in the latent space."""
    m = cfg.mla
    compute = jnp.dtype(cfg.compute_dtype)
    B = x.shape[0]
    S = cache["ckv"].shape[1]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(params, x, cfg, compute)          # (B,1,H,*)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv_new, krope_new = _mla_latents(params, x, cfg, positions, compute)
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], ckv_new.astype(cache["ckv"].dtype), pos, axis=1)
    krope = jax.lax.dynamic_update_slice_in_dim(
        cache["krope"], krope_new.astype(cache["krope"].dtype), pos, axis=1)
    wk, wv = _mla_wkvb_split(params, cfg, compute)
    # absorb: q_eff[h,r] = sum_d q_nope[h,d] * wk[r,h,d]
    q_eff = jnp.einsum("bqhd,rhd->bqhr", q_nope, wk,
                       preferred_element_type=jnp.float32)
    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    s_lat = jnp.einsum("bqhr,bsr->bhqs", q_eff.astype(ckv.dtype), ckv,
                       preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(krope.dtype), krope,
                        preferred_element_type=jnp.float32)
    scores = (s_lat + s_rope) * scale
    k_pos = jnp.arange(S, dtype=jnp.int32)
    valid = k_pos <= pos
    if window > 0:
        valid &= k_pos > pos - window
    scores = scores + jnp.where(valid, 0.0, _NEG)[None, None, None, :]
    w8 = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhqs,bsr->bqhr", w8.astype(ckv.dtype), ckv,
                         preferred_element_type=jnp.float32)
    out = jnp.einsum("bqhr,rhd->bqhd", out_lat.astype(wv.dtype), wv,
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, 1, cfg.n_heads * m.v_head_dim).astype(compute)
    out = (out @ params["wo"].astype(compute)).astype(x.dtype)
    return out, {"ckv": ckv, "krope": krope}
