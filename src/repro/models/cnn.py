"""VGG-family CNN for the paper-faithful reproduction (MNIST/CIFAR clients).

Keeps the paper's Eq. 3 signature exactly: post-ReLU conv feature maps have
true zeros, and ``signature_layer`` selects which conv output provides the
zero-fraction 'kernel signatures' (one per output channel).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.cnn import CNNConfig


def init_cnn(key, cfg: CNNConfig):
    params = {"convs": [], "fcs": []}
    in_ch = cfg.in_channels
    k = cfg.kernel_size
    size = cfg.image_size
    for stack in cfg.conv_stacks:
        stack_params = []
        for out_ch in stack:
            key, sub = jax.random.split(key)
            w = jax.random.normal(sub, (k, k, in_ch, out_ch), jnp.float32)
            w = w * math.sqrt(2.0 / (k * k * in_ch))
            stack_params.append({"w": w, "b": jnp.zeros((out_ch,), jnp.float32)})
            in_ch = out_ch
        params["convs"].append(stack_params)
        size //= 2
    d = in_ch * size * size
    for out_d in cfg.fc_dims + (cfg.n_classes,):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (d, out_d), jnp.float32) * math.sqrt(2.0 / d)
        params["fcs"].append({"w": w, "b": jnp.zeros((out_d,), jnp.float32)})
        d = out_d
    return params


def cnn_forward(params, images, cfg: CNNConfig, want_signature: bool = False,
                kernel_policy=None):
    """images (B, H, W, C) -> (logits (B, n_classes), signature | None).

    The signature is the paper's Eq. 3-4: per-channel zero fraction of the
    ``signature_layer``-th conv feature map, averaged over the batch —
    computed through the kernel dispatch layer (``kernel_policy=None`` ->
    ``"reference"``: the pure-jnp incumbent bits).
    """
    from repro.kernels import ops as kops
    x = images
    sig = None
    conv_idx = 0
    for stack_params in params["convs"]:
        for p in stack_params:
            x = jax.lax.conv_general_dilated(
                x, p["w"], window_strides=(1, 1), padding="SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = jax.nn.relu(x + p["b"])
            if want_signature and conv_idx == cfg.signature_layer:
                # zero(F_k(x)) / (H*W), averaged over samples (Eq. 3-4)
                zero_frac = kops.signature_per_channel(
                    x, tau=0.0, policy=kernel_policy or "reference")
                sig = jnp.mean(zero_frac, axis=0)            # (channels,)
            conv_idx += 1
        x = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    x = x.reshape(x.shape[0], -1)
    for p in params["fcs"][:-1]:
        x = jax.nn.relu(x @ p["w"] + p["b"])
    p = params["fcs"][-1]
    return x @ p["w"] + p["b"], sig


def cnn_loss(params, batch, cfg: CNNConfig, want_signature: bool = False):
    logits, sig = cnn_forward(params, batch["images"], cfg, want_signature)
    labels = batch["labels"]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(logz - ll)
    return loss, {"signature": sig, "logits": logits}


def cnn_accuracy(params, images, labels, cfg: CNNConfig):
    logits, _ = cnn_forward(params, images, cfg)
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
