"""Shared neural-net building blocks (pure functions over param pytrees).

Conventions
-----------
- ``init_*`` functions return nested dicts of jnp arrays; leaf *names* are the
  contract with ``repro.sharding.rules`` (path-based PartitionSpec mapping).
- ``apply`` functions take ``params`` first and are shape-polymorphic over a
  leading batch/seq prefix.
- Matmuls run in ``compute_dtype`` (bf16 on TPU); accumulations that need it
  (softmax, norms, losses) run in f32.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32)
            * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# normalisation
# ---------------------------------------------------------------------------


def init_norm(kind: str, d: int, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_norm(params, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


def softcap(x, cap: float):
    """Gemma-2 style logit soft-capping: cap * tanh(x / cap)."""
    if cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wg": dense_init(k1, d, d_ff, dtype),
        "wi": dense_init(k2, d, d_ff, dtype),
        "wdown": dense_init(k3, d_ff, d, dtype),
    }


def apply_mlp(params, x, act: str, compute_dtype, sc=None):
    xc = x.astype(compute_dtype)
    g = xc @ params["wg"].astype(compute_dtype)
    h = xc @ params["wi"].astype(compute_dtype)
    a = activation(act)(g) * h
    if sc is not None:
        a = sc.shard_act_ff(a)
    out = a @ params["wdown"].astype(compute_dtype)
    return out.astype(x.dtype), a


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def _rotate_half(x):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([-x2, x1], axis=-1)


def apply_rope(x, positions, theta: float,
               mrope_sections: Optional[Tuple[int, int, int]] = None):
    """Rotary embedding.

    x: (..., S, n_heads, head_dim); positions: (B, S) int32 or (3, B, S) for
    M-RoPE (temporal/height/width ids — equal for pure-text streams).
    """
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)                       # (half,)
    if mrope_sections is None:
        pos = positions if positions.ndim == 2 else positions[0]
        ang = pos[..., None].astype(jnp.float32) * inv      # (B, S, half)
    else:
        if positions.ndim == 2:                             # text-only stream
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        parts = []
        start = 0
        for sec, p in zip(mrope_sections, positions):
            parts.append(p[..., None].astype(jnp.float32) * inv[start:start + sec])
            start += sec
        ang = jnp.concatenate(parts, axis=-1)               # (B, S, half)
    ang = jnp.concatenate([ang, ang], axis=-1)              # (B, S, head_dim)
    cos = jnp.cos(ang)[..., None, :]                        # (B, S, 1, hd)
    sin = jnp.sin(ang)[..., None, :]
    xf = x.astype(jnp.float32)
    out = xf * cos + _rotate_half(xf) * sin
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d: int, dtype, tied: bool):
    k1, k2 = jax.random.split(key)
    p = {"embedding": embed_init(k1, vocab, d, dtype)}
    if not tied:
        p["unembed"] = dense_init(k2, d, vocab, dtype, scale=0.02)
    return p


def embed_tokens(params, tokens, compute_dtype):
    return params["embedding"].astype(compute_dtype)[tokens]


def unembed(params, x, compute_dtype, final_cap: float = 0.0):
    xc = x.astype(compute_dtype)
    if "unembed" in params:
        logits = xc @ params["unembed"].astype(compute_dtype)
    else:
        logits = xc @ params["embedding"].astype(compute_dtype).T
    logits = logits.astype(jnp.float32)
    return softcap(logits, final_cap)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def cross_entropy(logits, labels, mask=None):
    """Mean next-token CE in f32. logits (B,S,V) f32, labels (B,S) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# feature signatures (paper Eq. 3-4, transformer adaptation)
# ---------------------------------------------------------------------------


def activation_signature(h, n_sig: int = 64, tau: float = 0.05):
    """Threshold-zero fraction of hidden activations, bucketed to n_sig dims.

    The paper's Eq. 3 counts exact zeros of post-ReLU conv maps; GeLU/SiLU
    emit no exact zeros, so the transformer adaptation uses |a| < tau.
    h: (..., d) -> (n_sig,) f32, averaged over all leading axes.
    """
    d = h.shape[-1]
    pad = (-d) % n_sig
    flags = (jnp.abs(h.astype(jnp.float32)) < tau).astype(jnp.float32)
    flags = flags.reshape(-1, d)
    if pad:
        flags = jnp.pad(flags, ((0, 0), (0, pad)))
    flags = flags.reshape(flags.shape[0], n_sig, -1)
    return jnp.mean(flags, axis=(0, 2))
