"""Mamba-1 selective SSM block (used by jamba-v0.1).

Train/prefill: chunked sequential scan (outer ``lax.scan`` over chunks with
``jax.checkpoint`` on the chunk body, inner scan over time) — the remat
pattern mirrors the CUDA kernel's recompute-in-backward trick adapted to the
TPU memory hierarchy: only chunk-boundary states (B, d_in, N) are saved.
Decode: single recurrent step against carried {ssm state, conv tail}.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MambaConfig
from repro.models.layers import dense_init


def _dims(cfg: ArchConfig):
    mc = cfg.mamba
    d_in = mc.expand * cfg.d_model
    dt_rank = mc.dt_rank or -(-cfg.d_model // 16)
    return mc, d_in, dt_rank


def init_mamba(key, cfg: ArchConfig, dtype):
    mc, d_in, dt_rank = _dims(cfg)
    keys = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, mc.d_state + 1, dtype=jnp.float32)[None],
                 (d_in, 1))
    return {
        "in_proj": dense_init(keys[0], cfg.d_model, 2 * d_in, dtype),
        "conv_w": (jax.random.normal(keys[1], (mc.d_conv, d_in), jnp.float32)
                   / math.sqrt(mc.d_conv)).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": dense_init(keys[2], d_in, dt_rank + 2 * mc.d_state, dtype),
        "dt_proj": dense_init(keys[3], dt_rank, d_in, dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((d_in,), 0.01))).astype(jnp.float32),
        "A_log": jnp.log(a),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(keys[4], d_in, cfg.d_model, dtype),
    }


def init_mamba_state(cfg: ArchConfig, batch: int, leading: tuple = ()):
    mc, d_in, _ = _dims(cfg)
    return {
        "h": jnp.zeros(leading + (batch, d_in, mc.d_state), jnp.float32),
        "conv": jnp.zeros(leading + (batch, mc.d_conv - 1, d_in), jnp.float32),
    }


def _ssm_params(params, xb, cfg, compute):
    """xb (..., d_in) conv-activated input -> dt (softplus), B, C."""
    mc, d_in, dt_rank = _dims(cfg)
    proj = xb.astype(compute) @ params["x_proj"].astype(compute)
    dt, Bc, Cc = jnp.split(proj.astype(jnp.float32),
                           [dt_rank, dt_rank + mc.d_state], axis=-1)
    dt = dt @ params["dt_proj"].astype(jnp.float32) + params["dt_bias"]
    dt = jax.nn.softplus(dt)
    return dt, Bc, Cc


def mamba_forward(params, x, *, cfg: ArchConfig, state=None, runtime=None):
    """Full-sequence scan. x (B,S,d) -> (out (B,S,d), final state)."""
    mc, d_in, _ = _dims(cfg)
    compute = jnp.dtype(cfg.compute_dtype)
    B, S, d = x.shape
    xz = x.astype(compute) @ params["in_proj"].astype(compute)
    xs, z = jnp.split(xz, 2, axis=-1)                        # (B,S,d_in)

    if state is None:
        state = init_mamba_state(cfg, B)
    # causal depthwise conv over time (prepend carried tail)
    tail = state["conv"].astype(compute)
    xp = jnp.concatenate([tail, xs], axis=1)                 # (B, S+dc-1, d_in)
    conv_w = params["conv_w"].astype(compute)
    xconv = sum(xp[:, i:i + S] * conv_w[i] for i in range(mc.d_conv))
    xb = jax.nn.silu(xconv + params["conv_b"].astype(compute))

    dt, Bc, Cc = _ssm_params(params, xb, cfg, compute)       # (B,S,*)
    A = -jnp.exp(params["A_log"])                            # (d_in, N)
    xbf = xb.astype(jnp.float32)

    if runtime is not None and getattr(runtime, "use_pallas", False):
        from repro.kernels import ops as kops
        y, h_last = kops.selective_scan(
            xbf, dt, A, Bc, Cc, state["h"], chunk=mc.chunk,
            policy=kops.policy_from_runtime(runtime))
    else:
        y, h_last = selective_scan_ref(xbf, dt, A, Bc, Cc, state["h"],
                                       chunk=mc.chunk)
    y = y + xbf * params["D"]
    out = (y.astype(compute) * jax.nn.silu(z)) @ params["out_proj"].astype(compute)
    new_state = {"h": h_last,
                 "conv": xp[:, -(mc.d_conv - 1):].astype(jnp.float32)}
    return out.astype(x.dtype), new_state


def selective_scan_ref(x, dt, A, Bc, Cc, h0, chunk: int = 256):
    """Chunked sequential selective scan (pure jnp oracle).

    x,dt (B,S,d_in) f32; A (d_in,N); Bc,Cc (B,S,N); h0 (B,d_in,N).
    Returns (y (B,S,d_in), h_last).
    """
    B, S, d_in = x.shape
    N = A.shape[1]
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))

    def chunk_body(h, xs):
        xc, dtc, bc, cc = xs                                  # (C,B,...)

        def step(h, s):
            xt, dtt, bt, ct = s                               # (B,d_in),(B,d_in),(B,N),(B,N)
            da = jnp.exp(dtt[..., None] * A)                  # (B,d_in,N)
            h = da * h + (dtt * xt)[..., None] * bt[:, None, :]
            y = jnp.sum(h * ct[:, None, :], axis=-1)          # (B,d_in)
            return h, y

        h, ys = jax.lax.scan(step, h, (xc, dtc, bc, cc))
        return h, ys

    xs = tuple(a.reshape(B, n_chunks, chunk, -1).transpose(1, 2, 0, 3)
               for a in (x, dt, Bc, Cc))
    h, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0, xs)
    y = ys.reshape(n_chunks * chunk, B, d_in).transpose(1, 0, 2)
    return y[:, :S], h


def mamba_decode(params, x, state, *, cfg: ArchConfig):
    """Single-token recurrent step. x (B,1,d)."""
    mc, d_in, _ = _dims(cfg)
    compute = jnp.dtype(cfg.compute_dtype)
    B = x.shape[0]
    xz = x[:, 0].astype(compute) @ params["in_proj"].astype(compute)
    xs, z = jnp.split(xz, 2, axis=-1)                        # (B,d_in)
    conv_w = params["conv_w"].astype(compute)
    window = jnp.concatenate([state["conv"].astype(compute), xs[:, None]], axis=1)
    xconv = jnp.sum(window * conv_w[None], axis=1)
    xb = jax.nn.silu(xconv + params["conv_b"].astype(compute))
    dt, Bc, Cc = _ssm_params(params, xb, cfg, compute)
    A = -jnp.exp(params["A_log"])
    da = jnp.exp(dt[..., None] * A)
    h = da * state["h"] + (dt * xb.astype(jnp.float32))[..., None] * Bc[:, None, :]
    y = jnp.sum(h * Cc[:, None, :], axis=-1) + xb.astype(jnp.float32) * params["D"]
    out = (y.astype(compute) * jax.nn.silu(z)) @ params["out_proj"].astype(compute)
    return out[:, None].astype(x.dtype), {"h": h,
                                          "conv": window[:, 1:].astype(jnp.float32)}
