"""Mixture-of-Experts FFN with capacity-bounded one-hot dispatch (GSPMD style).

Tokens are grouped (``group_size`` tokens per dispatch group) so the dispatch
tensor is (G, S_g, E, C) with per-group capacity C = ceil(S_g * top_k / E *
capacity_factor); experts shard over the ``model`` mesh axis (expert
parallelism) and groups over ``data``, so XLA materialises the all-to-all in
the lowered HLO — which is exactly what the roofline's collective term wants
to see.  Overflow tokens are dropped (standard Switch behaviour); the router
carries a load-balance aux loss and a z-loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.models.layers import activation, dense_init, init_mlp, apply_mlp

_GROUP = 512


def init_moe(key, cfg: ArchConfig, dtype):
    mo = cfg.moe
    d = cfg.d_model
    keys = jax.random.split(key, 5)
    p = {
        "router": dense_init(keys[0], d, mo.n_experts, dtype, scale=0.02),
        "we_gate": _expert_init(keys[1], mo.n_experts, d, mo.d_expert, dtype),
        "we_up": _expert_init(keys[2], mo.n_experts, d, mo.d_expert, dtype),
        "we_down": _expert_init(keys[3], mo.n_experts, mo.d_expert, d, dtype),
    }
    if mo.n_shared:
        p["shared"] = init_mlp(keys[4], d, mo.n_shared * mo.d_expert, dtype)
    return p


def _expert_init(key, e, din, dout, dtype):
    import math
    return (jax.random.normal(key, (e, din, dout), jnp.float32)
            / math.sqrt(din)).astype(dtype)


def moe_forward(params, x, *, cfg: ArchConfig, sc=None,
                generous_capacity: bool = False):
    """x (B, S, d) -> (out, aux) where aux has load-balance and z losses.

    ``generous_capacity`` (serving: prefill/decode) widens expert capacity to
    4x the balanced load (floor 8) so tokens are effectively never dropped;
    training keeps Switch-style ``capacity_factor`` dropping.
    """
    mo = cfg.moe
    compute = jnp.dtype(cfg.compute_dtype)
    B, S, d = x.shape
    E, k = mo.n_experts, mo.top_k

    tokens = x.reshape(B * S, d)
    g_size = min(_GROUP, B * S)
    n_groups = (B * S) // g_size
    rem = B * S - n_groups * g_size
    if rem:                                   # pad to whole groups
        tokens = jnp.pad(tokens, ((0, g_size - rem), (0, 0)))
        n_groups += 1
    xg = tokens.reshape(n_groups, g_size, d).astype(compute)

    logits = (xg @ params["router"].astype(compute)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (G,Sg,E)

    if S == 1 or generous_capacity:
        cap = min(g_size, max(8, -(-g_size * k * 4 // E)))
    else:
        cap = max(int(g_size * k / E * mo.capacity_factor), 1)

    # top-k routing with per-slot cumulative capacity positions
    gates, dispatch = _topk_dispatch(probs, k, cap)            # (G,Sg,E,C)

    # dispatch tokens to expert slots
    xe = jnp.einsum("gsec,gsd->gecd", dispatch.astype(compute), xg)
    # expert FFN (E sharded over "model")
    act = activation(cfg.act)
    h = act(jnp.einsum("gecd,edf->gecf", xe, params["we_gate"].astype(compute)))
    h = h * jnp.einsum("gecd,edf->gecf", xe, params["we_up"].astype(compute))
    ye = jnp.einsum("gecf,efd->gecd", h, params["we_down"].astype(compute))
    # combine
    combine = (dispatch.astype(jnp.float32) * gates[..., None]).astype(compute)
    out = jnp.einsum("gsec,gecd->gsd", combine, ye)

    out = out.reshape(-1, d)[: B * S].reshape(B, S, d)

    if mo.n_shared:
        shared, _ = apply_mlp(params["shared"], x, cfg.act, compute, sc=sc)
        out = out + shared.reshape(B, S, d)

    # aux losses (Switch-style load balance + router z-loss)
    me = jnp.mean(probs.reshape(-1, E), axis=0)
    ce = jnp.mean(jnp.max(dispatch, axis=-1).reshape(-1, E).astype(jnp.float32),
                  axis=0)
    aux_lb = E * jnp.sum(me * ce) * mo.router_aux_weight
    z = jax.scipy.special.logsumexp(logits, axis=-1)
    aux_z = jnp.mean(jnp.square(z)) * mo.router_z_weight
    return out.astype(x.dtype), {"moe_aux": aux_lb + aux_z,
                                 "expert_load": ce}


def _topk_dispatch(probs, k: int, cap: int):
    """Greedy top-k dispatch with capacity. Returns (gates (G,Sg,E),
    dispatch one-hot (G,Sg,E,C))."""
    G, Sg, E = probs.shape
    remaining = probs
    fill = jnp.zeros((G, E), jnp.int32)                 # slots used per expert
    gates = jnp.zeros((G, Sg, E), jnp.float32)
    dispatch = jnp.zeros((G, Sg, E, cap), bool)
    for _ in range(k):
        idx = jnp.argmax(remaining, axis=-1)            # (G,Sg)
        onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)
        # position of each token within its expert queue (priority = seq order)
        pos = jnp.cumsum(onehot, axis=1) - 1.0 + fill[:, None, :].astype(jnp.float32)
        pos_tok = jnp.sum(pos * onehot, axis=-1)        # (G,Sg)
        keep = pos_tok < cap
        slot = jax.nn.one_hot(pos_tok.astype(jnp.int32), cap, dtype=bool)
        dispatch = dispatch | (
            (onehot[..., None] > 0) & slot[:, :, None, :] & keep[:, :, None, None])
        gates = gates + onehot * probs * keep[..., None].astype(jnp.float32)
        fill = fill + jnp.sum(onehot * keep[..., None], axis=1).astype(jnp.int32)
        remaining = remaining * (1.0 - onehot)
    denom = jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    gates = gates / denom
    return gates, dispatch
