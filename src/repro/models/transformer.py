"""Staged decoder (and optional encoder) assembled from ArchConfig.

The layer stack is organised as *stages*: each stage is a repeating pattern of
heterogeneous blocks scanned with ``lax.scan`` over parameters stacked along a
leading ``repeats`` axis.  One traced period covers every distinct block in
the architecture, so the HLO stays small for 62-80-layer models.

Public API
----------
init_params / init_cache / forward / loss_fn / prefill / decode_step
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec
from repro.models import attention as attn
from repro.models import mamba as mam
from repro.models import moe as moe_mod
from repro.models import xlstm as xl
from repro.models.layers import (apply_mlp, apply_norm, cross_entropy,
                                 embed_tokens, init_embedding, init_mlp,
                                 init_norm, unembed)
from repro.runtime import DEFAULT, Runtime


def _shard_batch(x, runtime: Runtime):
    """Constrain dim 0 (batch) of an activation to the launcher's batch axes.

    Without this, XLA's sharding propagation is free to replicate the batch
    and shard d_model off the embedding table's layout instead — which
    explodes per-device activation memory (observed: 70 GiB/chip on
    internlm2 train_4k before this constraint)."""
    if runtime.batch_axes is None or x.ndim < 2:
        return x
    if x.shape[0] % max(runtime.batch_axis_size, 1):
        return x
    from jax.sharding import PartitionSpec as P
    axes = (runtime.batch_axes if len(runtime.batch_axes) > 1
            else runtime.batch_axes[0])
    try:
        return jax.lax.with_sharding_constraint(
            x, P(axes, *([None] * (x.ndim - 1))))
    except Exception:          # no mesh context (plain CPU tests)
        return x


# ---------------------------------------------------------------------------
# window resolution (long-context adaptation, see DESIGN.md)
# ---------------------------------------------------------------------------


def _arch_is_subquadratic(cfg: ArchConfig) -> bool:
    return any(s.window > 0 or s.kind in ("mamba", "mlstm", "slstm")
               for s in cfg.layer_specs())


def resolve_window(cfg: ArchConfig, spec: LayerSpec, seq_len: int) -> int:
    if spec.kind != "attn":
        return -1
    w = spec.window
    if (w <= 0 and seq_len >= cfg.long_context_threshold
            and not _arch_is_subquadratic(cfg)):
        w = cfg.long_context_window
    return w


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ArchConfig, spec: LayerSpec, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"norm1": init_norm(cfg.norm, cfg.d_model, dtype)}
    if spec.kind == "attn":
        p["core"] = attn.init_attn(k1, cfg, spec, dtype)
    elif spec.kind == "mamba":
        p["core"] = mam.init_mamba(k1, cfg, dtype)
    elif spec.kind == "mlstm":
        p["core"] = xl.init_mlstm(k1, cfg, dtype)
    elif spec.kind == "slstm":
        p["core"] = xl.init_slstm(k1, cfg, dtype)
    else:
        raise ValueError(spec.kind)
    if spec.cross_attn:
        p["xnorm"] = init_norm(cfg.norm, cfg.d_model, dtype)
    if spec.ffn == "dense" and cfg.d_ff > 0:
        p["norm2"] = init_norm(cfg.norm, cfg.d_model, dtype)
        p["ffn"] = init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
    elif spec.ffn == "moe":
        p["norm2"] = init_norm(cfg.norm, cfg.d_model, dtype)
        p["ffn"] = moe_mod.init_moe(k3, cfg, dtype)
    return p


def init_params(key, cfg: ArchConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    n_stages = len(cfg.stages)
    keys = jax.random.split(key, n_stages + 3)
    params = {"embed": init_embedding(keys[0], cfg.vocab_size, cfg.d_model,
                                      dtype, cfg.tie_embeddings),
              "final_norm": init_norm(cfg.norm, cfg.d_model, dtype),
              "stages": []}
    for si, stage in enumerate(cfg.stages):
        skeys = jax.random.split(keys[si + 1], stage.repeats)

        def one_period(k):
            pk = jax.random.split(k, len(stage.pattern))
            return {f"l{j}": _init_layer(pk[j], cfg, spec, dtype)
                    for j, spec in enumerate(stage.pattern)}

        params["stages"].append(jax.vmap(one_period)(skeys))
    if cfg.encoder is not None:
        params["encoder"] = _init_encoder(keys[-1], cfg, dtype)
    return params


def _init_encoder(key, cfg: ArchConfig, dtype):
    e = cfg.encoder
    keys = jax.random.split(key, e.n_layers + 1)
    spec = LayerSpec(kind="attn", ffn="dense")

    def one(k):
        return _init_layer(k, cfg, spec, dtype)

    return {"layers": jax.vmap(one)(keys[:e.n_layers]),
            "final_norm": init_norm(cfg.norm, cfg.d_model, dtype)}


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_seq: int):
    """Zero decode cache mirroring the stage structure."""
    caches = []
    for stage in cfg.stages:
        sc = {}
        for j, spec in enumerate(stage.pattern):
            lead = (stage.repeats,)
            if spec.kind == "attn":
                c = attn.init_kv_cache(cfg, spec, batch, max_seq, leading=lead)
                if spec.cross_attn:
                    e = cfg.encoder
                    c["xk"] = jnp.zeros(lead + (batch, e.n_ctx, cfg.n_kv_heads,
                                                cfg.head_dim),
                                        jnp.dtype(cfg.cache_dtype))
                    c["xv"] = jnp.zeros_like(c["xk"])
            elif spec.kind == "mamba":
                c = mam.init_mamba_state(cfg, batch, leading=lead)
            elif spec.kind == "mlstm":
                c = xl.init_mlstm_state(cfg, batch, leading=lead)
            elif spec.kind == "slstm":
                c = xl.init_slstm_state(cfg, batch, leading=lead)
            sc[f"l{j}"] = c
        caches.append(sc)
    return caches


# ---------------------------------------------------------------------------
# layer / stage forward
# ---------------------------------------------------------------------------


def _layer_forward(lp, x, *, cfg, spec, positions, window, runtime,
                   enc_out=None, causal=True, mode="train"):
    """Full-sequence block. Returns (x, cache_out, aux_scalar)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(lp["norm1"], x, cfg.norm, cfg.norm_eps)
    if spec.kind == "attn":
        if causal:
            core, cache = attn.attn_forward(lp["core"], h, cfg=cfg, spec=spec,
                                            positions=positions, window=window,
                                            runtime=runtime)
        else:
            core, cache = _encoder_attn(lp["core"], h, cfg, positions, runtime)
    elif spec.kind == "mamba":
        core, cache = mam.mamba_forward(lp["core"], h, cfg=cfg, runtime=runtime)
    elif spec.kind == "mlstm":
        core, cache = xl.mlstm_forward(lp["core"], h, cfg=cfg, runtime=runtime)
    elif spec.kind == "slstm":
        core, cache = xl.slstm_forward(lp["core"], h, cfg=cfg, runtime=runtime)
    x = x + core
    if spec.cross_attn and enc_out is not None:
        h2 = apply_norm(lp["xnorm"], x, cfg.norm, cfg.norm_eps)
        xk, xv = attn.cross_kv(lp["core"], enc_out, cfg=cfg)
        x = x + attn.cross_attn_forward(lp["core"], h2, xk, xv, cfg=cfg)
        cache = dict(cache)
        cache["xk"], cache["xv"] = xk, xv
    if spec.ffn == "dense" and cfg.d_ff > 0:
        h3 = apply_norm(lp["norm2"], x, cfg.norm, cfg.norm_eps)
        y, _ = apply_mlp(lp["ffn"], h3, cfg.act, jnp.dtype(cfg.compute_dtype))
        x = x + y
    elif spec.ffn == "moe":
        h3 = apply_norm(lp["norm2"], x, cfg.norm, cfg.norm_eps)
        y, maux = moe_mod.moe_forward(lp["ffn"], h3, cfg=cfg,
                                      generous_capacity=(mode != "train"))
        aux = aux + maux["moe_aux"]
        x = x + y
    return x, cache, aux


def _encoder_attn(params, h, cfg, positions, runtime):
    from repro.models.attention import _project_qkv, scaled_attention
    compute = jnp.dtype(cfg.compute_dtype)
    q, k, v = _project_qkv(params, h, cfg, compute)
    from repro.models.layers import apply_rope
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    pos1d = jnp.arange(h.shape[1], dtype=jnp.int32)
    out = scaled_attention(q, k, v, pos1d, pos1d, causal=False,
                           window=-1, cap=cfg.attn_softcap, runtime=runtime)
    out = out.reshape(h.shape[0], h.shape[1], cfg.q_dim)
    out = (out.astype(compute) @ params["wo"].astype(compute)).astype(h.dtype)
    return out, {}


def _layer_decode(lp, x, cache, pos, *, cfg, spec, window, runtime):
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(lp["norm1"], x, cfg.norm, cfg.norm_eps)
    if spec.kind == "attn":
        core, new_cache = attn.attn_decode(lp["core"], h, cache, pos, cfg=cfg,
                                           spec=spec, window=window,
                                           runtime=runtime)
        if spec.cross_attn:
            new_cache = dict(new_cache)
            new_cache["xk"], new_cache["xv"] = cache["xk"], cache["xv"]
    elif spec.kind == "mamba":
        core, new_cache = mam.mamba_decode(lp["core"], h, cache, cfg=cfg)
    elif spec.kind == "mlstm":
        core, new_cache = xl.mlstm_decode(lp["core"], h, cache, cfg=cfg)
    elif spec.kind == "slstm":
        core, new_cache = xl.slstm_decode(lp["core"], h, cache, cfg=cfg)
    x = x + core
    if spec.cross_attn:
        h2 = apply_norm(lp["xnorm"], x, cfg.norm, cfg.norm_eps)
        x = x + attn.cross_attn_forward(lp["core"], h2, cache["xk"], cache["xv"],
                                        cfg=cfg)
    if spec.ffn == "dense" and cfg.d_ff > 0:
        h3 = apply_norm(lp["norm2"], x, cfg.norm, cfg.norm_eps)
        y, _ = apply_mlp(lp["ffn"], h3, cfg.act, jnp.dtype(cfg.compute_dtype))
        x = x + y
    elif spec.ffn == "moe":
        h3 = apply_norm(lp["norm2"], x, cfg.norm, cfg.norm_eps)
        y, maux = moe_mod.moe_forward(lp["ffn"], h3, cfg=cfg)
        aux = aux + maux["moe_aux"]
        x = x + y
    return x, new_cache, aux


def _stage_forward(stage_params, x, *, cfg, pattern, positions, seq_len,
                   runtime, enc_out, collect_cache, mode):
    windows = [resolve_window(cfg, spec, seq_len) for spec in pattern]

    def body(carry, pp):
        x, aux = carry
        caches = {}
        for j, spec in enumerate(pattern):
            x, c, a = _layer_forward(pp[f"l{j}"], x, cfg=cfg, spec=spec,
                                     positions=positions, window=windows[j],
                                     runtime=runtime, enc_out=enc_out,
                                     mode=mode)
            x = _shard_batch(x, runtime)
            caches[f"l{j}"] = c if collect_cache else {}
            aux = aux + a
        return (x, aux), caches

    if runtime.remat and mode == "train":
        body = jax.checkpoint(body)
    (x, aux), caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), stage_params)
    return x, aux, caches


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def _positions_for(cfg: ArchConfig, batch_dict, B, S):
    if "positions" in batch_dict and batch_dict["positions"] is not None:
        return batch_dict["positions"]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(pos[None], (3, B, S))
    return pos


def _encoder_forward(params, enc_embed, cfg: ArchConfig, runtime):
    e = cfg.encoder
    B, S = enc_embed.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    spec = LayerSpec(kind="attn", ffn="dense")
    x = enc_embed.astype(jnp.dtype(cfg.compute_dtype))

    def body(x, lp):
        x, _, _ = _layer_forward(lp, x, cfg=cfg, spec=spec, positions=pos,
                                 window=-1, runtime=runtime, causal=False)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"]["layers"])
    return apply_norm(params["encoder"]["final_norm"], x, cfg.norm, cfg.norm_eps)


def forward_hidden(params, batch, cfg: ArchConfig, runtime: Runtime = DEFAULT,
                   collect_cache: bool = False, mode: str = "train"):
    """Full-sequence forward up to the final norm (no unembedding).

    Returns (h (B,S,d), aux dict, caches).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    compute = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(params["embed"], tokens, compute) * (cfg.d_model ** 0.5
        if cfg.norm == "rmsnorm" and cfg.tie_embeddings else 1.0)
    x = _shard_batch(x, runtime)
    positions = _positions_for(cfg, batch, B, S)
    enc_out = None
    if cfg.encoder is not None:
        enc_out = _encoder_forward(params, batch["enc_embed"], cfg, runtime)
        enc_out = _shard_batch(enc_out, runtime)

    aux_total = jnp.zeros((), jnp.float32)
    caches = []
    for si, stage in enumerate(cfg.stages):
        x, aux, cache = _stage_forward(
            params["stages"][si], x, cfg=cfg, pattern=stage.pattern,
            positions=positions, seq_len=S, runtime=runtime, enc_out=enc_out,
            collect_cache=collect_cache, mode=mode)
        x = _shard_batch(x, runtime)
        aux_total = aux_total + aux
        caches.append(cache)

    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    aux = {"moe_aux": aux_total}
    if runtime.want_signature:
        from repro.kernels import ops as kops
        aux["signature"] = kops.signature(
            x, tau=runtime.signature_tau, n_sig=runtime.signature_dims,
            policy=kops.policy_from_runtime(runtime))
    return x, aux, caches


def per_sample_signature(h, runtime: Runtime = DEFAULT):
    """Per-sample Eq. 3 signature rows from the designated signature layer.

    ``forward_hidden`` emits ONE signature averaged over the whole batch
    (``aux["signature"]``); the cohort engine needs a (B, n_sig) row per
    sample so padded rows can be masked out of the mean.  Rows of equal
    length average back to the fused signature exactly, so the two paths
    agree whenever no padding is present.
    h: (B, S, d) activations of the designated layer (the final-norm
    output, matching ``Runtime.want_signature``).  Routed through the
    kernel dispatch layer; the policy (hence the compiled branch) is
    resolved once, outside the vmap.
    """
    from repro.kernels import ops as kops
    policy = kops.policy_from_runtime(runtime)
    return jax.vmap(lambda row: kops.signature(
        row, tau=runtime.signature_tau, n_sig=runtime.signature_dims,
        policy=policy))(h)


def forward(params, batch, cfg: ArchConfig, runtime: Runtime = DEFAULT,
            collect_cache: bool = False, mode: str = "train"):
    """Full logits (B,S,V) f32 — eval/tests; serving and training use the
    memory-sane paths (``prefill`` / ``loss_fn``)."""
    h, aux, caches = forward_hidden(params, batch, cfg, runtime,
                                    collect_cache, mode)
    logits = unembed(params["embed"], h,
                     jnp.dtype(cfg.compute_dtype), cfg.final_softcap)
    return logits, aux, caches


def _ce_chunk(cfg: ArchConfig, B: int, S: int) -> int:
    """Sequence-chunk size keeping per-chunk f32 logits ~<= 32 GB global
    (~2 GB per device on the 16-way data axis)."""
    budget = 32e9
    c = int(budget / (4.0 * B * cfg.vocab_size))
    c = max(64, min(1024, 1 << (c.bit_length() - 1) if c > 0 else 64))
    while S % c:
        c //= 2
        if c < 1:
            return S
    return c


def loss_fn(params, batch, cfg: ArchConfig, runtime: Runtime = DEFAULT):
    """Chunked-CE training loss: unembedding + softmax-CE run per sequence
    chunk under remat, so the full (B,S,V) f32 logits never materialise."""
    h, aux, _ = forward_hidden(params, batch, cfg, runtime, mode="train")
    labels = batch["labels"]
    mask = batch.get("mask")
    B, S, d = h.shape
    compute = jnp.dtype(cfg.compute_dtype)
    C = _ce_chunk(cfg, B, S)
    n_chunks = S // C

    hc = h.reshape(B, n_chunks, C, d).transpose(1, 0, 2, 3)
    yc = labels.reshape(B, n_chunks, C).transpose(1, 0, 2)
    mc = (mask.reshape(B, n_chunks, C).transpose(1, 0, 2)
          if mask is not None else jnp.ones_like(yc, jnp.float32))

    def chunk_body(carry, xs):
        tot, cnt = carry
        h_c, y_c, m_c = xs
        logits = unembed(params["embed"], h_c, compute, cfg.final_softcap)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, y_c[..., None], axis=-1)[..., 0]
        m = m_c.astype(jnp.float32)
        return (tot + jnp.sum((logz - ll) * m), cnt + jnp.sum(m)), None

    (tot, cnt), _ = jax.lax.scan(
        jax.checkpoint(chunk_body),
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, yc, mc))
    loss = tot / jnp.maximum(cnt, 1.0)
    total = loss + aux["moe_aux"]
    aux = dict(aux)
    aux["ce_loss"] = loss
    return total, aux


def prefill(params, batch, cfg: ArchConfig, runtime: Runtime = DEFAULT):
    """Serve-prefill: last-position logits + full KV cache (the full
    (B,S,V) logits are never formed)."""
    h, aux, caches = forward_hidden(params, batch, cfg, runtime,
                                    collect_cache=True, mode="prefill")
    logits = unembed(params["embed"], h[:, -1:],
                     jnp.dtype(cfg.compute_dtype), cfg.final_softcap)
    return logits[:, 0], caches, aux


def decode_step(params, token, caches, pos, cfg: ArchConfig,
                runtime: Runtime = DEFAULT):
    """One decode step. token (B,1) int32, pos scalar int32.

    Returns (logits (B,V) f32, new caches).
    """
    B = token.shape[0]
    compute = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(params["embed"], token, compute) * (cfg.d_model ** 0.5
        if cfg.norm == "rmsnorm" and cfg.tie_embeddings else 1.0)
    x = _shard_batch(x, runtime)
    # decode window must match the shape the cache was built for
    new_caches = []
    for si, stage in enumerate(cfg.stages):
        pattern = stage.pattern
        cache_seq = _cache_seq_len(caches[si], pattern, cfg)
        windows = [resolve_window(cfg, spec, cache_seq) for spec in pattern]

        def body(x, xs):
            pp, cache = xs
            new_cache = {}
            for j, spec in enumerate(pattern):
                xx, c, _ = _layer_decode(pp[f"l{j}"], x, cache[f"l{j}"], pos,
                                         cfg=cfg, spec=spec, window=windows[j],
                                         runtime=runtime)
                new_cache[f"l{j}"] = c
                x = xx
            return x, new_cache

        x, nc = jax.lax.scan(body, x, (params["stages"][si], caches[si]))
        new_caches.append(nc)
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    logits = unembed(params["embed"], x, compute, cfg.final_softcap)
    return logits[:, 0], new_caches


def _cache_seq_len(stage_cache, pattern, cfg) -> int:
    for j, spec in enumerate(pattern):
        if spec.kind == "attn":
            c = stage_cache[f"l{j}"]
            key = "ckv" if cfg.mla is not None else "k"
            return c[key].shape[2]
    return 0
