"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, strictly recurrent) — arXiv:2405.04517.

mLSTM uses the stabilized chunkwise formulation (intra-chunk quadratic D
matrix over ``chunk`` steps + carried inter-chunk state (C, n, m)), which is
the TPU-friendly adaptation of the paper's recurrence: within-chunk work maps
onto the MXU as (L x L) matmuls, across chunks a short ``lax.scan``.
``mlstm_recurrent_ref`` is the step-by-step oracle used by tests.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import apply_norm, dense_init, init_norm


def _mdims(cfg: ArchConfig):
    xc = cfg.xlstm
    d_in = xc.m_expand * cfg.d_model
    d_qk = int(xc.m_qk_dim_factor * d_in)
    H = cfg.n_heads
    return xc, d_in, d_qk, H


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ArchConfig, dtype):
    xc, d_in, d_qk, H = _mdims(cfg)
    keys = jax.random.split(key, 8)
    return {
        "up_proj": dense_init(keys[0], cfg.d_model, 2 * d_in, dtype),
        "conv_w": (jax.random.normal(keys[1], (xc.s_conv, d_in), jnp.float32)
                   / math.sqrt(xc.s_conv)).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "wq": dense_init(keys[2], d_in, d_qk, dtype),
        "wk": dense_init(keys[3], d_in, d_qk, dtype),
        "wv": dense_init(keys[4], d_in, d_in, dtype),
        "w_if": dense_init(keys[5], d_in, 2 * H, dtype, scale=0.01),
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]
                                ).astype(jnp.float32),
        "head_norm": init_norm("rmsnorm", d_in, dtype),
        "down_proj": dense_init(keys[6], d_in, cfg.d_model, dtype),
    }


def init_mlstm_state(cfg: ArchConfig, batch: int, leading: tuple = ()):
    xc, d_in, d_qk, H = _mdims(cfg)
    return {
        "C": jnp.zeros(leading + (batch, H, d_qk // H, d_in // H), jnp.float32),
        "n": jnp.zeros(leading + (batch, H, d_qk // H), jnp.float32),
        "m": jnp.full(leading + (batch, H), -1e30, jnp.float32),
        "conv": jnp.zeros(leading + (batch, xc.s_conv - 1, d_in), jnp.float32),
    }


def _mlstm_qkvif(params, x, cfg, compute):
    """x (B,S,d) -> q,k (B,S,H,dqk/H), v (B,S,H,dv/H), i,f (B,S,H), z (B,S,d_in)."""
    xc, d_in, d_qk, H = _mdims(cfg)
    B, S, _ = x.shape
    up = x.astype(compute) @ params["up_proj"].astype(compute)
    xm, z = jnp.split(up, 2, axis=-1)
    # causal conv + silu feeds q/k (paper's block layout)
    conv_w = params["conv_w"].astype(compute)
    xp = jnp.pad(xm, ((0, 0), (xc.s_conv - 1, 0), (0, 0)))
    xconv = sum(xp[:, i:i + S] * conv_w[i] for i in range(xc.s_conv))
    xcn = jax.nn.silu(xconv + params["conv_b"].astype(compute))
    q = (xcn @ params["wq"].astype(compute)).reshape(B, S, H, d_qk // H)
    k = (xcn @ params["wk"].astype(compute)).reshape(B, S, H, d_qk // H)
    v = (xm @ params["wv"].astype(compute)).reshape(B, S, H, d_in // H)
    gif = (xm @ params["w_if"].astype(compute)).astype(jnp.float32) + params["b_if"]
    i_gate, f_gate = jnp.split(gif, 2, axis=-1)              # (B,S,H)
    return q, k, v, i_gate, f_gate, z


def mlstm_chunkwise(q, k, v, i_gate, f_gate, state, chunk: int = 256):
    """Stabilized chunkwise mLSTM.

    q,k (B,S,H,dk) v (B,S,H,dv); gates (B,S,H) raw (i pre-exp, f pre-logsig).
    state: {C (B,H,dk,dv), n (B,H,dk), m (B,H)}.  Returns (h (B,S,H,dv), state).
    """
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    scale = 1.0 / math.sqrt(dk)
    L = min(chunk, S)
    n_chunks = -(-S // L)
    pad = n_chunks * L - S
    if pad:
        q, k, v = (jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for a in (q, k, v))
        i_gate = jnp.pad(i_gate, ((0, 0), (0, pad), (0, 0)))
        f_gate = jnp.pad(f_gate, ((0, 0), (0, pad), (0, 0)),
                         constant_values=30.0)  # ~sigmoid->1, keeps state

    def chunk_body(carry, xs):
        C, n, m = carry
        qc, kc, vc, ic, fc = xs                  # (L,B,H,*) time-major
        qc = qc.transpose(1, 2, 0, 3).astype(jnp.float32) * scale   # (B,H,L,dk)
        kc = kc.transpose(1, 2, 0, 3).astype(jnp.float32)
        vc = vc.transpose(1, 2, 0, 3).astype(jnp.float32)
        ic = ic.transpose(1, 2, 0)                                   # (B,H,L)
        fc = fc.transpose(1, 2, 0)
        logf = jax.nn.log_sigmoid(fc)
        b = jnp.cumsum(logf, axis=-1)                                # (B,H,L)
        g = b[..., -1]
        # intra-chunk decay matrix D[t,s] = b_t - b_s + i_s  (s <= t)
        D = b[..., :, None] - b[..., None, :] + ic[..., None, :]
        tri = jnp.tril(jnp.ones((L, L), bool))
        D = jnp.where(tri, D, -jnp.inf)
        m_intra = jnp.max(D, axis=-1)                                # (B,H,L)
        m_t = jnp.maximum(b + m[..., None], m_intra)
        # inter contribution
        w_inter = jnp.exp(b + m[..., None] - m_t)                    # (B,H,L)
        num_inter = jnp.einsum("bhld,bhdv->bhlv", qc, C) * w_inter[..., None]
        den_inter = jnp.einsum("bhld,bhd->bhl", qc, n) * w_inter
        # intra contribution
        logits = jnp.einsum("bhld,bhsd->bhls", qc, kc)
        decay = jnp.where(tri, jnp.exp(D - m_t[..., None]), 0.0)
        Wn = decay * logits
        num_intra = jnp.einsum("bhls,bhsv->bhlv", Wn, vc)
        den_intra = jnp.sum(Wn, axis=-1)
        num = num_inter + num_intra
        den = den_inter + den_intra
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # state update
        m_next = jnp.maximum(g + m, jnp.max(g[..., None] - b + ic, axis=-1))
        w_c = jnp.exp(g + m - m_next)
        w_s = jnp.exp(g[..., None] - b + ic - m_next[..., None])     # (B,H,L)
        C_next = C * w_c[..., None, None] + jnp.einsum(
            "bhs,bhsd,bhsv->bhdv", w_s, kc, vc)
        n_next = n * w_c[..., None] + jnp.einsum("bhs,bhsd->bhd", w_s, kc)
        h_out = h.transpose(2, 0, 1, 3)                              # (L,B,H,dv)
        return (C_next, n_next, m_next), h_out

    xs = tuple(a.reshape(B, n_chunks, L, H, -1).transpose(1, 2, 0, 3, 4)
               if a.ndim == 4 else
               a.reshape(B, n_chunks, L, H).transpose(1, 2, 0, 3)
               for a in (q, k, v, i_gate, f_gate))
    (C, n, m), hs = jax.lax.scan(jax.checkpoint(chunk_body),
                                 (state["C"], state["n"], state["m"]), xs)
    h = hs.transpose(2, 0, 1, 3, 4).reshape(B, n_chunks * L, H, dv)
    if pad:
        h = h[:, :S]
    return h, {"C": C, "n": n, "m": m}


def mlstm_recurrent_ref(q, k, v, i_gate, f_gate, state):
    """Step-by-step oracle (same signature, scan over every timestep)."""
    B, S, H, dk = q.shape
    scale = 1.0 / math.sqrt(dk)

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, it, ft = xs                  # (B,H,dk),(B,H,dk),(B,H,dv),(B,H)
        qt = qt.astype(jnp.float32) * scale
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + m, it)
        fprime = jnp.exp(logf + m - m_new)
        iprime = jnp.exp(it - m_new)
        C = C * fprime[..., None, None] + iprime[..., None, None] * (
            kt.astype(jnp.float32)[..., :, None] * vt.astype(jnp.float32)[..., None, :])
        n = n * fprime[..., None] + iprime[..., None] * kt.astype(jnp.float32)
        num = jnp.einsum("bhd,bhdv->bhv", qt, C)
        den = jnp.einsum("bhd,bhd->bh", qt, n)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
        return (C, n, m_new), h

    xs = tuple(a.transpose(1, 0, 2, 3) if a.ndim == 4 else a.transpose(1, 0, 2)
               for a in (q, k, v, i_gate, f_gate))
    (C, n, m), hs = jax.lax.scan(step, (state["C"], state["n"], state["m"]), xs)
    return hs.transpose(1, 0, 2, 3), {"C": C, "n": n, "m": m}


def mlstm_forward(params, x, *, cfg: ArchConfig, state=None, runtime=None):
    xc, d_in, d_qk, H = _mdims(cfg)
    compute = jnp.dtype(cfg.compute_dtype)
    B, S, _ = x.shape
    if state is None:
        state = init_mlstm_state(cfg, B)
    q, k, v, i_gate, f_gate, z = _mlstm_qkvif(params, x, cfg, compute)
    h, core = mlstm_chunkwise(q, k, v, i_gate, f_gate, state, chunk=xc.chunk)
    h = h.reshape(B, S, d_in)
    h = apply_norm(params["head_norm"], h, "rmsnorm")
    out = (h.astype(compute) * jax.nn.silu(z)) @ params["down_proj"].astype(compute)
    new_state = dict(core)
    # conv tail kept for decode continuity
    xm = (x.astype(compute) @ params["up_proj"].astype(compute))[..., :d_in]
    new_state["conv"] = xm[:, -(xc.s_conv - 1):].astype(jnp.float32) if S >= xc.s_conv - 1 \
        else jnp.concatenate([state["conv"][:, S:], xm.astype(jnp.float32)], axis=1)
    return out.astype(x.dtype), new_state


def mlstm_decode(params, x, state, *, cfg: ArchConfig):
    """Single-step recurrent decode. x (B,1,d)."""
    xc, d_in, d_qk, H = _mdims(cfg)
    compute = jnp.dtype(cfg.compute_dtype)
    B = x.shape[0]
    up = x[:, 0].astype(compute) @ params["up_proj"].astype(compute)
    xm, z = jnp.split(up, 2, axis=-1)
    window = jnp.concatenate([state["conv"].astype(compute), xm[:, None]], axis=1)
    conv_w = params["conv_w"].astype(compute)
    xcn = jax.nn.silu(jnp.sum(window * conv_w[None], axis=1)
                      + params["conv_b"].astype(compute))
    q = (xcn @ params["wq"].astype(compute)).reshape(B, 1, H, d_qk // H)
    k = (xcn @ params["wk"].astype(compute)).reshape(B, 1, H, d_qk // H)
    v = (xm @ params["wv"].astype(compute)).reshape(B, 1, H, d_in // H)
    gif = (xm @ params["w_if"].astype(compute)).astype(jnp.float32) + params["b_if"]
    i_gate, f_gate = jnp.split(gif[:, None], 2, axis=-1)
    h, core = mlstm_recurrent_ref(q, k, v, i_gate, f_gate, state)
    h = apply_norm(params["head_norm"], h.reshape(B, 1, d_in), "rmsnorm")
    out = (h[:, 0].astype(compute) * jax.nn.silu(z)) @ params["down_proj"].astype(compute)
    new_state = dict(core)
    new_state["conv"] = window[:, 1:].astype(jnp.float32)
    return out[:, None].astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    xc = cfg.xlstm
    keys = jax.random.split(key, 6)
    d_up = int(4 * d / 3) // 2 * 2
    return {
        "conv_w": (jax.random.normal(keys[0], (xc.s_conv, d), jnp.float32)
                   / math.sqrt(xc.s_conv)).astype(dtype),
        "conv_b": jnp.zeros((d,), dtype),
        "w_gates": dense_init(keys[1], d, 4 * d, dtype),
        "r_gates": dense_init(keys[2], d, 4 * d, dtype, scale=0.01),
        "b_gates": jnp.concatenate(
            [jnp.zeros((d,)), 3.0 * jnp.ones((d,)),
             jnp.zeros((2 * d,))]).astype(jnp.float32),
        "up_proj": dense_init(keys[3], d, 2 * d_up, dtype),
        "down_proj": dense_init(keys[4], d_up, d, dtype),
        "out_norm": init_norm("rmsnorm", d, dtype),
    }


def init_slstm_state(cfg: ArchConfig, batch: int, leading: tuple = ()):
    d = cfg.d_model
    xc = cfg.xlstm
    z = lambda: jnp.zeros(leading + (batch, d), jnp.float32)
    return {"c": z(), "n": z(), "h": z(),
            "m": jnp.full(leading + (batch, d), -1e30, jnp.float32),
            "conv": jnp.zeros(leading + (batch, xc.s_conv - 1, d), jnp.float32)}


def _slstm_scan_maybe_sharded(params, xconv, x_raw, state, compute, runtime):
    """Run the recurrence under ``shard_map`` over the batch axes when a mesh
    is available.

    Why: with batch-sharded activations and replicated gate weights, GSPMD
    places the weight-gradient all-reduce INSIDE the per-timestep backward
    loop (observed: 232 GB/chip of (3072,768) all-reduces on xlstm-125m
    train_4k).  Inside a shard_map region everything is shard-local; the
    psum of the replicated weights' cotangent is inserted ONCE at region
    exit — the mathematically identical reduction, hoisted out of the loop.
    """
    mesh = getattr(runtime, "mesh", None) if runtime is not None else None
    baxes = getattr(runtime, "batch_axes", None) if runtime is not None else None
    B = x_raw.shape[0]
    if mesh is None or not baxes or B % max(runtime.batch_axis_size, 1):
        return _slstm_scan(params, xconv, x_raw, state, compute)
    from jax.sharding import PartitionSpec as P
    bx = tuple(baxes) if len(baxes) > 1 else baxes[0]
    b3 = P(bx, None, None)
    b2 = P(bx, None)
    used = {k: params[k] for k in ("w_gates", "r_gates", "b_gates")}
    fn = jax.shard_map(
        lambda pr, xc, xr, st: _slstm_scan(pr, xc, xr, st, compute),
        mesh=mesh,
        in_specs=(P(), b3, b3, {"c": b2, "n": b2, "h": b2, "m": b2,
                                "conv": b3}),
        out_specs=(b3, {"c": b2, "n": b2, "h": b2, "m": b2}),
        check_vma=False)
    state_in = {k: state[k] for k in ("c", "n", "h", "m")}
    state_in["conv"] = state["conv"]
    return fn(used, xconv, x_raw, state_in)


def _slstm_scan(params, xconv, x_raw, state, compute):
    """xconv/x_raw (B,S,d). Sequential exponential-gated recurrence.

    The input-side gate projection (xconv @ W + b) is hoisted out of the
    timestep loop as ONE batched matmul — W then streams from HBM once per
    layer instead of once per timestep (the recurrent R @ h matvec stays in
    the loop; holding R VMEM-resident across steps is the Pallas-kernel
    follow-up, see EXPERIMENTS.md §Perf).
    """
    r = params["r_gates"].astype(jnp.float32)
    d = x_raw.shape[-1]
    gates_x = (xconv.astype(jnp.float32)
               @ params["w_gates"].astype(jnp.float32) + params["b_gates"])

    def step(carry, xs):
        c, n, h, m = carry
        gx_t, xr_t = xs                                       # (B,4d),(B,d)
        gates = gx_t + h @ r
        i_t, f_t, z_t, o_t = jnp.split(gates, 4, axis=-1)
        m_new = jnp.maximum(f_t + m, i_t)                     # exp forget gate
        iprime = jnp.exp(i_t - m_new)
        fprime = jnp.exp(f_t + m - m_new)
        c = fprime * c + iprime * jnp.tanh(z_t)
        n = fprime * n + iprime
        h = jax.nn.sigmoid(o_t) * c / jnp.maximum(n, 1e-6)
        return (c, n, h, m_new), h

    xs = (gates_x.transpose(1, 0, 2),
          x_raw.astype(jnp.float32).transpose(1, 0, 2))
    (c, n, h, m), hs = jax.lax.scan(
        step, (state["c"], state["n"], state["h"], state["m"]), xs)
    return hs.transpose(1, 0, 2), {"c": c, "n": n, "h": h, "m": m}


def slstm_forward(params, x, *, cfg: ArchConfig, state=None, runtime=None):
    xc = cfg.xlstm
    compute = jnp.dtype(cfg.compute_dtype)
    B, S, d = x.shape
    if state is None:
        state = init_slstm_state(cfg, B)
    xp = jnp.concatenate([state["conv"].astype(compute), x.astype(compute)], axis=1)
    conv_w = params["conv_w"].astype(compute)
    xconv = sum(xp[:, i:i + S] * conv_w[i] for i in range(xc.s_conv))
    xconv = jax.nn.silu(xconv + params["conv_b"].astype(compute))
    hs, core = _slstm_scan_maybe_sharded(params, xconv, x, state, compute,
                                         runtime)
    hs = apply_norm(params["out_norm"], hs.astype(x.dtype), "rmsnorm")
    up = hs.astype(compute) @ params["up_proj"].astype(compute)
    a, g = jnp.split(up, 2, axis=-1)
    out = (jax.nn.gelu(a, approximate=True) * g) @ params["down_proj"].astype(compute)
    new_state = dict(core)
    new_state["conv"] = xp[:, -(xc.s_conv - 1):].astype(jnp.float32)
    return out.astype(x.dtype), new_state


def slstm_decode(params, x, state, *, cfg: ArchConfig):
    return slstm_forward(params, x, cfg=cfg, state=state)
