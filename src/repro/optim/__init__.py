from repro.optim.optimizers import (Optimizer, adamw, apply_updates,
                                    clip_by_global_norm, constant_schedule,
                                    cosine_schedule, sgd, warmup_cosine)

__all__ = ["Optimizer", "adamw", "sgd", "cosine_schedule", "warmup_cosine",
           "constant_schedule", "clip_by_global_norm", "apply_updates"]
