"""Minimal optimizer library (optax is not installed in this container).

``Optimizer`` is an (init, update) pair over pytrees, mirroring the optax
GradientTransformation contract so swapping in optax later is mechanical.
AdamW supports configurable moment dtype (bf16 moments for the 200B+ MoE
archs — see ArchConfig.moment_dtype).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable   # (grads, state, params) -> (updates, state)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.minimum(step / max(total_steps, 1), 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)
    return fn


def warmup_cosine(lr: float, warmup: int, total_steps: int,
                  final_frac: float = 0.1):
    cos = cosine_schedule(lr, max(total_steps - warmup, 1), final_frac)

    def fn(step):
        warm = lr * step / max(warmup, 1)
        return jnp.where(step < warmup, warm, cos(step - warmup))
    return fn


# ---------------------------------------------------------------------------
# gradient clipping
# ---------------------------------------------------------------------------


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gnorm


# ---------------------------------------------------------------------------
# SGD / AdamW
# ---------------------------------------------------------------------------


def sgd(lr, momentum: float = 0.0, weight_decay: float = 0.0):
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        if momentum == 0.0:
            return {"step": jnp.zeros((), jnp.int32)}
        return {"step": jnp.zeros((), jnp.int32),
                "mu": jax.tree_util.tree_map(
                    lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)

        def upd(g, p, mu=None):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            if mu is not None:
                mu_new = momentum * mu + g
                return -lr_t * mu_new, mu_new
            return -lr_t * g, None

        if momentum == 0.0:
            updates = jax.tree_util.tree_map(
                lambda g, p: upd(g, p)[0], grads, params)
            return updates, {"step": step}
        pairs = jax.tree_util.tree_map(upd, grads, params, state["mu"])
        updates = jax.tree_util.tree_map(lambda pr: pr[0], pairs,
                                         is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree_util.tree_map(lambda pr: pr[1], pairs,
                                    is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"step": step, "mu": mu}

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, moment_dtype=jnp.float32):
    sched = lr if callable(lr) else constant_schedule(lr)
    moment_dtype = jnp.dtype(moment_dtype)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, moment_dtype)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree_util.tree_map(zeros, params),
                "v": jax.tree_util.tree_map(zeros, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
            mhat = m_new / bc1
            vhat = v_new / bc2
            u = -lr_t * (mhat / (jnp.sqrt(vhat) + eps)
                         + weight_decay * p.astype(jnp.float32))
            return u, m_new.astype(moment_dtype), v_new.astype(moment_dtype)

        triples = jax.tree_util.tree_map(upd, grads, state["m"], state["v"],
                                         params)
        is_t = lambda x: isinstance(x, tuple)
        updates = jax.tree_util.tree_map(lambda t: t[0], triples, is_leaf=is_t)
        m = jax.tree_util.tree_map(lambda t: t[1], triples, is_leaf=is_t)
        v = jax.tree_util.tree_map(lambda t: t[2], triples, is_leaf=is_t)
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)
