"""Runtime knobs threaded through model apply functions."""
from __future__ import annotations

from dataclasses import dataclass


from typing import Any, Optional, Tuple


@dataclass(frozen=True)
class Runtime:
    use_pallas: bool = False       # route hot-spots through Pallas kernels
    # kernel dispatch policy for those hot-spots (repro.kernels.dispatch):
    # "auto" resolves via $REPRO_KERNEL_POLICY then the platform (TPU ->
    # "compiled", else "interpret"); "reference" forces the pure-jnp
    # oracles.  Supersedes pallas_interpret, which remains only as a
    # legacy explicit override consumed by kernels.dispatch.
    kernel_policy: str = "auto"
    pallas_interpret: Optional[bool] = None  # legacy; None = follow policy
    remat: bool = True             # checkpoint scanned periods in training
    want_signature: bool = False   # emit DAG-AFL feature signature in aux
    signature_tau: float = 0.05
    signature_dims: int = 64
    # activation sharding: constrain the residual stream's batch dim to these
    # mesh axes (set by the launcher; None = no constraints, e.g. CPU tests)
    batch_axes: Optional[Tuple[str, ...]] = None
    batch_axis_size: int = 1
    # mesh handle for shard_map regions (recurrent blocks move their weight-
    # gradient reduction out of the timestep loop this way; see xlstm.py)
    mesh: Optional[Any] = None


DEFAULT = Runtime()


def serve_runtime(kernel_policy: Optional[str] = None) -> Runtime:
    """Runtime for the serving path (prefill + KV-cache decode): no
    signature extraction, kernel hot-spots routed per ``kernel_policy``
    (None / "reference" keep the stock-XLA math — the same convention the
    FL backends use for their ``kernel_policy`` knob)."""
    if kernel_policy is None or kernel_policy == "reference":
        return Runtime()
    return Runtime(use_pallas=True, kernel_policy=kernel_policy)
