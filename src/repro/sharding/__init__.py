from repro.sharding.rules import (MeshPlan, batch_shardings, cache_shardings,
                                  opt_state_shardings, param_pspec,
                                  param_shardings, replicated)

__all__ = ["MeshPlan", "param_pspec", "param_shardings",
           "opt_state_shardings", "batch_shardings", "cache_shardings",
           "replicated"]
