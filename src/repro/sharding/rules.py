"""Path-based PartitionSpec rules: params, optimizer state, batches, caches.

Strategy (see DESIGN.md §4):
  - tensor parallel over ``model``: column-parallel projections shard their
    output feature dim, row-parallel their input dim; attention projections
    shard ONLY when the head count divides the axis (never split a head);
    MoE experts shard the expert dim (expert parallelism); vocab shards the
    embedding/unembed.
  - FSDP over ``data`` (+ ``pod``): large leaves additionally shard a
    non-TP dim when divisible (threshold ``fsdp_min_bytes``).
  - anything non-divisible falls back to replication — the rules must never
    produce an invalid NamedSharding for any (arch x mesh).

Leaf names are the contract with ``repro.models`` init functions.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class MeshPlan:
    """Axis layout of the production mesh."""

    batch_axes: Tuple[str, ...] = ("data",)    # ("pod","data") multi-pod
    tp_axis: str = "model"
    fsdp_axis = "data"                         # may be a tuple of axes
    fsdp_min_bytes: int = 1 << 22              # 4 MiB
    enable_fsdp: bool = True
    enable_tp: bool = True                     # False: pure data parallelism
    attn_tp: bool = True                       # False: replicate q/o (decode
                                               # with non-shardable kv heads)
    # serving: shard experts over data x model (2-D EP+TP) so no weight is
    # ever re-gathered per decoded token (FSDP gathers are a train-time
    # amortisation that decode cannot afford)
    expert_data_shard: bool = False
    # serving: additionally shard the embedding/unembed tables over the
    # data axes (they are touched once per step; per-layer projections stay
    # TP-only — XLA re-gathers contraction-sharded weights, measured worse)
    dense_2d_shard: bool = False

    def axis_size(self, mesh: Mesh, name) -> int:
        if isinstance(name, tuple):
            return int(np.prod([mesh.shape[a] for a in name]))
        return mesh.shape[name]


def small_model_plan(batch_axes: Tuple[str, ...], tp_axis: str,
                     param_count: int) -> "MeshPlan":
    """Beyond-baseline plan for small archs: TP off, batch over EVERY axis.

    A 125M-2B model TP-sharded 16 ways pays per-layer (and for recurrent
    blocks per-timestep) collectives worth orders of magnitude more than its
    compute (observed: 61x on xlstm-125m train_4k).  Pure DP removes them;
    FSDP over the combined axis keeps optimizer state per-chip bounded for
    the >0.75B members."""
    plan = MeshPlan(batch_axes=tuple(batch_axes) + (tp_axis,),
                    enable_tp=False,
                    enable_fsdp=param_count > 750_000_000)
    object.__setattr__(plan, "_fsdp_axes", tuple(batch_axes) + (tp_axis,))
    return plan


# column-parallel (shard output dim -1), row-parallel (shard input dim -2)
_COL = {"wq", "wk", "wv", "wi", "wg", "up_proj", "in_proj",
        "wq_a", "wq_b", "wkv_b", "unembed"}
_ROW = {"wo", "wdown", "down_proj", "out_proj", "dt_proj", "x_proj", "xwo"}
_CROSS_COL = {"xwq", "xwk", "xwv"}
_EXPERT = {"we_gate", "we_up", "we_down"}
# sLSTM gate weights are REPLICATED: TP-sharding a per-timestep recurrence
# inserts a collective every timestep (observed: 1.6 s collective term on a
# 125M model).  w_if (mLSTM gates) is tiny; same treatment.
_REPLICATE = {"scale", "bias", "bq", "bk", "bv", "b_if", "b_gates", "conv_w",
              "conv_b", "dt_bias", "A_log", "D", "router", "wkv_a", "b",
              "w_gates", "r_gates", "w_if"}

# attention-projection leaves gated on head divisibility
_Q_HEAD_LEAVES = {"wq", "xwq", "wq_b"}
_KV_HEAD_LEAVES = {"wk", "wv", "xwk", "xwv"}
_O_HEAD_LEAVES = {"wo", "xwo"}


def _head_aligned(cfg: ArchConfig, name: str, tp: int) -> bool:
    if cfg.mla is not None:
        # MLA: wq_b/wkv_b/wo all carry n_heads; kv latents are replicated
        return cfg.n_heads % tp == 0
    if name in _Q_HEAD_LEAVES or name in _O_HEAD_LEAVES:
        return cfg.n_heads % tp == 0
    if name in _KV_HEAD_LEAVES:
        return cfg.n_kv_heads % tp == 0
    return True


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def _in_module(path, module: str) -> bool:
    return any(isinstance(e, jax.tree_util.DictKey) and str(e.key) == module
               for e in path)


def param_pspec(path, leaf, cfg: ArchConfig, mesh: Mesh, plan: MeshPlan) -> P:
    name = _leaf_name(path)
    nd = leaf.ndim
    tp = plan.axis_size(mesh, plan.tp_axis) if plan.enable_tp else 1
    fsdp_axis = getattr(plan, "_fsdp_axes", None) or plan.fsdp_axis
    fsdp = plan.axis_size(mesh, fsdp_axis)
    spec = [None] * nd

    def try_assign(dim: int, axis, size: int) -> bool:
        d = dim % nd
        if spec[d] is None and leaf.shape[d] % size == 0 and size > 1:
            spec[d] = axis
            return True
        return False

    is_attn_leaf = (name in _Q_HEAD_LEAVES | _KV_HEAD_LEAVES | _O_HEAD_LEAVES
                    or name in {"wkv_b"})
    head_ok = _head_aligned(cfg, name, tp) and plan.attn_tp

    if name == "embedding":
        try_assign(-2, plan.tp_axis, tp)               # vocab over model
        if plan.dense_2d_shard:                        # serving: 2-D table
            baxes = tuple(plan.batch_axes)
            try_assign(-1, baxes if len(baxes) > 1 else baxes[0],
                       plan.axis_size(mesh, baxes))
        return P(*spec)            # never FSDP the d dim of the lookup table
    elif name in _EXPERT and nd >= 3:
        if plan.expert_data_shard:
            baxes = tuple(plan.batch_axes)
            bsize = plan.axis_size(mesh, baxes)
            if not try_assign(-3, baxes if len(baxes) > 1 else baxes[0], bsize):
                try_assign(-3, plan.batch_axes[-1],
                           plan.axis_size(mesh, plan.batch_axes[-1]))
            # per-expert TP: col for up/gate, row for down
            if name == "we_down":
                try_assign(-2, plan.tp_axis, tp)
            else:
                try_assign(-1, plan.tp_axis, tp)
            return P(*spec)
        try_assign(-3, plan.tp_axis, tp)               # experts over model
    elif name in _COL or name in _CROSS_COL:
        if not is_attn_leaf or head_ok:
            try_assign(-1, plan.tp_axis, tp)
        if plan.dense_2d_shard and name == "unembed":
            baxes = tuple(plan.batch_axes)
            try_assign(-2, baxes if len(baxes) > 1 else baxes[0],
                       plan.axis_size(mesh, baxes))
            return P(*spec)
    elif name in _ROW:
        if not is_attn_leaf or head_ok:
            try_assign(-2, plan.tp_axis, tp)
    elif name in _REPLICATE:
        pass

    # FSDP over the data axis for big leaves, on a spare dim
    if (plan.enable_fsdp and leaf.size * leaf.dtype.itemsize
            >= plan.fsdp_min_bytes and nd >= 2):
        for dim in (-2, -1, -3):
            if abs(dim) <= nd and try_assign(dim, fsdp_axis, fsdp):
                break
    return P(*spec)


def param_shardings(params, cfg: ArchConfig, mesh: Mesh,
                    plan: Optional[MeshPlan] = None):
    plan = plan or MeshPlan()

    def spec(path, leaf):
        return NamedSharding(mesh, param_pspec(path, leaf, cfg, mesh, plan))

    return jax.tree_util.tree_map_with_path(spec, params)


def opt_state_shardings(opt_state, params_sh, mesh: Mesh):
    """Adam m/v mirror the param shardings; step scalars replicate."""
    flat_params = dict(jax.tree_util.tree_flatten_with_path(params_sh)[0])

    def walk(state):
        out = {}
        for k, v in state.items():
            if k == "step":
                out[k] = NamedSharding(mesh, P())
            else:
                out[k] = jax.tree_util.tree_map_with_path(
                    lambda path, leaf, _k=k: flat_params.get(
                        tuple(path), NamedSharding(mesh, P())), v)
        return out

    # m/v have identical treedef to params => reuse specs by path
    def mirror(path, leaf):
        return flat_params.get(tuple(path), NamedSharding(mesh, P()))

    out = {}
    for k, v in opt_state.items():
        if k == "step":
            out[k] = NamedSharding(mesh, P())
        else:
            out[k] = jax.tree_util.tree_map_with_path(mirror, v)
    return out


def batch_shardings(batch, mesh: Mesh, plan: Optional[MeshPlan] = None):
    """tokens/labels (B, S): shard batch over the batch axes when divisible;
    M-RoPE positions (3, B, S) shard dim 1."""
    plan = plan or MeshPlan()
    baxes = plan.batch_axes if len(plan.batch_axes) > 1 else plan.batch_axes[0]
    bsize = plan.axis_size(mesh, tuple(plan.batch_axes))

    def spec(path, leaf):
        nd = leaf.ndim
        bdim = 1 if (nd == 3 and leaf.shape[0] == 3) else 0
        s = [None] * nd
        if leaf.shape[bdim] % bsize == 0 and bsize > 1:
            s[bdim] = baxes
        return NamedSharding(mesh, P(*s))

    return jax.tree_util.tree_map_with_path(spec, batch)


def cache_shardings(cache, cfg: ArchConfig, mesh: Mesh,
                    plan: Optional[MeshPlan] = None):
    """KV caches (R, B, S, K, hd) / (R, B, S, r): batch over data when it
    divides, otherwise SEQUENCE over data (long_500k batch=1 path); kv heads
    over model when divisible."""
    plan = plan or MeshPlan()
    baxes = plan.batch_axes if len(plan.batch_axes) > 1 else plan.batch_axes[0]
    bsize = plan.axis_size(mesh, tuple(plan.batch_axes))
    tp_in_batch = plan.tp_axis in plan.batch_axes
    tp = (plan.axis_size(mesh, plan.tp_axis)
          if plan.enable_tp and not tp_in_batch else 1)

    all_axes = (tuple(plan.batch_axes) if tp_in_batch
                else tuple(plan.batch_axes) + (plan.tp_axis,))

    def axis_prod(axes):
        out = 1
        for a in axes:
            out *= mesh.shape[a]
        return out

    def spec(path, leaf):
        name = _leaf_name(path)
        nd = leaf.ndim
        s = [None] * nd
        if name in ("k", "v", "xk", "xv") and nd == 5:
            R, B, S, K, hd = leaf.shape
            kv_shardable = K % tp == 0 and tp > 1
            if B % bsize == 0 and bsize > 1:
                s[1] = baxes
                if kv_shardable:
                    s[3] = plan.tp_axis
                elif S % tp == 0 and tp > 1:
                    s[2] = plan.tp_axis
            else:
                # batch not fully shardable: try a leading subset of the
                # batch axes for B, the rest for S (whisper cross-kv path),
                # then pure sequence sharding (long_500k batch=1 path)
                done = False
                for i in range(len(plan.batch_axes) - 1, 0, -1):
                    head = plan.batch_axes[:i]
                    tail = plan.batch_axes[i:]
                    if B % axis_prod(head) == 0 and axis_prod(head) > 1:
                        s[1] = head if len(head) > 1 else head[0]
                        if S % axis_prod(tail) == 0:
                            s[2] = tail if len(tail) > 1 else tail[0]
                        elif K % axis_prod(tail) == 0:
                            s[3] = tail if len(tail) > 1 else tail[0]
                        done = True
                        break
                if not done:
                    if not kv_shardable and S % (bsize * tp) == 0:
                        s[2] = all_axes
                    elif S % bsize == 0 and bsize > 1:
                        s[2] = baxes
                        if kv_shardable:
                            s[3] = plan.tp_axis
        elif name in ("ckv", "krope") and nd == 4:
            R, B, S, r = leaf.shape
            if B % bsize == 0 and bsize > 1:
                s[1] = baxes
                if S % tp == 0 and tp > 1:
                    s[2] = plan.tp_axis
            elif S % (bsize * tp) == 0:
                s[2] = all_axes
            elif S % bsize == 0 and bsize > 1:
                s[2] = baxes
        else:
            # recurrent states: (R, B, ...) batch over data when divisible
            if nd >= 2 and leaf.shape[1] % bsize == 0 and bsize > 1:
                s[1] = baxes
            # shard the big inner dim of mamba/mlstm states over model
            if nd >= 3 and leaf.shape[2] % tp == 0 and tp > 1 \
                    and name in ("h", "C", "n", "conv"):
                dim = 2 if name != "conv" else nd - 1
                if leaf.shape[dim] % tp == 0:
                    s[dim] = plan.tp_axis
        return NamedSharding(mesh, P(*s))

    return jax.tree_util.tree_map_with_path(spec, cache)


def replicated(tree, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), tree)


# -- cohort (stacked K-client) trees ----------------------------------------
#
# The FL cohort engine (repro.fl.cohort) keeps K client models stacked as one
# pytree with a leading client axis.  Its SPMD layout is two rules: shard
# that leading axis over the ``clients`` mesh axis, and — on a 2-D
# (clients, data) mesh — shard a designated SAMPLE dim of the batch arrays
# over the ``data`` axis while the client models stay REPLICATED within
# each client group (per-group gradient psums keep them in lockstep).
# Per-client model parallelism belongs to the per-leaf rules above and
# composes via extra mesh axes, never by splitting a client's own dims here.


def cohort_pspec(axis: str = "clients", data_axis: Optional[str] = None,
                 data_dim: Optional[int] = None) -> P:
    """PartitionSpec of a stacked-cohort array: leading client axis sharded
    over ``axis``; with ``data_axis`` AND ``data_dim`` given, that dim
    additionally shards over the data axis (batch/sample dims of xb/yb/eval
    arrays — dim 2 for train batches (K, T, B, ...), dim 1 for eval shards
    (K, N, ...)).  Params never take a data dim: they replicate within a
    client group."""
    if data_axis is None or data_dim is None:
        return P(axis)
    if data_dim < 1:
        raise ValueError(f"data_dim must be >= 1 (got {data_dim}); dim 0 is "
                         "the client axis")
    spec = [axis] + [None] * (data_dim - 1) + [data_axis]
    return P(*spec)


def _check_axis(mesh: Mesh, axis: str) -> None:
    if axis not in mesh.shape:
        raise ValueError(f"mesh {tuple(mesh.axis_names)} has no {axis!r} axis")


def cohort_batch_sharding(mesh: Mesh, axis: str = "clients",
                          data_axis: Optional[str] = None,
                          data_dim: Optional[int] = None) -> NamedSharding:
    """NamedSharding for a cohort batch array (xb/yb/mask): leading client
    axis over ``axis``; on a 2-D mesh, ``data_dim`` (the sample dim) over
    ``data_axis``.  One rule for every backend family — the engine never
    inspects what the trailing dims hold (image batches, token windows,
    masks)."""
    _check_axis(mesh, axis)
    if data_axis is not None:
        _check_axis(mesh, data_axis)
    return NamedSharding(mesh, cohort_pspec(axis, data_axis, data_dim))


def data_shard_sharding(mesh: Mesh, data_axis: str = "data",
                        dim: int = 0) -> NamedSharding:
    """NamedSharding for an array carrying NO client axis whose ``dim``
    shards over the data axis (e.g. the shared validation shard of a tip
    sweep, or the per-step batch-row mask)."""
    _check_axis(mesh, data_axis)
    spec = [None] * dim + [data_axis]
    return NamedSharding(mesh, P(*spec))


def stacked_client_shardings(stacked, mesh: Mesh, axis: str = "clients",
                             data_axis: Optional[str] = None):
    """NamedShardings for a ``tree_stack``-ed K-client pytree: every leaf's
    leading K axis over ``axis``, remaining dims replicated.  K must divide
    ``mesh.shape[axis]`` times an integer — the cohort engine guarantees it
    by padding the client axis to a multiple of the mesh size.  On a 2-D
    (clients, data) mesh the params stay REPLICATED over ``data_axis``
    (each device in a client group holds the group's full models; only the
    batch arrays split) — the axis is accepted and validated here so
    callers can pass their full mesh spec through one chokepoint."""
    _check_axis(mesh, axis)
    if data_axis is not None:
        _check_axis(mesh, data_axis)
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, cohort_pspec(axis)), stacked)
