from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.step import (default_optimizer, make_eval_step,
                              make_serve_decode, make_serve_prefill,
                              make_train_step)

__all__ = ["make_train_step", "make_serve_prefill", "make_serve_decode",
           "make_eval_step", "default_optimizer", "save_checkpoint",
           "load_checkpoint"]
