"""Checkpointing: flat-key .npz snapshots of arbitrary pytrees.

No orbax in this container; paths are keyed by their tree path so any
params/opt_state tree round-trips exactly (dtypes included).
"""
from __future__ import annotations

import os
import re
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey)
            else f"[{p.idx}]" if isinstance(p, jax.tree_util.SequenceKey)
            else str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, tree, step: int = 0) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    flat["__step__"] = np.asarray(step)
    np.savez(path, **flat)
    return path


def load_checkpoint(path: str, like) -> Tuple[object, int]:
    """Restore into the structure of ``like`` (values replaced by file's)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    step = int(data["__step__"]) if "__step__" in data else 0
    flat = _flatten(like)
    missing = [k for k in flat if k not in data.files]
    if missing:
        raise KeyError(f"checkpoint missing keys: {missing[:5]}...")
    values = {k: jnp.asarray(data[k]) for k in flat}
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    ordered = []
    for path_k, leaf in leaves_like:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey)
            else f"[{p.idx}]" if isinstance(p, jax.tree_util.SequenceKey)
            else str(p) for p in path_k)
        ordered.append(values[key].astype(leaf.dtype).reshape(leaf.shape))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), ordered)
    return tree, step
