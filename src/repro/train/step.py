"""Jittable train / serve step builders used by the launcher and dry-run.

``make_train_step`` folds loss, grad, clip, optimizer update and the
DAG-AFL signature extraction into one pjit-able program;
``make_serve_prefill`` / ``make_serve_decode`` are the serving pair
(decode = ONE new token against a KV cache, per the assigned decode shapes).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as tfm
from repro.optim.optimizers import (Optimizer, adamw, apply_updates,
                                    clip_by_global_norm)
from repro.runtime import Runtime


def default_optimizer(cfg: ArchConfig, lr: float = 3e-4) -> Optimizer:
    return adamw(lr, weight_decay=0.1,
                 moment_dtype=jnp.dtype(cfg.moment_dtype))


def make_train_step(cfg: ArchConfig, optimizer: Optional[Optimizer] = None,
                    runtime: Runtime = Runtime(want_signature=True),
                    clip_norm: float = 1.0, microbatches: int = 1):
    """``microbatches > 1`` = gradient accumulation: the global batch is
    split into N sequential microbatches scanned with f32 grad accumulation.
    Activation (and layer-scan carry) memory scales by 1/N — the lever that
    brings 200B+ MoE training under the per-chip HBM budget (see
    EXPERIMENTS.md §Perf H3)."""
    opt = optimizer or default_optimizer(cfg)
    compute = jnp.dtype(cfg.compute_dtype)

    def cast_params(p):
        """Mixed precision: compute against a bf16 copy so FSDP all-gathers
        move half the bytes; the f32 master stays sharded."""
        return jax.tree_util.tree_map(
            lambda a: a.astype(compute)
            if jnp.issubdtype(a.dtype, jnp.floating) and a.dtype != compute
            else a, p)

    def grad_fn(params, batch):
        return jax.value_and_grad(
            lambda p: tfm.loss_fn(cast_params(p), batch, cfg, runtime),
            has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, aux), grads = grad_fn(params, batch)
        else:
            def split(leaf):
                # M-RoPE positions are (3, B, S): batch dim is 1 there
                bdim = 1 if (leaf.ndim == 3 and leaf.shape[0] == 3) else 0
                B = leaf.shape[bdim]
                assert B % microbatches == 0, (B, microbatches)
                if bdim == 0:
                    return leaf.reshape(microbatches, B // microbatches,
                                        *leaf.shape[1:])
                out = leaf.reshape(leaf.shape[0], microbatches,
                                   B // microbatches, *leaf.shape[2:])
                return jnp.moveaxis(out, 1, 0)

            mb = jax.tree_util.tree_map(split, batch)

            def body(carry, batch_mb):
                gsum, lsum, auxsum = carry
                (loss, aux), g = grad_fn(params, batch_mb)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                auxsum = {k: auxsum[k] + v for k, v in aux.items()
                          if k in auxsum}
                return (gsum, lsum + loss, auxsum), aux.get("signature")

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            aux0 = {"ce_loss": jnp.zeros(()), "moe_aux": jnp.zeros(())}
            (grads, loss, aux), sigs = jax.lax.scan(
                body, (g0, jnp.zeros(()), aux0), mb)
            n = float(microbatches)
            grads = jax.tree_util.tree_map(lambda g: g / n, grads)
            loss = loss / n
            aux = {k: v / n for k, v in aux.items()}
            if sigs is not None and runtime.want_signature:
                aux["signature"] = jnp.mean(sigs, axis=0)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, new_opt_state = opt.update(grads, opt_state, params)
        new_params = apply_updates(params, updates)
        metrics = {"loss": loss, "ce_loss": aux["ce_loss"],
                   "moe_aux": aux["moe_aux"], "grad_norm": gnorm}
        if "signature" in aux:
            metrics["signature"] = aux["signature"]
        return new_params, new_opt_state, metrics

    return train_step, opt


def make_serve_prefill(cfg: ArchConfig, runtime: Runtime = Runtime()):
    def serve_prefill(params, batch):
        last_logits, caches, _ = tfm.prefill(params, batch, cfg, runtime)
        return last_logits, caches

    return serve_prefill


def make_serve_decode(cfg: ArchConfig, runtime: Runtime = Runtime()):
    def serve_decode(params, token, caches, pos):
        logits, new_caches = tfm.decode_step(params, token, caches, pos, cfg,
                                             runtime)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, new_caches

    return serve_decode


def make_eval_step(cfg: ArchConfig, runtime: Runtime = Runtime()):
    def eval_step(params, batch):
        logits, aux, _ = tfm.forward(params, batch, cfg, runtime,
                                     mode="prefill")
        pred = jnp.argmax(logits[:, :-1], axis=-1)
        acc = jnp.mean((pred == batch["tokens"][:, 1:]).astype(jnp.float32))
        return {"accuracy": acc}

    return eval_step
