"""Minimal stand-in for the ``hypothesis`` API used by this test suite.

The CI image installs the real ``hypothesis`` (see requirements.txt); this
fallback keeps the property-test modules collectable and meaningfully
runnable in hermetic containers where it is absent.  It implements the
subset the suite uses — ``given``, ``settings``, and the ``integers`` /
``floats`` / ``lists`` / ``tuples`` / ``sampled_from`` strategies — as a
deterministic random sampler (fixed seed, so failures reproduce).  No
shrinking, no database, no health checks.

``tests/conftest.py`` installs this module into ``sys.modules`` as
``hypothesis`` only when the real package cannot be imported.
"""
from __future__ import annotations


import random
from types import ModuleType
from typing import Any, Callable, List, Sequence

DEFAULT_MAX_EXAMPLES = 25
_SEED = 0xDA6AF1


class Strategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def example_from(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def map(self, fn: Callable[[Any], Any]) -> "Strategy":
        return Strategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred: Callable[[Any], bool]) -> "Strategy":
        def draw(rng: random.Random):
            for _ in range(1000):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise RuntimeError("filter predicate never satisfied")
        return Strategy(draw)


def integers(min_value: int = -(2 ** 31), max_value: int = 2 ** 31 - 1
             ) -> Strategy:
    def draw(rng: random.Random) -> int:
        # bias toward the boundaries, where off-by-ones live
        r = rng.random()
        if r < 0.15:
            return min_value
        if r < 0.3:
            return max_value
        return rng.randint(min_value, max_value)
    return Strategy(draw)


def floats(min_value: float = -1e9, max_value: float = 1e9,
           allow_nan: bool = False, allow_infinity: bool = False,
           width: int = 64) -> Strategy:
    def draw(rng: random.Random) -> float:
        r = rng.random()
        if r < 0.1:
            return float(min_value)
        if r < 0.2:
            return float(max_value)
        if r < 0.3 and min_value <= 0.0 <= max_value:
            return 0.0
        return rng.uniform(min_value, max_value)
    return Strategy(draw)


def booleans() -> Strategy:
    return Strategy(lambda rng: rng.random() < 0.5)


def lists(elements: Strategy, min_size: int = 0, max_size: int = 10,
          unique: bool = False) -> Strategy:
    def draw(rng: random.Random) -> List[Any]:
        n = rng.randint(min_size, max_size)
        out: List[Any] = []
        attempts = 0
        while len(out) < n and attempts < 1000:
            v = elements.example_from(rng)
            attempts += 1
            if unique and v in out:
                continue
            out.append(v)
        return out
    return Strategy(draw)


def tuples(*strategies: Strategy) -> Strategy:
    return Strategy(lambda rng: tuple(s.example_from(rng) for s in strategies))


def sampled_from(choices: Sequence[Any]) -> Strategy:
    seq = list(choices)
    return Strategy(lambda rng: seq[rng.randrange(len(seq))])


def just(value: Any) -> Strategy:
    return Strategy(lambda rng: value)


def one_of(*strategies: Strategy) -> Strategy:
    return Strategy(
        lambda rng: strategies[rng.randrange(len(strategies))].example_from(rng))


class _Unsatisfied(Exception):
    pass


def assume(condition: bool) -> bool:
    if not condition:
        raise _Unsatisfied()
    return True


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored) -> Callable:
    def decorate(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return decorate


class HealthCheck:
    """Accepted and ignored (API compatibility)."""

    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"

    @classmethod
    def all(cls):
        return []


def given(*strategies: Strategy, **kw_strategies: Strategy) -> Callable:
    def decorate(fn):
        # NOTE: no functools.wraps — pytest must see a parameterless
        # signature, or it would treat the strategy params as fixtures.
        def wrapper():
            n = getattr(fn, "_fallback_max_examples", DEFAULT_MAX_EXAMPLES)
            rng = random.Random(_SEED)
            ran = 0
            for i in range(n * 4):            # head-room for assume() rejects
                if ran >= n:
                    break
                pos = tuple(s.example_from(rng) for s in strategies)
                kws = {k: s.example_from(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*pos, **kws)
                except _Unsatisfied:
                    continue
                except Exception:
                    print(f"Falsifying example (fallback hypothesis): "
                          f"args={pos} kwargs={kws}")
                    raise
                ran += 1
            return None
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.__qualname__ = fn.__qualname__
        return wrapper
    return decorate


def _build_module() -> ModuleType:
    mod = ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.HealthCheck = HealthCheck
    mod.__version__ = "0.0.0-fallback"
    st = ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "lists", "tuples",
                 "sampled_from", "just", "one_of"):
        setattr(st, name, globals()[name])
    mod.strategies = st
    return mod


def install() -> None:
    """Register this module as ``hypothesis`` if the real one is missing."""
    import sys
    if "hypothesis" in sys.modules:
        return
    try:
        import hypothesis  # noqa: F401  (real package present)
        return
    except ImportError:
        pass
    mod = _build_module()
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = mod.strategies
