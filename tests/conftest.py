import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))
# Repo root, so `import tools.repro_lint` resolves under pytest.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

# Hermetic containers may lack `hypothesis`; fall back to the bundled
# deterministic shim so property-test modules still collect and run.
import _hypothesis_fallback  # noqa: E402

_hypothesis_fallback.install()
