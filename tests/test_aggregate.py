"""Aggregation (paper Eq. 6) + weighted/interpolated variants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.aggregate import (tree_interpolate, tree_mean,
                                  tree_size_bytes, tree_weighted)


def make_tree(v):
    return {"a": jnp.full((3, 2), v, jnp.float32),
            "b": [jnp.full((4,), 2 * v, jnp.float32)],
            "n": jnp.asarray(7, jnp.int32)}       # non-float passes through


def test_tree_mean_eq6():
    out = tree_mean([make_tree(1.0), make_tree(3.0)])
    assert np.allclose(out["a"], 2.0)
    assert np.allclose(out["b"][0], 4.0)
    assert out["n"] == 7


def test_tree_weighted_normalises():
    out = tree_weighted([make_tree(0.0), make_tree(1.0)], [1.0, 3.0])
    assert np.allclose(out["a"], 0.75)


def test_tree_interpolate():
    out = tree_interpolate(make_tree(0.0), make_tree(1.0), 0.25)
    assert np.allclose(out["a"], 0.25)


def test_tree_size_bytes():
    assert tree_size_bytes({"w": jnp.zeros((8,), jnp.float32)}) == 32


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(-1e3, 1e3), min_size=2, max_size=6))
def test_mean_matches_numpy(vals):
    trees = [make_tree(v) for v in vals]
    out = tree_mean(trees)
    assert np.allclose(out["a"], np.mean(vals), rtol=1e-5, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.floats(-10, 10), st.floats(0.01, 5)),
                min_size=2, max_size=5))
def test_weighted_is_convex_combination(pairs):
    vals = [p[0] for p in pairs]
    ws = [p[1] for p in pairs]
    out = tree_weighted([make_tree(v) for v in vals], ws)
    expect = np.sum(np.array(vals) * np.array(ws)) / np.sum(ws)
    assert np.allclose(out["a"], expect, rtol=1e-4, atol=1e-4)
    assert out["a"].min() >= min(vals) - 1e-4
    assert out["a"].max() <= max(vals) + 1e-4
