"""Assigned-architecture smoke tests (deliverable f).

Each of the 10 architectures is instantiated as a REDUCED member of the same
family (2 layers, d_model<=512, <=4 experts) and runs one forward and one
train step on CPU; output shapes and finiteness are asserted.  The FULL
configs are exercised shape-only by the dry-run.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, all_configs, get_config, reduced
from repro.models import transformer as T
from repro.optim.optimizers import apply_updates, sgd


def _reduced(name):
    return dataclasses.replace(reduced(get_config(name)),
                               compute_dtype="float32")


def _batch(cfg, key, B=2, S=32):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.encoder is not None:
        batch["enc_embed"] = jax.random.normal(
            key, (B, cfg.encoder.n_ctx, cfg.d_model), jnp.float32) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_shapes_and_finiteness(arch):
    cfg = _reduced(arch)
    assert cfg.n_layers <= 4 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    batch = _batch(cfg, key)
    logits, aux, _ = T.forward(params, batch, cfg)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step_no_nans(arch):
    cfg = _reduced(arch)
    key = jax.random.PRNGKey(1)
    params = T.init_params(key, cfg)
    batch = _batch(cfg, key)
    opt = sgd(0.05, momentum=0.9)
    opt_state = opt.init(params)
    (loss, aux), grads = jax.value_and_grad(
        lambda p: T.loss_fn(p, batch, cfg), has_aux=True)(params)
    assert bool(jnp.isfinite(loss))
    updates, opt_state = opt.update(grads, opt_state, params)
    new_params = apply_updates(params, updates)
    loss2, _ = T.loss_fn(new_params, batch, cfg)
    assert bool(jnp.isfinite(loss2))
    # one SGD step on the same batch should not increase loss much
    assert float(loss2) < float(loss) + 0.5


def test_full_configs_match_assignment():
    dims = {
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
    }
    for name, cfg in all_configs().items():
        L, d, h, kv, ff, v = dims[name]
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, h, kv, ff, v), name
        assert cfg.citation


def test_moe_assignment_details():
    l4 = get_config("llama4-maverick-400b-a17b")
    assert l4.moe.n_experts == 128 and l4.moe.top_k == 1
    ds = get_config("deepseek-v2-236b")
    assert ds.moe.n_experts == 160 and ds.moe.top_k == 6 and ds.moe.n_shared == 2
    assert ds.mla.kv_lora_rank == 512
    jb = get_config("jamba-v0.1-52b")
    assert jb.moe.n_experts == 16 and jb.moe.top_k == 2
    kinds = [s.kind for s in jb.layer_specs()]
    assert kinds.count("attn") * 7 == kinds.count("mamba")  # 1:7 interleave
