"""Equivalence of the attention/recurrence compute paths used at scale."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.attention as A
import repro.models.mamba as M
import repro.models.xlstm as X


def _qkv(B=2, S=300, H=4, K=2, hd=32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (B, S, H, hd)),
            jax.random.normal(ks[1], (B, S, K, hd)),
            jax.random.normal(ks[2], (B, S, K, hd)))


def test_chunked_equals_dense():
    q, k, v = _qkv()
    pos = jnp.arange(300, dtype=jnp.int32)
    d = A._dense_attn(q, k, v, pos, pos, True, -1, 0.0)
    c = A._chunked_attn(q, k, v, pos, pos, True, 0.0, chunk=64)
    np.testing.assert_allclose(np.asarray(d), np.asarray(c), atol=2e-5)


def test_chunked_softcap():
    q, k, v = _qkv(seed=1)
    pos = jnp.arange(300, dtype=jnp.int32)
    d = A._dense_attn(q, k, v, pos, pos, True, -1, 30.0)
    c = A._chunked_attn(q, k, v, pos, pos, True, 30.0, chunk=64)
    np.testing.assert_allclose(np.asarray(d), np.asarray(c), atol=2e-5)


@pytest.mark.parametrize("window,q_block", [(48, 32), (100, 64), (8, 16)])
def test_banded_equals_dense(window, q_block):
    q, k, v = _qkv(seed=2)
    pos = jnp.arange(300, dtype=jnp.int32)
    d = A._dense_attn(q, k, v, pos, pos, True, window, 0.0)
    b = A._banded_attn(q, k, v, pos, pos, window, 0.0, q_block=q_block)
    np.testing.assert_allclose(np.asarray(d), np.asarray(b), atol=2e-5)


def test_mlstm_chunkwise_equals_recurrent():
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    B, S, H, dk, dv = 2, 96, 2, 16, 24
    q = jax.random.normal(ks[0], (B, S, H, dk))
    k = jax.random.normal(ks[1], (B, S, H, dk))
    v = jax.random.normal(ks[2], (B, S, H, dv))
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = jax.random.normal(ks[4], (B, S, H)) + 2.0
    st = {"C": jnp.zeros((B, H, dk, dv)), "n": jnp.zeros((B, H, dk)),
          "m": jnp.full((B, H), -1e30)}
    h1, s1 = X.mlstm_chunkwise(q, k, v, ig, fg, st, chunk=16)
    h2, s2 = X.mlstm_recurrent_ref(q, k, v, ig, fg, st)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)
    # continuation across a split must also agree
    ha, sa = X.mlstm_chunkwise(q[:, :48], k[:, :48], v[:, :48], ig[:, :48],
                               fg[:, :48], st, chunk=16)
    hb, _ = X.mlstm_chunkwise(q[:, 48:], k[:, 48:], v[:, 48:], ig[:, 48:],
                              fg[:, 48:], sa, chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([ha, hb], 1)),
                               np.asarray(h2), atol=1e-4)


def test_mamba_chunk_invariance():
    ks = jax.random.split(jax.random.PRNGKey(4), 6)
    B, S, d_in, N = 2, 90, 8, 4
    x = jax.random.normal(ks[0], (B, S, d_in))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, d_in)))
    Aa = -jnp.exp(jax.random.normal(ks[2], (d_in, N)))
    Bc = jax.random.normal(ks[3], (B, S, N))
    Cc = jax.random.normal(ks[4], (B, S, N))
    h0 = jnp.zeros((B, d_in, N))
    y_ref, h_ref = M.selective_scan_ref(x, dt, Aa, Bc, Cc, h0, chunk=90)
    for c in (7, 16, 45):
        y, h = M.selective_scan_ref(x, dt, Aa, Bc, Cc, h0, chunk=c)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)
        np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=1e-5)


def test_gqa_grouping_matches_repeat():
    """GQA via reshape-grouping == explicit kv repetition."""
    q, k, v = _qkv(B=1, S=64, H=8, K=2, hd=16, seed=5)
    pos = jnp.arange(64, dtype=jnp.int32)
    out = A._dense_attn(q, k, v, pos, pos, True, -1, 0.0)
    k_rep = jnp.repeat(k, 4, axis=2)
    v_rep = jnp.repeat(v, 4, axis=2)
    expect = A._dense_attn(q, k_rep, v_rep, pos, pos, True, -1, 0.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)
