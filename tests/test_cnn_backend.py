"""Paper-faithful CNN path: VGG forward, exact Eq.3 signatures, training."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.cnn import VGG16, VGG_TINY, vgg_for
from repro.data import make_benchmark_dataset, split_811
from repro.fl.backend import CNNBackend
from repro.models.cnn import cnn_forward, init_cnn


def test_vgg16_is_papers_backbone():
    assert VGG16.conv_stacks == ((64, 64), (128, 128), (256, 256, 256),
                                 (512, 512, 512), (512, 512, 512))
    assert VGG16.kernel_size == 3          # paper: 3x3 kernels


def test_cnn_forward_shapes():
    cfg = vgg_for("mnist")
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    x = jnp.ones((4, cfg.image_size, cfg.image_size, cfg.in_channels))
    logits, sig = cnn_forward(params, x, cfg, want_signature=True)
    assert logits.shape == (4, cfg.n_classes)
    n_ch = cfg.conv_stacks[cfg.signature_layer // 10][cfg.signature_layer]
    assert sig.shape[-1] == cfg.conv_stacks[0][1]


def test_signature_is_exact_zero_fraction():
    """Eq. 3: ReLU maps have true zeros; signature in [0, 1]."""
    cfg = vgg_for("mnist")
    params = init_cnn(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 16, 16, 1))
    _, sig = cnn_forward(params, x, cfg, want_signature=True)
    sig = np.asarray(sig)
    assert (sig >= 0).all() and (sig <= 1).all()
    assert sig.std() > 0                   # channels differ


def test_signatures_separate_distributions():
    """Clients with different label mixes get different signatures (the
    premise of the paper's similarity filter)."""
    cfg = vgg_for("mnist")
    ds = make_benchmark_dataset("mnist", n_samples=600)
    backend = CNNBackend(cfg, local_epochs=1, batch_size=32)
    params = backend.init(jax.random.PRNGKey(0))
    from repro.data.synthetic import Dataset
    d0 = Dataset(ds.x[ds.y <= 2], ds.y[ds.y <= 2])
    d1 = Dataset(ds.x[ds.y >= 7], ds.y[ds.y >= 7])
    p0, _ = backend.train_local(params, d0, seed=0)
    s_same_a = backend.signature(p0, d0)
    s_same_b = backend.signature(p0, d0)
    s_diff = backend.signature(p0, d1)
    assert np.allclose(s_same_a, s_same_b)
    assert not np.allclose(s_same_a, s_diff, atol=1e-4)


def test_cnn_learns():
    cfg = vgg_for("mnist")
    splits = split_811(make_benchmark_dataset("mnist", n_samples=1200))
    backend = CNNBackend(cfg, local_epochs=3, batch_size=32)
    params = backend.init(jax.random.PRNGKey(0))
    acc0 = backend.evaluate(params, splits["test"])
    params, loss = backend.train_local(params, splits["train"], seed=0)
    acc1 = backend.evaluate(params, splits["test"])
    assert acc1 > acc0 + 0.2, (acc0, acc1)
