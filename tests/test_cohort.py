"""Vectorized cohort engine: equivalence with the sequential path.

The contract under test (ISSUE 1): a vmapped cohort round is numerically
equivalent — per client, within float tolerance — to K sequential
``train_local`` calls with the same seeds, and ragged-shard padding/masking
never leaks into gradients, evaluation or signatures.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.cnn import vgg_for
from repro.core.aggregate import (stacked_mean, stacked_weighted, tree_mean,
                                  tree_stack, tree_unstack, tree_weighted)
from repro.data import make_benchmark_dataset, partition_dirichlet, split_811
from repro.data.synthetic import Dataset
from repro.fl.backend import CNNBackend
from repro.fl.cohort import CohortBackend

# float tolerance between the engine's matmul-form conv and lax.conv:
# identical math, different summation order
ATOL = 5e-3


def _leaves_close(a, b, atol=ATOL):
    return all(np.allclose(x, y, atol=atol) for x, y in
               zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


@pytest.fixture(scope="module")
def world():
    ds = make_benchmark_dataset("mnist", n_samples=900, seed=0)
    splits = split_811(ds)
    backend = CNNBackend(vgg_for("mnist"), local_epochs=2, batch_size=32)
    return backend, splits


def _shards(splits, sizes, seed=0):
    """Deliberately ragged shards (different batch counts per client)."""
    rng = np.random.default_rng(seed)
    train = splits["train"]
    out = []
    for s in sizes:
        idx = rng.choice(len(train), size=s, replace=False)
        out.append(Dataset(train.x[idx], train.y[idx]))
    return out


@settings(max_examples=3, deadline=None)
@given(st.integers(2, 4), st.integers(0, 2 ** 31 - 1))
def test_cohort_train_matches_sequential(n_clients, seed):
    """Same seeds => same per-client weights, sequential vs vmapped."""
    ds = make_benchmark_dataset("mnist", n_samples=600, seed=1)
    splits = split_811(ds)
    rng = np.random.default_rng(seed)
    sizes = [int(rng.integers(40, 200)) for _ in range(n_clients)]
    shards = _shards(splits, sizes, seed % 1000)
    backend = CNNBackend(vgg_for("mnist"), local_epochs=2, batch_size=32)
    cohort = CohortBackend(backend, capacity=n_clients)
    params = [backend.init(jax.random.PRNGKey(seed % 7 + i))
              for i in range(n_clients)]
    seeds = [int(rng.integers(2 ** 31)) for _ in range(n_clients)]

    seq = [backend.train_local(p, d, seed=s)
           for p, d, s in zip(params, shards, seeds)]
    coh_params, coh_losses = cohort.train_cohort(params, shards, seeds)

    for i in range(n_clients):
        assert _leaves_close(seq[i][0], coh_params[i]), f"client {i} diverged"
        assert seq[i][1] == pytest.approx(coh_losses[i], abs=5e-2)


def test_padding_never_leaks_into_gradients(world):
    """A client trained inside a ragged cohort (so its step axis is padded
    against a much larger peer, and the cohort axis itself is padded to
    capacity) must get EXACTLY the weights it gets when trained alone."""
    backend, splits = world
    small, large = _shards(splits, [40, 420], seed=3)
    # capacity 4 with 2 clients: the cohort axis itself gets masked repeats,
    # on top of small's step axis being padded against large's
    cohort = CohortBackend(backend, capacity=4)
    p0 = backend.init(jax.random.PRNGKey(0))
    p1 = backend.init(jax.random.PRNGKey(1))

    solo_small, _ = backend.train_local(p0, small, seed=7)
    solo_large, _ = backend.train_local(p1, large, seed=8)
    coh, _ = cohort.train_cohort([p0, p1], [small, large], [7, 8])

    assert _leaves_close(solo_small, coh[0])
    assert _leaves_close(solo_large, coh[1])
    # and evaluation / signatures ignore padded samples
    accs = cohort.evaluate_cohort(coh, [small, large])
    sigs = cohort.signature_cohort(coh, [small, large])
    assert accs[0] == pytest.approx(backend.evaluate(coh[0], small), abs=1e-5)
    assert accs[1] == pytest.approx(backend.evaluate(coh[1], large), abs=1e-5)
    assert np.allclose(sigs[0], backend.signature(coh[0], small), atol=1e-2)
    assert np.allclose(sigs[1], backend.signature(coh[1], large), atol=1e-2)


def test_evaluate_many_and_shared_match_sequential(world):
    backend, splits = world
    shards = _shards(splits, [60, 90, 120], seed=5)
    cohort = CohortBackend(backend, capacity=4)
    models = [backend.train_local(backend.init(jax.random.PRNGKey(i)),
                                  shards[i % 3], seed=i)[0] for i in range(3)]
    many = cohort.evaluate_many(models, splits["val"])
    for m, model in zip(many, models):
        assert m == pytest.approx(backend.evaluate(model, splits["val"]),
                                  abs=1e-5)
    shared = cohort.evaluate_shared(models[0], shards)
    for a, d in zip(shared, shards):
        assert a == pytest.approx(backend.evaluate(models[0], d), abs=1e-5)


def test_stacked_aggregate_matches_listwise(world):
    backend, _ = world
    models = [backend.init(jax.random.PRNGKey(i)) for i in range(3)]
    stacked = tree_stack(models)

    assert _leaves_close(stacked_mean(stacked), tree_mean(models), atol=1e-6)

    w = np.array([[1.0, 1.0, 0.0], [0.2, 0.3, 0.5]], np.float32)
    per_client = tree_unstack(stacked_weighted(stacked, w))
    assert _leaves_close(per_client[0], tree_mean(models[:2]), atol=1e-6)
    assert _leaves_close(per_client[1],
                         tree_weighted(models, [0.2, 0.3, 0.5]), atol=1e-6)

    # round trip
    for a, b in zip(tree_unstack(stacked), models):
        assert _leaves_close(a, b, atol=0.0)


def test_coordinator_cohort_run_is_consistent(world):
    """End-to-end: the cohort coordinator completes every scheduled round
    (no window may strand a request), keeps publishes on the simulated
    clock, produces a verifiable DAG, and learns.

    Tight sequential-vs-cohort parity (wall-clock AND accuracy) is asserted
    at benchmark geometry by ``benchmarks/chain_perf.py --cohort-size``; at
    this 2-round scale trajectory noise from ~10-sample val shards makes a
    cross-engine accuracy comparison flaky, so the invariants here are
    structural."""
    from repro.core import (DagAflConfig, DagAflCoordinator,
                            TipSelectionConfig, verify_full_dag)
    from repro.core.simulator import CostModel, make_profiles

    backend, splits = world
    parts = partition_dirichlet(splits["train"], 4, beta=0.5, seed=0)
    cd = []
    for p in parts:
        s = split_811(p, seed=1)
        cd.append({"train": s["train"], "val": s["val"], "test": s["test"]})

    cfg = DagAflConfig(n_clients=4, max_rounds=2, local_epochs=1,
                       tip=TipSelectionConfig(n_select=2), seed=0,
                       cohort_size=4, cohort_window=2.0)
    coord = DagAflCoordinator(backend, cd, splits["test"], cfg,
                              CostModel(local_epoch=2.0),
                              make_profiles(4, 0.5, 0))
    res = coord.run()

    ok, reason = verify_full_dag(coord.ledger)
    assert ok, reason
    assert res.extra["cohorts_dispatched"] >= 1
    # tracker cannot stop early here (min_updates=3 > the 2 monitor
    # updates), so every client must complete every scheduled round —
    # a stranded cohort window would show up as missing rounds
    assert res.rounds == cfg.n_clients * cfg.max_rounds
    assert res.sim_time > 0
    # publishes happen at per-round completion times, not batched at flush:
    # transaction timestamps must not collapse onto a handful of instants
    stamps = {round(tx.timestamp, 6) for tx in coord.ledger.transactions()}
    assert len(stamps) > res.extra["cohorts_dispatched"] + 1
    init_acc = backend.evaluate(backend.init(jax.random.PRNGKey(0)),
                                splits["test"])
    assert res.final_accuracy > init_acc + 0.1
