"""LM cohort programs: equivalence with the sequential transformer path.

The contract under test (ISSUE 3 tentpole): ``LMCohortPrograms`` makes the
vectorized cohort engine produce, for ragged transformer cohorts, exactly
the per-client weights / losses / next-token accuracies / Eq. 3 signatures
that K sequential ``LMBackend`` calls produce with the same seeds — and the
shared execution machinery (padding, masking, LRU eval cache, shard_map
mesh) behaves identically to the CNN suite.

Single-device hosts run everything except the mesh-equivalence test, which
CI's multi-device job (``XLA_FLAGS=--xla_force_host_platform_device_count=8``)
exercises for real.
"""
import dataclasses

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced
from repro.data import make_lm_dataset
from repro.fl.backend import LMBackend
from repro.fl.cohort import CohortBackend, LMCohortPrograms, build_cohort_engine
from repro.launch.mesh import make_cohort_mesh

N_DEV = len(jax.devices())
multi_device = pytest.mark.skipif(
    N_DEV < 2, reason="needs >=2 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=N before jax import)")

# the LM suite runs the SAME forward graph in both engines (no conv-lowering
# rewrite like the CNN suite), so the budget is pure float-reduction noise
ATOL = 1e-4


def _leaves_close(a, b, atol=ATOL):
    return all(np.allclose(x, y, atol=atol) for x, y in
               zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


def _world(local_steps=3, batch_size=4, seq_len=16):
    cfg = dataclasses.replace(reduced(get_config("internlm2-1.8b"),
                                      d_model=64), vocab_size=128)
    return LMBackend(cfg, lr=5e-3, local_steps=local_steps,
                     batch_size=batch_size, seq_len=seq_len)


def _streams(n, vocab=128, n_tokens=1200, seed=0):
    return [make_lm_dataset(vocab=vocab, n_tokens=n_tokens, order=2.0,
                            seed=seed + i) for i in range(n)]


@pytest.fixture(scope="module")
def world():
    backend = _world()
    return backend, _streams(3)


@settings(max_examples=3, deadline=None)
@given(st.integers(2, 4), st.integers(0, 2 ** 31 - 1))
def test_lm_cohort_train_matches_sequential(n_clients, seed):
    """Same seeds => same per-client transformer weights, sequential vs
    vmapped — including a cohort axis padded to capacity."""
    backend = _world()
    rng = np.random.default_rng(seed)
    streams = _streams(n_clients, n_tokens=int(rng.integers(800, 2000)),
                       seed=seed % 1000)
    cohort = CohortBackend(backend, capacity=4)
    params = [backend.init(jax.random.PRNGKey(seed % 7 + i))
              for i in range(n_clients)]
    seeds = [int(rng.integers(2 ** 31)) for _ in range(n_clients)]

    seq = [backend.train_local(p, d, seed=s)
           for p, d, s in zip(params, streams, seeds)]
    coh_params, coh_losses = cohort.train_cohort(params, streams, seeds)

    for i in range(n_clients):
        assert _leaves_close(seq[i][0], coh_params[i]), f"client {i} diverged"
        assert seq[i][1] == pytest.approx(coh_losses[i], abs=1e-3)


def test_lm_eval_signature_shared_many_match_sequential(world):
    backend, streams = world
    cohort = CohortBackend(backend, capacity=4)
    models = [backend.train_local(backend.init(jax.random.PRNGKey(i)),
                                  streams[i], seed=i)[0] for i in range(3)]

    accs = cohort.evaluate_cohort(models, streams)
    for a, (m, d) in zip(accs, zip(models, streams)):
        assert a == pytest.approx(backend.evaluate(m, d), abs=1e-5)

    sigs = cohort.signature_cohort(models, streams)
    for s, (m, d) in zip(sigs, zip(models, streams)):
        assert np.allclose(s, backend.signature(m, d), atol=1e-5)

    shared = cohort.evaluate_shared(models[0], streams)
    for a, d in zip(shared, streams):
        assert a == pytest.approx(backend.evaluate(models[0], d), abs=1e-5)

    # 4 models: strictly above eval_many_min_batch (3), so this exercises
    # the vmapped pow2-padded _eval_many_impl branch, not the fast path
    four = models + [backend.init(jax.random.PRNGKey(9))]
    assert len(four) > cohort.programs.eval_many_min_batch
    many = cohort.evaluate_many(four, streams[0])
    for a, m in zip(many, four):
        assert a == pytest.approx(backend.evaluate(m, streams[0]), abs=1e-5)
    # M <= min_batch goes through the sequential program
    assert cohort.evaluate_many(models[:1], streams[0])[0] == pytest.approx(
        backend.evaluate(models[0], streams[0]), abs=1e-6)


def test_eval_cache_eviction_does_not_change_results(world):
    """The LRU bound on the eval-data cache is an execution detail: a
    1-entry cache (every call evicts) must score identically to the
    default, and the cache must actually stay bounded."""
    backend, streams = world
    model = backend.init(jax.random.PRNGKey(3))
    roomy = CohortBackend(backend, capacity=4)
    tiny = CohortBackend(backend, capacity=4, eval_cache_entries=1)
    for _ in range(2):                     # second pass hits/evicts
        a = roomy.evaluate_shared(model, streams)
        b = tiny.evaluate_shared(model, streams)
        assert np.allclose(a, b, atol=0.0)
    # the bound clamps to the widest call so a sweep can't evict its own
    # entries mid-loop; a narrower follow-up call shrinks it back down
    assert len(tiny._eval_data_cache) <= max(1, len(streams))
    tiny.evaluate_shared(model, streams[:1])
    assert len(tiny._eval_data_cache) == 1
    assert len(roomy._eval_data_cache) <= roomy.eval_cache_entries


def test_build_cohort_engine_is_backend_agnostic(world):
    backend, streams = world
    assert CohortBackend.supports(backend)
    eng = build_cohort_engine(backend, streams, cohort_size=4, mesh=None)
    assert isinstance(eng.programs, LMCohortPrograms)
    assert eng._pad_T == backend.local_steps     # shards pre-registered
    assert build_cohort_engine(backend, streams, cohort_size=1) is None
    assert build_cohort_engine(object(), streams, cohort_size=4) is None


def test_lm_coordinator_cohort_run_short_rounds_clamp(world):
    """End-to-end LM cohort run where every round is SHORTER than the
    cohort window: publishes whose completion times precede the flush are
    clamped to the flush time (EventLoop.clamped counts them), every
    scheduled round still completes, and the DAG audits clean."""
    from repro.core import (DagAflConfig, DagAflCoordinator,
                            TipSelectionConfig, verify_full_dag)
    from repro.core.simulator import CostModel, make_profiles

    backend, streams = world
    cd = [{"train": s, "val": s, "test": s} for s in streams]
    gt = make_lm_dataset(vocab=backend.cfg.vocab_size, n_tokens=1200, seed=9)
    # rounds cost ~0.03 simulated seconds; the window flushes after 10 —
    # every batched publish lands before its window closes
    cfg = DagAflConfig(n_clients=3, max_rounds=2, local_epochs=2,
                       tip=TipSelectionConfig(n_select=2), seed=0,
                       cohort_size=3, cohort_window=10.0, mesh=None)
    coord = DagAflCoordinator(backend, cd, gt, cfg,
                              CostModel(local_epoch=0.01, eval_batch=0.001,
                                        signature=0.001, chain_op=0.0001),
                              make_profiles(3, 0.2, 0))
    res = coord.run()
    ok, reason = verify_full_dag(coord.ledger)
    assert ok, reason
    assert res.rounds == cfg.n_clients * cfg.max_rounds
    assert res.extra["cohorts_dispatched"] >= 1
    assert coord.loop.clamped > 0          # short rounds hit the clamp
    # simulated time stayed monotone through the clamped publishes
    stamps = [tx.timestamp for tx in coord.ledger.transactions()]
    assert all(t >= 0.0 for t in stamps)


# -- mesh-sharded LM cohort (runs for real in CI's multi-device job) ---------


@multi_device
def test_lm_sharded_cohort_matches_single_device():
    """Ragged LM cohorts on a clients mesh: shard_map must reproduce the
    single-device vmap engine's weights, accuracies and signatures."""
    backend = _world()
    n_clients = 3                           # not divisible by a 2/4-mesh
    streams = _streams(n_clients, seed=7)
    mesh = make_cohort_mesh(min(N_DEV, 4))
    single = CohortBackend(backend, capacity=n_clients)
    sharded = CohortBackend(backend, capacity=n_clients, mesh=mesh)
    rng = np.random.default_rng(0)
    params = [backend.init(jax.random.PRNGKey(i)) for i in range(n_clients)]
    seeds = [int(rng.integers(2 ** 31)) for _ in range(n_clients)]

    p1, l1 = single.train_cohort(params, streams, seeds)
    p2, l2 = sharded.train_cohort(params, streams, seeds)
    for i in range(n_clients):
        assert _leaves_close(p1[i], p2[i]), f"client {i} diverged"
        assert l1[i] == pytest.approx(l2[i], abs=1e-3)

    assert np.allclose(single.evaluate_cohort(p1, streams),
                       sharded.evaluate_cohort(p2, streams), atol=1e-4)
    assert np.allclose(single.signature_cohort(p1, streams),
                       sharded.signature_cohort(p2, streams), atol=1e-4)
    assert np.allclose(single.evaluate_shared(p1[0], streams),
                       sharded.evaluate_shared(p2[0], streams), atol=1e-4)
    assert np.allclose(single.evaluate_many(p1, streams[0]),
                       sharded.evaluate_many(p2, streams[0]), atol=1e-4)
