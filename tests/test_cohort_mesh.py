"""Mesh-sharded SPMD cohort engine: equivalence with the single-device path.

The contract under test (ISSUE 2): sharding the stacked K-client pytree over
a ``clients`` device mesh (``shard_map`` per-device client groups, psum
aggregation collectives) is numerically equivalent to the single-device
``vmap`` engine for ragged cohorts — including K not divisible by the mesh —
and degrades to the EXACT single-device path on a 1-device host.

Single-device hosts run the degradation/clamping tests and skip the rest;
CI's multi-device job (``XLA_FLAGS=--xla_force_host_platform_device_count=8``)
runs everything.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.cnn import vgg_for
from repro.core.aggregate import (stacked_mean, stacked_weighted, tree_mean,
                                  tree_stack, tree_unstack, tree_weighted)
from repro.data import make_benchmark_dataset, split_811
from repro.data.synthetic import Dataset
from repro.fl.backend import CNNBackend
from repro.fl.cohort import CohortBackend
from repro.launch.mesh import make_cohort_mesh

N_DEV = len(jax.devices())
multi_device = pytest.mark.skipif(
    N_DEV < 2, reason="needs >=2 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=N before jax import)")

# matmul-form vs conv-form float tolerance (same as test_cohort.py); the
# sharded path runs the SAME per-client programs, so it gets the same budget
ATOL = 5e-3


def _leaves_close(a, b, atol=ATOL):
    return all(np.allclose(x, y, atol=atol) for x, y in
               zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


@pytest.fixture(scope="module")
def world():
    ds = make_benchmark_dataset("mnist", n_samples=700, seed=2)
    splits = split_811(ds)
    backend = CNNBackend(vgg_for("mnist"), local_epochs=1, batch_size=32)
    return backend, splits


def _shards(splits, sizes, seed=0):
    rng = np.random.default_rng(seed)
    train = splits["train"]
    out = []
    for s in sizes:
        idx = rng.choice(len(train), size=s, replace=False)
        out.append(Dataset(train.x[idx], train.y[idx]))
    return out


# -- mesh construction / degradation (run everywhere) ------------------------


def test_make_cohort_mesh_clamps_to_available_devices():
    mesh = make_cohort_mesh(10_000)
    assert dict(mesh.shape)["clients"] == min(10_000, N_DEV)
    assert make_cohort_mesh(1).axis_names == ("clients",)
    assert dict(make_cohort_mesh(0).shape)["clients"] == 1  # floor at 1


def test_one_device_mesh_degrades_to_single_device_engine(world):
    backend, _ = world
    engine = CohortBackend(backend, capacity=4, mesh=make_cohort_mesh(1))
    assert engine.mesh is None          # exact single-device programs
    assert engine._n_shards == 1


def test_mesh_without_clients_axis_rejected(world):
    backend, _ = world
    from jax.sharding import Mesh
    bad = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    with pytest.raises(ValueError, match="clients"):
        CohortBackend(backend, capacity=4, mesh=bad)


def test_make_host_mesh_degrades_when_not_strict():
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(data=N_DEV + 7, model=1, strict=False)
    assert dict(mesh.shape)["data"] == N_DEV
    # an oversized MODEL axis must degrade too, not raise
    mesh = make_host_mesh(data=1, model=N_DEV + 7, strict=False)
    assert dict(mesh.shape)["model"] == N_DEV
    with pytest.raises(RuntimeError):
        make_host_mesh(data=N_DEV + 7, model=1)


def test_stacked_client_shardings_specs(world):
    from jax.sharding import PartitionSpec as P
    from repro.sharding.rules import stacked_client_shardings
    backend, _ = world
    mesh = make_cohort_mesh(max(N_DEV, 1))
    stacked = tree_stack([backend.init(jax.random.PRNGKey(i))
                          for i in range(2)])
    sh = stacked_client_shardings(stacked, mesh)
    for s in jax.tree_util.tree_leaves(sh):
        assert s.spec == P("clients")
    with pytest.raises(ValueError):
        stacked_client_shardings(stacked, mesh, axis="nope")


# -- psum aggregation collectives (property: any K/M vs the mesh) ------------


@multi_device
@settings(max_examples=4, deadline=None)
@given(st.integers(2, 7), st.integers(0, 2 ** 31 - 1))
def test_stacked_aggregation_collectives_match_single_device(m, seed):
    """stacked_mean / stacked_weighted over a sharded model axis must equal
    the listwise programs for ANY stack size, divisible by the mesh or not."""
    backend = CNNBackend(vgg_for("mnist"), local_epochs=1, batch_size=32)
    mesh = make_cohort_mesh(min(N_DEV, 4))
    rng = np.random.default_rng(seed)
    models = [backend.init(jax.random.PRNGKey(int(rng.integers(1 << 30))))
              for _ in range(m)]
    stacked = tree_stack(models)

    assert _leaves_close(stacked_mean(stacked, mesh=mesh),
                         tree_mean(models), atol=1e-6)

    w = rng.random((2, m)).astype(np.float32) + 0.01
    per_client = tree_unstack(stacked_weighted(stacked, w, mesh=mesh))
    for k in range(2):
        assert _leaves_close(per_client[k],
                             tree_weighted(models, list(w[k])), atol=1e-6)
    flat = stacked_weighted(stacked, list(w[0]), mesh=mesh)
    assert _leaves_close(flat, tree_weighted(models, list(w[0])), atol=1e-6)


# -- sharded train/eval/signature equivalence (the tentpole contract) --------


@multi_device
@settings(max_examples=2, deadline=None)
@given(st.integers(2, 5), st.integers(0, 2 ** 31 - 1))
def test_sharded_cohort_matches_single_device(n_clients, seed):
    """Ragged cohorts (K possibly not divisible by the mesh): the shard_map
    engine must produce the same per-client weights, accuracies and
    signatures as the single-device vmap engine."""
    ds = make_benchmark_dataset("mnist", n_samples=500, seed=3)
    splits = split_811(ds)
    rng = np.random.default_rng(seed)
    sizes = [int(rng.integers(40, 140)) for _ in range(n_clients)]
    shards = _shards(splits, sizes, seed % 1000)
    backend = CNNBackend(vgg_for("mnist"), local_epochs=1, batch_size=32)
    mesh = make_cohort_mesh(min(N_DEV, 4))
    single = CohortBackend(backend, capacity=n_clients)
    sharded = CohortBackend(backend, capacity=n_clients, mesh=mesh)
    params = [backend.init(jax.random.PRNGKey(seed % 5 + i))
              for i in range(n_clients)]
    seeds = [int(rng.integers(2 ** 31)) for _ in range(n_clients)]

    p1, l1 = single.train_cohort(params, shards, seeds)
    p2, l2 = sharded.train_cohort(params, shards, seeds)
    for i in range(n_clients):
        assert _leaves_close(p1[i], p2[i]), f"client {i} diverged"
        assert l1[i] == pytest.approx(l2[i], abs=5e-2)

    assert np.allclose(single.evaluate_cohort(p1, shards),
                       sharded.evaluate_cohort(p2, shards), atol=1e-4)
    assert np.allclose(single.signature_cohort(p1, shards),
                       sharded.signature_cohort(p2, shards), atol=1e-2)
    assert np.allclose(single.evaluate_shared(p1[0], shards),
                       sharded.evaluate_shared(p2[0], shards), atol=1e-4)
    assert np.allclose(single.evaluate_many(p1, shards[0]),
                       sharded.evaluate_many(p2, shards[0]), atol=1e-4)


@multi_device
def test_coordinator_auto_mesh_runs_spmd(world):
    """End-to-end: the default (mesh="auto") coordinator on a multi-device
    host takes the shard_map path, completes every round, and matches the
    explicitly single-device run's final accuracy."""
    from repro.core import (DagAflConfig, DagAflCoordinator,
                            TipSelectionConfig, verify_full_dag)
    from repro.core.simulator import CostModel, make_profiles

    backend, splits = world
    from repro.data import partition_dirichlet
    parts = partition_dirichlet(splits["train"], 4, beta=0.5, seed=0)
    cd = []
    for p in parts:
        s = split_811(p, seed=1)
        cd.append({"train": s["train"], "val": s["val"], "test": s["test"]})

    accs = {}
    for mesh in ("auto", None):
        cfg = DagAflConfig(n_clients=4, max_rounds=2, local_epochs=1,
                           tip=TipSelectionConfig(n_select=2), seed=0,
                           cohort_size=4, cohort_window=2.0, mesh=mesh)
        coord = DagAflCoordinator(backend, cd, splits["test"], cfg,
                                  CostModel(local_epoch=2.0),
                                  make_profiles(4, 0.5, 0))
        if mesh == "auto":
            assert coord.cohort.mesh is not None      # SPMD path engaged
            assert coord.cohort._n_shards == min(N_DEV, 4)
        res = coord.run()
        ok, reason = verify_full_dag(coord.ledger)
        assert ok, reason
        assert res.rounds == cfg.n_clients * cfg.max_rounds
        accs[mesh] = res.final_accuracy
    # tolerance = one argmax flip on this world's ~70-sample test set
    # (1/70 ~= 0.0143): the sharded path reorders float reductions, so a
    # single borderline prediction may legitimately flip
    assert abs(accs["auto"] - accs[None]) <= 0.02
