"""2-D (clients, data) SPMD cohort engine: equivalence with the 1-D
clients mesh and the single-device vmap path (ISSUE 4 tentpole contract).

Sharding each client group's batch/sample axes over a ``data`` mesh axis
(sum-form losses/metrics, psum'd per group) must not change numerics — for
ragged cohorts, for batch sizes NOT divisible by the data-axis size (pad +
``bm`` masking), and end-to-end through the coordinator.  Single-device
hosts run the construction/degradation tests and skip the rest; CI's
multi-device job (``XLA_FLAGS=--xla_force_host_platform_device_count=8``)
runs everything on both 8x1 and 4x2 meshes.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.cnn import vgg_for
from repro.core.aggregate import (stacked_mean, stacked_weighted, tree_mean,
                                  tree_stack, tree_unstack, tree_weighted)
from repro.data import make_benchmark_dataset, split_811
from repro.data.synthetic import Dataset
from repro.fl.backend import CNNBackend
from repro.fl.cohort import CohortBackend, resolve_cohort_mesh
from repro.launch.mesh import make_cohort_mesh

N_DEV = len(jax.devices())
multi_device = pytest.mark.skipif(
    N_DEV < 4, reason="needs >=4 devices for a 2-D mesh "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=N before jax import)")

ATOL = 5e-3           # same matmul-vs-conv budget as test_cohort_mesh.py


def _leaves_close(a, b, atol=ATOL):
    return all(np.allclose(x, y, atol=atol) for x, y in
               zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


def _shards(splits, sizes, seed=0):
    rng = np.random.default_rng(seed)
    train = splits["train"]
    out = []
    for s in sizes:
        idx = rng.choice(len(train), size=s, replace=False)
        out.append(Dataset(train.x[idx], train.y[idx]))
    return out


# -- construction / degradation (run everywhere) -----------------------------


def test_make_cohort_mesh_2d_shapes_and_clamping():
    mesh = make_cohort_mesh(4, data=2)
    if N_DEV >= 8:
        assert dict(mesh.shape) == {"clients": 4, "data": 2}
        assert mesh.axis_names == ("clients", "data")
    elif N_DEV == 1:
        # data shrinks to the host first, then clients: 1-D single device
        assert mesh.axis_names == ("clients",)
        assert dict(mesh.shape)["clients"] == 1
    # data axis larger than the host clamps instead of raising
    mesh = make_cohort_mesh(2, data=10_000)
    assert int(np.prod(list(dict(mesh.shape).values()))) <= N_DEV
    # data=1 keeps the exact 1-D back-compat mesh
    assert make_cohort_mesh(3, data=1).axis_names == ("clients",)


def test_resolve_cohort_mesh_specs():
    m = resolve_cohort_mesh("4x2", cohort_size=8)
    assert "clients" in m.shape
    m_auto = resolve_cohort_mesh(("auto", 2), cohort_size=8)
    assert "clients" in m_auto.shape
    m_tuple = resolve_cohort_mesh((2, 2), cohort_size=8)
    assert "clients" in m_tuple.shape
    assert resolve_cohort_mesh(None, cohort_size=8) is None
    mesh = make_cohort_mesh(2)
    assert resolve_cohort_mesh(mesh, cohort_size=8) is mesh
    with pytest.raises(ValueError):
        resolve_cohort_mesh("bogus", cohort_size=8)
    with pytest.raises(ValueError):
        resolve_cohort_mesh("4x2x1", cohort_size=8)
    with pytest.raises(ValueError):
        resolve_cohort_mesh((4, 2, 1), cohort_size=8)
    with pytest.raises(TypeError):
        resolve_cohort_mesh(4, cohort_size=8)


def test_cohort_pspecs_with_data_axis():
    from jax.sharding import PartitionSpec as P
    from repro.sharding.rules import (cohort_batch_sharding, cohort_pspec,
                                      data_shard_sharding,
                                      stacked_client_shardings)
    assert cohort_pspec() == P("clients")
    assert cohort_pspec("clients", "data", 2) == P("clients", None, "data")
    assert cohort_pspec("clients", "data", 1) == P("clients", "data")
    with pytest.raises(ValueError):
        cohort_pspec("clients", "data", 0)

    mesh = make_cohort_mesh(max(N_DEV // 2, 1), data=min(N_DEV, 2))
    if "data" in mesh.shape:
        sh = cohort_batch_sharding(mesh, "clients", "data", 2)
        assert sh.spec == P("clients", None, "data")
        assert data_shard_sharding(mesh, "data").spec == P("data")
        backend = CNNBackend(vgg_for("mnist"), local_epochs=1, batch_size=8)
        stacked = tree_stack([backend.init(jax.random.PRNGKey(i))
                              for i in range(2)])
        # params stay replicated within a client group: no data axis
        for s in jax.tree_util.tree_leaves(
                stacked_client_shardings(stacked, mesh, data_axis="data")):
            assert s.spec == P("clients")
        with pytest.raises(ValueError):
            cohort_batch_sharding(mesh, "clients", "nope", 2)


def test_one_by_one_mesh_degrades_to_single_device_engine():
    backend = CNNBackend(vgg_for("mnist"), local_epochs=1, batch_size=8)
    engine = CohortBackend(backend, capacity=4,
                           mesh=make_cohort_mesh(1, data=1))
    assert engine.mesh is None
    assert engine._n_shards == 1 and engine._n_data == 1


# -- 2-D equivalence properties (the tentpole contract) ----------------------


@pytest.fixture(scope="module")
def world():
    ds = make_benchmark_dataset("mnist", n_samples=600, seed=4)
    splits = split_811(ds)
    return splits


@multi_device
@settings(max_examples=2, deadline=None)
@given(st.integers(2, 5), st.integers(0, 2 ** 31 - 1))
def test_2d_mesh_matches_1d_and_single_device(n_clients, seed):
    """Ragged cohorts (K not divisible by the mesh) with an ODD batch size
    (not divisible by the data axis, so every step pads + bm-masks batch
    rows): the 2-D engine must match both the 1-D clients mesh and the
    single-device vmap engine on weights, losses, accuracies, signatures,
    shared-model eval and tip sweeps."""
    ds = make_benchmark_dataset("mnist", n_samples=500, seed=3)
    splits = split_811(ds)
    rng = np.random.default_rng(seed)
    sizes = [int(rng.integers(40, 120)) for _ in range(n_clients)]
    shards = _shards(splits, sizes, seed % 1000)
    # batch_size=9 is NOT divisible by the data axis (2): exercises the
    # pad+bm-mask path on every training step
    backend = CNNBackend(vgg_for("mnist"), local_epochs=1, batch_size=9)
    mesh_1d = make_cohort_mesh(min(N_DEV, 4))
    mesh_2d = make_cohort_mesh(min(N_DEV // 2, 4), data=2)
    assert "data" in mesh_2d.shape

    single = CohortBackend(backend, capacity=n_clients)
    one_d = CohortBackend(backend, capacity=n_clients, mesh=mesh_1d)
    two_d = CohortBackend(backend, capacity=n_clients, mesh=mesh_2d)
    assert two_d._n_data == 2

    params = [backend.init(jax.random.PRNGKey(seed % 5 + i))
              for i in range(n_clients)]
    seeds = [int(rng.integers(2 ** 31)) for _ in range(n_clients)]

    p0, l0 = single.train_cohort(params, shards, seeds)
    p1, l1 = one_d.train_cohort(params, shards, seeds)
    p2, l2 = two_d.train_cohort(params, shards, seeds)
    for i in range(n_clients):
        assert _leaves_close(p0[i], p2[i]), f"client {i}: 2-D != single"
        assert _leaves_close(p1[i], p2[i]), f"client {i}: 2-D != 1-D"
        assert l0[i] == pytest.approx(l2[i], abs=5e-2)

    # eval-family programs compared on IDENTICAL weights (p0): comparing
    # each engine's own trained weights would let a legitimate 5e-3 weight
    # difference flip a borderline argmax and fail the tight accuracy atol
    assert np.allclose(single.evaluate_cohort(p0, shards),
                       two_d.evaluate_cohort(p0, shards), atol=1e-4)
    assert np.allclose(single.signature_cohort(p0, shards),
                       two_d.signature_cohort(p0, shards), atol=1e-2)
    assert np.allclose(single.evaluate_shared(p0[0], shards),
                       two_d.evaluate_shared(p0[0], shards), atol=1e-4)
    assert np.allclose(single.evaluate_many(p0, shards[0]),
                       two_d.evaluate_many(p0, shards[0]), atol=1e-4)


@multi_device
def test_2d_aggregation_collectives_match_listwise():
    backend = CNNBackend(vgg_for("mnist"), local_epochs=1, batch_size=8)
    mesh = make_cohort_mesh(min(N_DEV // 2, 4), data=2)
    assert "data" in mesh.shape
    rng = np.random.default_rng(0)
    models = [backend.init(jax.random.PRNGKey(i)) for i in range(5)]
    stacked = tree_stack(models)
    assert _leaves_close(
        stacked_mean(stacked, mesh=mesh, data_axis="data"),
        tree_mean(models), atol=1e-6)
    w = rng.random((3, 5)).astype(np.float32) + 0.01
    per_client = tree_unstack(
        stacked_weighted(stacked, w, mesh=mesh, data_axis="data"))
    for k in range(3):
        assert _leaves_close(per_client[k],
                             tree_weighted(models, list(w[k])), atol=1e-6)


@multi_device
def test_coordinator_2d_mesh_end_to_end(world):
    """mesh="CxD" through DagAflConfig: the 2-D run completes every round,
    the DAG verifies, and accuracy matches the single-device run."""
    from repro.core import (DagAflConfig, DagAflCoordinator,
                            TipSelectionConfig, verify_full_dag)
    from repro.core.simulator import CostModel, make_profiles
    from repro.data import partition_dirichlet

    splits = world
    backend = CNNBackend(vgg_for("mnist"), local_epochs=1, batch_size=9)
    parts = partition_dirichlet(splits["train"], 4, beta=0.5, seed=0)
    cd = []
    for p in parts:
        s = split_811(p, seed=1)
        cd.append({"train": s["train"], "val": s["val"], "test": s["test"]})

    accs = {}
    for mesh in (f"{min(N_DEV // 2, 4)}x2", None):
        cfg = DagAflConfig(n_clients=4, max_rounds=2, local_epochs=1,
                           tip=TipSelectionConfig(n_select=2), seed=0,
                           cohort_size=4, cohort_window=2.0, mesh=mesh)
        coord = DagAflCoordinator(backend, cd, splits["test"], cfg,
                                  CostModel(local_epoch=2.0),
                                  make_profiles(4, 0.5, 0))
        if mesh is not None:
            assert coord.cohort.mesh is not None
            assert coord.cohort._n_data == 2       # 2-D path engaged
        res = coord.run()
        ok, reason = verify_full_dag(coord.ledger)
        assert ok, reason
        assert res.rounds == cfg.n_clients * cfg.max_rounds
        accs[mesh] = res.final_accuracy
    vals = list(accs.values())
    # one borderline argmax flip on the ~60-sample test set is legitimate
    # reduction-reorder noise; more indicates a numerics break
    assert abs(vals[0] - vals[1]) <= 0.04
