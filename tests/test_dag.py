"""DAG ledger: structure, tips, reachability (paper Alg. 1)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.dag import DAGLedger, ModelStore, TxMetadata


def meta(cid=0, epoch=0):
    return TxMetadata(client_id=cid, signature=(0.1, 0.2),
                      model_accuracy=0.5, current_epoch=epoch,
                      validation_node_id=cid)


def build_ledger():
    led = DAGLedger()
    led.add_genesis(meta(-1))
    return led


def test_genesis_is_tip():
    led = build_ledger()
    assert led.tips() == [led.genesis_id]


def test_approval_consumes_tips():
    led = build_ledger()
    g = led.genesis_id
    t1 = led.add_transaction(meta(0, 1), [g], 1.0)
    assert led.tips() == [t1.tx_id]
    t2 = led.add_transaction(meta(1, 1), [g], 1.5)   # g already approved: ok
    assert set(led.tips()) == {t1.tx_id, t2.tx_id}
    t3 = led.add_transaction(meta(2, 2), [t1.tx_id, t2.tx_id], 2.0)
    assert led.tips() == [t3.tx_id]


def test_unknown_parent_rejected():
    led = build_ledger()
    with pytest.raises(KeyError):
        led.add_transaction(meta(), ["nope"], 1.0)


def test_latest_of_client():
    led = build_ledger()
    g = led.genesis_id
    a = led.add_transaction(meta(0, 1), [g], 1.0)
    b = led.add_transaction(meta(0, 2), [a.tx_id], 2.0)
    led.add_transaction(meta(1, 1), [g], 1.5)
    assert led.latest_of(0) == b.tx_id
    assert led.latest_of(99) is None


def test_latest_of_tie_breaking_keeps_insertion_order():
    """Equal timestamps: the LATEST-inserted transaction wins (regression
    for the O(1) per-client index — the old full scan iterated the
    insertion-ordered node dict with a >= comparison)."""
    led = build_ledger()
    g = led.genesis_id
    first = led.add_transaction(meta(0, 1), [g], 5.0)
    second = led.add_transaction(meta(0, 2), [g], 5.0)   # same timestamp
    assert led.latest_of(0) == second.tx_id
    # an EARLIER timestamp never displaces the index
    led.add_transaction(meta(0, 3), [g], 1.0)
    assert led.latest_of(0) == second.tx_id


def _scan_latest_of(led, client_id):
    """The pre-index O(ledger) reference implementation."""
    best, best_t = None, -1.0
    for tx in led.transactions():
        if tx.metadata.client_id == client_id and tx.timestamp >= best_t:
            best, best_t = tx.tx_id, tx.timestamp
    return best


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 4), st.integers(0, 50)),
                min_size=1, max_size=40))
def test_latest_of_index_matches_full_scan(ops):
    """Property: the per-client index agrees with the full scan for any
    append order, including repeated and out-of-order timestamps."""
    led = build_ledger()
    for cid, ts in ops:
        led.add_transaction(meta(cid, 1), [led.genesis_id], float(ts) / 7.0)
    for cid in range(5):
        assert led.latest_of(cid) == _scan_latest_of(led, cid)
    assert led.latest_of(-1) == led.genesis_id


def test_reachability_split():
    """Tips descending from the client's node are reachable, others not."""
    led = build_ledger()
    g = led.genesis_id
    mine = led.add_transaction(meta(0, 1), [g], 1.0)           # client 0
    other = led.add_transaction(meta(1, 1), [g], 1.1)          # client 1
    child = led.add_transaction(meta(2, 2), [mine.tx_id], 2.0)  # approves mine
    lone = led.add_transaction(meta(3, 2), [other.tx_id], 2.1)
    reach, unreach = led.reachable_tips(mine.tx_id)
    assert reach == [child.tx_id]
    assert unreach == [lone.tx_id]


def test_reachability_no_start():
    led = build_ledger()
    g = led.genesis_id
    led.add_transaction(meta(0, 1), [g], 1.0)
    reach, unreach = led.reachable_tips(None)
    assert reach == [] and len(unreach) == 1


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 9), st.integers(0, 1)),
                min_size=1, max_size=40))
def test_reachable_plus_unreachable_is_all_tips(ops):
    """Property: Alg. 1 partitions the tip set, for any random DAG."""
    led = build_ledger()
    rng = np.random.default_rng(0)
    for cid, n_parents_extra in ops:
        tips = led.tips()
        k = min(len(tips), 1 + n_parents_extra)
        parents = list(rng.choice(tips, size=k, replace=False))
        led.add_transaction(meta(cid, 1), parents, float(len(led)))
    for cid in range(10):
        start = led.latest_of(cid)
        reach, unreach = led.reachable_tips(start)
        assert sorted(reach + unreach) == led.tips()
        assert not (set(reach) & set(unreach))


def test_dag_is_acyclic_by_construction():
    """Parents must exist before children: timestamps strictly ordered back."""
    led = build_ledger()
    g = led.genesis_id
    a = led.add_transaction(meta(0, 1), [g], 1.0)
    b = led.add_transaction(meta(1, 2), [a.tx_id], 2.0)
    for anc in led.ancestors(b.tx_id):
        assert led.get_tx(anc).timestamp < led.get_tx(b.tx_id).timestamp


def test_model_store_tracks_bytes():
    import jax.numpy as jnp
    store = ModelStore()
    store.put("a", {"w": jnp.ones((4, 4), jnp.float32)})
    assert "a" in store
    store.get("a")
    assert store.bytes_transferred == 64
