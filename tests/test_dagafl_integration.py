"""End-to-end DAG-AFL behaviour on the simulator (paper workflow §III-A)."""
import jax
import numpy as np
import pytest

from repro.configs.cnn import vgg_for
from repro.core import (DagAflConfig, DagAflCoordinator, TipSelectionConfig,
                        verify_full_dag)
from repro.core.simulator import CostModel, make_profiles
from repro.data import make_benchmark_dataset, partition_dirichlet, split_811
from repro.fl.backend import CNNBackend


@pytest.fixture(scope="module")
def setup():
    ds = make_benchmark_dataset("mnist", n_samples=1200, seed=0)
    splits = split_811(ds)
    parts = partition_dirichlet(splits["train"], 3, beta=0.5, seed=0)
    client_data = []
    for p in parts:
        s = split_811(p, seed=1)
        client_data.append({"train": s["train"], "val": s["val"],
                            "test": s["test"]})
    backend = CNNBackend(vgg_for("mnist"), local_epochs=1, batch_size=32)
    return backend, client_data, splits["test"]


def run(setup, **kw):
    backend, client_data, test = setup
    cfg = DagAflConfig(n_clients=3, max_rounds=kw.pop("max_rounds", 3),
                       local_epochs=1,
                       tip=kw.pop("tip", TipSelectionConfig(n_select=2)),
                       seed=0, **kw)
    coord = DagAflCoordinator(backend, client_data, test, cfg,
                              CostModel(local_epoch=2.0),
                              make_profiles(3, 0.5, 0))
    return coord, coord.run()


def test_dagafl_improves_over_init(setup):
    backend, client_data, test = setup
    init_acc = backend.evaluate(backend.init(jax.random.PRNGKey(0)), test)
    _, res = run(setup)
    assert res.final_accuracy > init_acc + 0.2
    assert res.sim_time > 0
    assert res.extra["verify_failures"] == 0


def test_dag_grows_and_verifies(setup):
    coord, res = run(setup)
    assert res.extra["chain_len"] >= 4            # genesis + rounds
    ok, reason = verify_full_dag(coord.ledger)
    assert ok, reason
    # metadata-only on chain: every tx's signature is a short tuple
    for tx in coord.ledger.transactions():
        assert len(tx.metadata.signature) <= 16


def test_similarity_filter_saves_evaluations(setup):
    _, res_filtered = run(setup, tip=TipSelectionConfig(
        n_select=2, p_similar=1))
    _, res_all = run(setup, tip=TipSelectionConfig(
        n_select=2, use_similarity=False, p_similar=99))
    assert res_filtered.extra["tip_evaluations"] <= \
        res_all.extra["tip_evaluations"]


def test_async_clients_progress_independently(setup):
    coord, res = run(setup, max_rounds=2)
    rounds = coord._client_rounds
    assert sum(rounds) == res.rounds
    assert max(rounds) >= 1


def test_bounded_ledger_matches_unbounded_run(setup):
    """Checkpoint+prune mid-run must not change the training trajectory:
    same rounds, same simulated time, same final accuracy (verify_paths
    off — stored paths are legitimately shorter on a pruned ledger, which
    would shift only the simulated audit-cost term)."""
    coord_u, res_u = run(setup, max_rounds=2, verify_paths=False)
    coord_b, res_b = run(setup, max_rounds=2, verify_paths=False,
                         ledger_checkpoint_every=5.0)
    assert res_b.rounds == res_u.rounds
    assert res_b.sim_time == pytest.approx(res_u.sim_time)
    assert res_b.final_accuracy == pytest.approx(res_u.final_accuracy)
    # the bounded run really pruned: checkpoints fired, bodies + models gone
    assert coord_b.ledger.checkpoints
    assert coord_b.ledger.n_pruned > 0
    assert len(coord_b.ledger) < len(coord_u.ledger)
    # pruned-while-latest refs are deferred (the final sweep needs them),
    # so a tiny run may keep every model; it must never keep MORE
    assert len(coord_b.store) <= len(coord_u.store)
    ok, reason = verify_full_dag(coord_b.ledger)
    assert ok, reason
