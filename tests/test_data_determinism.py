"""Dataset generation must not depend on the interpreter's hash salt.

``make_image_dataset`` used to fold builtin ``hash(name)`` into the RNG
seed, so the "same" dataset differed between processes whenever
``PYTHONHASHSEED`` differed (which it does by default).  The fix derives
the per-dataset salt from ``zlib.crc32`` instead.  This regression test
generates data in two subprocesses pinned to different hash seeds and
asserts bit-identical output.
"""
import hashlib
import os
import subprocess
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent

_CHILD = r"""
import hashlib
import numpy as np
from repro.data.synthetic import make_benchmark_dataset, make_lm_dataset

h = hashlib.sha256()
for name in ("mnist", "cifar10"):
    ds = make_benchmark_dataset(name, n_samples=128, seed=7)
    h.update(np.ascontiguousarray(ds.x).tobytes())
    h.update(np.ascontiguousarray(ds.y).tobytes())
toks = make_lm_dataset(vocab=64, n_tokens=2000, seed=7)
h.update(np.ascontiguousarray(toks).tobytes())
print(h.hexdigest())
"""


def _digest_under_hashseed(hashseed: str) -> str:
    env = dict(os.environ)
    env["PYTHONHASHSEED"] = hashseed
    env["PYTHONPATH"] = str(REPO_ROOT / "src")
    out = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, check=True)
    return out.stdout.strip()


def test_datasets_identical_across_hash_seeds():
    a = _digest_under_hashseed("0")
    b = _digest_under_hashseed("4242")
    assert a == b, "dataset content depends on PYTHONHASHSEED"
    assert len(a) == 64  # sanity: a real sha256 came back


def test_name_salt_is_stable_and_distinct():
    from repro.data.synthetic import _name_salt

    # Pinned values: changing them silently re-rolls every synthetic dataset.
    assert _name_salt("mnist") == _name_salt("mnist")
    salts = {_name_salt(n) for n in ("mnist", "cifar10", "cifar100")}
    assert len(salts) == 3
