"""Host-side data pipeline: TokenPipeline sharding and the cohort
WindowAssembler (ISSUE 4 satellites + double-buffered overlap parity).

TokenPipeline contract: (batch, seq+1) windows in-vocab, disjoint
per-client shards covering the WHOLE stream (no silent tail loss), and a
clear error — not a cryptic ``rng.integers`` crash — when a shard is too
short for even one sequence window.

WindowAssembler contract: background/prefetched assembly produces
bit-identical windows to inline assembly (the per-seed np RNG streams
don't depend on where sampling runs), and the engine-level overlap toggle
never changes training results.
"""
import jax
import numpy as np
import pytest

from repro.configs.cnn import vgg_for
from repro.data import make_benchmark_dataset, split_811
from repro.data.pipeline import TokenPipeline, WindowAssembler
from repro.data.synthetic import Dataset, make_lm_dataset
from repro.fl.backend import CNNBackend
from repro.fl.cohort import CohortBackend


# -- TokenPipeline -----------------------------------------------------------


def test_token_pipeline_shapes_and_dtype():
    pipe = TokenPipeline(vocab=32, batch=4, seq=16, n_tokens=2000, seed=0)
    it = iter(pipe)
    arr = next(it)
    assert arr.shape == (4, 17)
    assert arr.min() >= 0 and arr.max() < 32
    d = pipe.batch_dict(arr)
    assert d["tokens"].shape == (4, 16) and d["tokens"].dtype == np.int32
    assert d["labels"].shape == (4, 16) and d["labels"].dtype == np.int32
    assert np.array_equal(d["tokens"][:, 1:], d["labels"][:, :-1])


def test_token_pipeline_shards_are_disjoint_and_cover_stream():
    # 1003 tokens over 4 shards: array_split semantics — no tail loss
    n_tokens, n_shards = 1003, 4
    full = make_lm_dataset(vocab=16, n_tokens=n_tokens, seed=7)
    shards = [TokenPipeline(vocab=16, batch=2, seq=8, n_tokens=n_tokens,
                            seed=7, n_shards=n_shards, shard=s).stream
              for s in range(n_shards)]
    assert sum(len(s) for s in shards) == n_tokens   # every token owned
    assert np.array_equal(np.concatenate(shards), full)  # disjoint slices
    # deterministic sampling per (seed, shard)
    a = next(iter(TokenPipeline(vocab=16, batch=2, seq=8, n_tokens=n_tokens,
                                seed=7, n_shards=n_shards, shard=1)))
    b = next(iter(TokenPipeline(vocab=16, batch=2, seq=8, n_tokens=n_tokens,
                                seed=7, n_shards=n_shards, shard=1)))
    assert np.array_equal(a, b)


def test_token_pipeline_short_shard_raises_clear_error():
    """Regression: small n_tokens with many shards used to reach
    ``rng.integers(0, <non-positive>)`` inside iteration; now construction
    raises with actionable guidance."""
    with pytest.raises(ValueError, match="n_shards"):
        TokenPipeline(vocab=16, batch=2, seq=64, n_tokens=600, n_shards=16)
    # boundary: the smallest legal shard (seq + 1 tokens = exactly one
    # window) still samples, and that window reaches the final token
    pipe = TokenPipeline(vocab=16, batch=2, seq=8, n_tokens=9)
    arr = next(iter(pipe))
    assert arr.shape == (2, 9)
    assert np.array_equal(arr[0], pipe.stream)       # start 0 is the only one


def test_token_pipeline_final_token_is_sampleable():
    """Regression: the start-range upper bound used to exclude the last
    valid window, so a shard's final token never appeared in any batch."""
    pipe = TokenPipeline(vocab=16, batch=64, seq=8, n_tokens=12, seed=1)
    it = iter(pipe)
    last = pipe.stream[-1]
    seen_last = any(
        np.any(arr[:, -1] == last) and
        any(np.array_equal(row, pipe.stream[-9:]) for row in arr)
        for arr in (next(it) for _ in range(50)))
    assert seen_last, "the final window (and token) was never sampled"


def test_token_pipeline_rejects_bad_shard_index():
    with pytest.raises(ValueError, match="out of range"):
        TokenPipeline(vocab=16, batch=2, seq=8, n_tokens=1000,
                      n_shards=2, shard=2)


# -- WindowAssembler ---------------------------------------------------------


@pytest.fixture(scope="module")
def cnn_world():
    ds = make_benchmark_dataset("mnist", n_samples=400, seed=5)
    splits = split_811(ds)
    backend = CNNBackend(vgg_for("mnist"), local_epochs=1, batch_size=16)
    rng = np.random.default_rng(0)
    shards = []
    for s in (40, 64, 52):
        idx = rng.choice(len(splits["train"]), size=s, replace=False)
        shards.append(Dataset(splits["train"].x[idx], splits["train"].y[idx]))
    return backend, shards


def _win_arrays(win):
    return [np.asarray(win.xb), np.asarray(win.yb), np.asarray(win.mask)] + \
        ([np.asarray(win.bm)] if win.bm is not None else [])


def test_window_assembler_overlap_parity(cnn_world):
    """Prefetched background assembly == inline assembly, bit for bit:
    same batches, same masks, same step counts, same RNG streams."""
    backend, shards = cnn_world
    seeds = [11, 22, 33]
    eng_inline = CohortBackend(backend, capacity=4, overlap=False)
    eng_overlap = CohortBackend(backend, capacity=4, overlap=True)
    for eng in (eng_inline, eng_overlap):
        eng.register_shards(shards, epochs=1)

    win_inline = eng_inline.assembler.take(shards, seeds, 1, 4)
    eng_overlap.prefetch_window(shards, seeds, epochs=1)
    win_over = eng_overlap.assembler.take(shards, seeds, 1, 4)
    assert win_inline.steps == win_over.steps
    assert win_inline.uniform == win_over.uniform
    for a, b in zip(_win_arrays(win_inline), _win_arrays(win_over)):
        assert np.array_equal(a, b)

    # a mismatched prefetch must fall back to correct inline assembly
    eng_overlap.prefetch_window(shards, [99, 98, 97], epochs=1)
    win_mismatch = eng_overlap.assembler.take(shards, seeds, 1, 4)
    for a, b in zip(_win_arrays(win_inline), _win_arrays(win_mismatch)):
        assert np.array_equal(a, b)
    eng_overlap.assembler.close()


def test_window_assembler_train_results_identical(cnn_world):
    """End-to-end: cohort training with the overlapped pipeline returns the
    same weights and losses as with inline assembly."""
    backend, shards = cnn_world
    seeds = [3, 4, 5]
    params = [backend.init(jax.random.PRNGKey(i)) for i in range(3)]
    eng_a = CohortBackend(backend, capacity=4, overlap=False)
    eng_b = CohortBackend(backend, capacity=4, overlap=True)
    pa, la = eng_a.train_cohort(params, shards, seeds)
    eng_b.prefetch_window(shards, seeds)       # double-buffered path
    pb, lb = eng_b.train_cohort(params, shards, seeds)
    assert la == pytest.approx(lb, abs=1e-6)
    for ta, tb in zip(pa, pb):
        for x, y in zip(jax.tree_util.tree_leaves(ta),
                        jax.tree_util.tree_leaves(tb)):
            assert np.array_equal(np.asarray(x), np.asarray(y))
    eng_b.assembler.close()


def test_window_assembler_monotone_pad_target(cnn_world):
    """register_shards pre-sizes the step-axis target; a longer window can
    only grow it (monotone — the steady-state program never re-compiles
    smaller)."""
    backend, shards = cnn_world
    asm = WindowAssembler(CohortBackend(backend, capacity=4,
                                        overlap=False).programs,
                          overlap=False)
    asm.register_shards(shards, epochs=1)
    t0 = asm.pad_T
    assert t0 == max(max(len(s) // backend.batch_size, 1) for s in shards)
    asm.register_shards(shards[:1], epochs=2)
    assert asm.pad_T >= t0
