"""Serving correctness: prefill + decode_step == full forward, per arch;
plus frontier-replica decode parity (the live-traffic serving path must
produce bit-identical tokens to a direct Eq. 6 aggregation)."""
import dataclasses
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.launch.serve import extend_caches
from repro.models import transformer as T
from repro.models.attention import cache_seq_axis


def _pad_caches(caches, cfg, extra=1):
    # the serving launcher's spec-driven helper IS the implementation under
    # test here: prefill-collected caches carry a stacked-layer leading axis
    return extend_caches(caches, cfg, extra)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    cfg = dataclasses.replace(reduced(get_config(arch)),
                              compute_dtype="float32")
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.encoder is not None:
        batch["enc_embed"] = jax.random.normal(
            key, (B, cfg.encoder.n_ctx, cfg.d_model)) * 0.1
    logits_full, _, _ = T.forward(params, batch, cfg, mode="prefill")
    bp = dict(batch)
    bp["tokens"] = toks[:, :S - 1]
    _, caches, _ = T.prefill(params, bp, cfg)
    caches = _pad_caches(caches, cfg)
    logits_dec, new_caches = T.decode_step(params, toks[:, S - 1:S], caches,
                                           jnp.int32(S - 1), cfg)
    diff = float(jnp.max(jnp.abs(logits_dec - logits_full[:, -1])))
    assert diff < 2e-2, f"{arch}: decode diverges from full forward ({diff})"
    # cache structure is preserved
    assert jax.tree_util.tree_structure(new_caches) == \
        jax.tree_util.tree_structure(caches)


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "jamba-v0.1-52b",
                                  "xlstm-125m"])
def test_multi_step_decode_tracks_full_forward(arch):
    """Decoding token-by-token stays close to teacher-forced full logits."""
    cfg = dataclasses.replace(reduced(get_config(arch)),
                              compute_dtype="float32")
    key = jax.random.PRNGKey(7)
    params = T.init_params(key, cfg)
    B, S_prompt, n_new = 1, 8, 4
    S = S_prompt + n_new
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    # teacher-forced reference over the whole sequence
    logits_full, _, _ = T.forward(params, {"tokens": toks}, cfg,
                                  mode="prefill")
    # prefill prompt, then feed the same ground-truth tokens step by step
    _, caches, _ = T.prefill(params, {"tokens": toks[:, :S_prompt]}, cfg)
    caches = _pad_caches(caches, cfg, extra=n_new)
    for step in range(n_new):
        pos = S_prompt + step
        logits_dec, caches = T.decode_step(
            params, toks[:, pos:pos + 1], caches, jnp.int32(pos), cfg)
        diff = float(jnp.max(jnp.abs(logits_dec - logits_full[:, pos])))
        assert diff < 2e-2, f"{arch} step {step}: {diff}"


# -- cache sequence-axis derivation ------------------------------------------


def test_cache_seq_axis_counts_from_trailing_end():
    """k/v caches keep (heads, head_dim) behind the sequence axis; latent
    ckv/krope caches keep one trailing dim — regardless of how many leading
    axes (batch, stacked layers) sit in front."""
    assert cache_seq_axis("k", 4) == 1          # (B, S, H, D)
    assert cache_seq_axis("v", 4) == 1
    assert cache_seq_axis("k", 5) == 2          # (L, B, S, H, D) stacked
    assert cache_seq_axis("v", 5) == 2
    assert cache_seq_axis("ckv", 3) == 1        # (B, S, d_latent)
    assert cache_seq_axis("krope", 3) == 1
    assert cache_seq_axis("ckv", 4) == 2        # (L, B, S, d_latent)
    assert cache_seq_axis("krope", 4) == 2


def test_extend_caches_pads_unstacked_layout_on_axis_1():
    """Regression for the old hardcoded ``pad[2]``: an UNSTACKED per-layer
    (B, S, H, D) cache entry must grow along axis 1 (its sequence axis) —
    padding axis 2 would silently corrupt the head axis instead."""
    fake_cfg = SimpleNamespace(stages=[
        SimpleNamespace(pattern=[SimpleNamespace(kind="attn")])])
    B, S, H, D = 2, 5, 3, 4
    caches = [{"l0": {"k": jnp.ones((B, S, H, D)),
                      "v": jnp.ones((B, S, H, D)),
                      "ckv": jnp.ones((B, S, 7))}}]
    out = extend_caches(caches, fake_cfg, extra=3)
    assert out[0]["l0"]["k"].shape == (B, S + 3, H, D)
    assert out[0]["l0"]["v"].shape == (B, S + 3, H, D)
    assert out[0]["l0"]["ckv"].shape == (B, S + 3, 7)
    # original sequence slots untouched, new slots zero
    np.testing.assert_array_equal(np.asarray(out[0]["l0"]["k"][:, :S]), 1.0)
    np.testing.assert_array_equal(np.asarray(out[0]["l0"]["k"][:, S:]), 0.0)


def test_extend_caches_pads_stacked_layout_on_axis_2():
    fake_cfg = SimpleNamespace(stages=[
        SimpleNamespace(pattern=[SimpleNamespace(kind="attn")])])
    L, B, S, H, D = 2, 1, 4, 2, 3
    caches = [{"l0": {"k": jnp.ones((L, B, S, H, D)),
                      "v": jnp.ones((L, B, S, H, D))}}]
    out = extend_caches(caches, fake_cfg, extra=2)
    assert out[0]["l0"]["k"].shape == (L, B, S + 2, H, D)
    assert out[0]["l0"]["v"].shape == (L, B, S + 2, H, D)


# -- frontier-replica decode parity (live-traffic serving) -------------------


def _tiny_lm_cfg():
    return dataclasses.replace(
        reduced(get_config("internlm2-1.8b"), d_model=64),
        vocab_size=128, compute_dtype="float32")


def _ledger_world(bounded: bool, cfg, n_models: int = 3):
    """A frontier of ``n_models`` distinct real LM param trees branching off
    genesis; the bounded variant also checkpoints (pruning genesis) so
    parity is exercised against a pruned ledger too."""
    from repro.core.dag import (BoundedDAGLedger, DAGLedger, ModelStore,
                                TxMetadata)
    store = ModelStore()
    ledger = (BoundedDAGLedger(evict_fn=lambda tx: store.evict(tx.model_ref))
              if bounded else DAGLedger())

    def meta(cid):
        return TxMetadata(client_id=cid, signature=(0.0,) * 16,
                          model_accuracy=0.5, current_epoch=0,
                          validation_node_id=cid)

    ref = store.put("genesis", T.init_params(jax.random.PRNGKey(99), cfg))
    ledger.add_genesis(meta(-1), 0.0, ref)
    g = ledger.genesis_id
    for c in range(n_models):
        ref = store.put(f"m{c}", T.init_params(jax.random.PRNGKey(c), cfg))
        ledger.add_transaction(meta(c), (g,), 1.0 + c, ref)
    if bounded:
        ledger.checkpoint(now=10.0)     # prunes genesis under the frontier
        assert ledger.n_pruned > 0 and "genesis" not in store
    return ledger, store


@pytest.mark.parametrize("policy", ["reference", "interpret", "auto"])
def test_replica_decode_parity_vs_direct_eq6(policy):
    """The tokens decoded from a published ServingReplica must be
    bit-identical to decoding from a directly-computed Eq. 6 aggregate over
    the same frontier — for bounded AND unbounded ledgers, under every
    kernel dispatch policy the serving path supports."""
    from repro.core.simulator import EventLoop
    from repro.fl.serving import (ConsensusPublisher, LMQueryDriver,
                                  consensus_over_refs, frontier_snapshot,
                                  trees_bitwise_equal)
    cfg = _tiny_lm_cfg()
    driver = LMQueryDriver(cfg, query_batch=2, prompt_len=6, new_tokens=4,
                           seed=0, kernel_policy=policy)
    prompts = np.random.default_rng(3).integers(0, cfg.vocab_size, (2, 6))
    for bounded in (False, True):
        ledger, store = _ledger_world(bounded, cfg)
        pub = ConsensusPublisher(ledger, store, EventLoop(), every=1.0)
        rep = pub.publish()
        _, refs = frontier_snapshot(ledger)
        assert rep.model_refs == refs and len(refs) == 3
        direct = consensus_over_refs(store, refs)
        assert trees_bitwise_equal(rep.params, direct)
        toks_replica = driver.decode_prompts(rep.params, prompts)
        toks_direct = driver.decode_prompts(direct, prompts)
        assert toks_replica.shape == (2, 4)
        np.testing.assert_array_equal(toks_replica, toks_direct)
