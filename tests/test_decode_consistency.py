"""Serving correctness: prefill + decode_step == full forward, per arch."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import transformer as T


def _pad_caches(caches, cfg, extra=1):
    out = []
    for si, stage in enumerate(cfg.stages):
        d = {}
        for j, spec in enumerate(stage.pattern):
            cc = dict(caches[si][f"l{j}"])
            if spec.kind == "attn":
                for kk in ("k", "v", "ckv", "krope"):
                    if kk in cc:
                        pad = [(0, 0)] * cc[kk].ndim
                        pad[2] = (0, extra)
                        cc[kk] = jnp.pad(cc[kk], pad)
            d[f"l{j}"] = cc
        out.append(d)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    cfg = dataclasses.replace(reduced(get_config(arch)),
                              compute_dtype="float32")
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.encoder is not None:
        batch["enc_embed"] = jax.random.normal(
            key, (B, cfg.encoder.n_ctx, cfg.d_model)) * 0.1
    logits_full, _, _ = T.forward(params, batch, cfg, mode="prefill")
    bp = dict(batch)
    bp["tokens"] = toks[:, :S - 1]
    _, caches, _ = T.prefill(params, bp, cfg)
    caches = _pad_caches(caches, cfg)
    logits_dec, new_caches = T.decode_step(params, toks[:, S - 1:S], caches,
                                           jnp.int32(S - 1), cfg)
    diff = float(jnp.max(jnp.abs(logits_dec - logits_full[:, -1])))
    assert diff < 2e-2, f"{arch}: decode diverges from full forward ({diff})"
    # cache structure is preserved
    assert jax.tree_util.tree_structure(new_caches) == \
        jax.tree_util.tree_structure(caches)


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "jamba-v0.1-52b",
                                  "xlstm-125m"])
def test_multi_step_decode_tracks_full_forward(arch):
    """Decoding token-by-token stays close to teacher-forced full logits."""
    cfg = dataclasses.replace(reduced(get_config(arch)),
                              compute_dtype="float32")
    key = jax.random.PRNGKey(7)
    params = T.init_params(key, cfg)
    B, S_prompt, n_new = 1, 8, 4
    S = S_prompt + n_new
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    # teacher-forced reference over the whole sequence
    logits_full, _, _ = T.forward(params, {"tokens": toks}, cfg,
                                  mode="prefill")
    # prefill prompt, then feed the same ground-truth tokens step by step
    _, caches, _ = T.prefill(params, {"tokens": toks[:, :S_prompt]}, cfg)
    caches = _pad_caches(caches, cfg, extra=n_new)
    for step in range(n_new):
        pos = S_prompt + step
        logits_dec, caches = T.decode_step(
            params, toks[:, pos:pos + 1], caches, jnp.int32(pos), cfg)
        diff = float(jnp.max(jnp.abs(logits_dec - logits_full[:, pos])))
        assert diff < 2e-2, f"{arch} step {step}: {diff}"
