"""Dry-run machinery integration test on a small forced-device mesh.

Runs in a subprocess so the 8-device XLA_FLAGS never pollutes the main test
process (jax locks device count on first init).
"""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json, sys
import numpy as np
import jax
from jax.sharding import Mesh

sys.path.insert(0, "src")
from repro.configs import get_config, reduced
from repro.configs.base import InputShape
from repro.launch import dryrun
from repro.launch.hlo_analysis import analyze_hlo
from repro.sharding.rules import MeshPlan

cfg = dataclasses.replace(
    reduced(get_config("{arch}")), compute_dtype="bfloat16",
    cache_dtype="bfloat16")
shape = InputShape("test", {seq}, {batch}, "{mode}")
mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
plan = MeshPlan()
jitted, args = dryrun.build_step(cfg, shape, mesh, plan)
with mesh:
    compiled = jitted.lower(*args).compile()
    cost = analyze_hlo(compiled.as_text())
    mem = compiled.memory_analysis()
print(json.dumps({{"flops": cost.flops, "bytes": cost.bytes,
                   "coll": cost.collective_bytes,
                   "temp": int(mem.temp_size_in_bytes)}}))
"""


@pytest.mark.parametrize("arch,mode,batch,seq", [
    ("internlm2-1.8b", "train", 8, 64),
    ("jamba-v0.1-52b", "train", 8, 64),
    ("deepseek-v2-236b", "decode", 8, 128),
    ("whisper-medium", "prefill", 8, 64),
])
def test_dryrun_small_mesh(arch, mode, batch, seq):
    script = _SCRIPT.format(arch=arch, mode=mode, batch=batch, seq=seq)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True,
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["flops"] > 0
    assert rec["bytes"] > 0
    if mode == "train":
        assert rec["coll"] > 0          # grad all-reduce must exist
