"""All 10 FL algorithms run end-to-end on the shared simulator (tiny)."""
import numpy as np
import pytest

from repro.configs.cnn import vgg_for
from repro.core.simulator import CostModel, make_profiles
from repro.data import make_benchmark_dataset, partition_dirichlet, split_811
from repro.fl import ALGORITHMS, CNNBackend, FLConfig


@pytest.fixture(scope="module")
def setup():
    ds = make_benchmark_dataset("mnist", n_samples=900, seed=0)
    splits = split_811(ds)
    parts = partition_dirichlet(splits["train"], 3, beta=0.5, seed=0)
    client_data = []
    for p in parts:
        s = split_811(p, seed=1)
        client_data.append({"train": s["train"], "val": s["val"],
                            "test": s["test"]})
    backend = CNNBackend(vgg_for("mnist"), local_epochs=1, batch_size=32)
    cfg = FLConfig(n_clients=3, max_rounds=2, local_epochs=1, seed=0)
    profiles = make_profiles(3, 0.5, 0)
    return backend, client_data, splits, cfg, profiles


@pytest.mark.parametrize("name", sorted(ALGORITHMS))
def test_algorithm_runs(name, setup):
    backend, client_data, splits, cfg, profiles = setup
    kw = {"pooled_train": splits["train"]} if name == "centralized" else {}
    res = ALGORITHMS[name](backend, client_data, splits["test"], cfg,
                           CostModel(local_epoch=2.0), profiles, **kw)
    assert 0.0 <= res.final_accuracy <= 1.0
    assert res.sim_time > 0
    assert res.rounds >= 1
    assert res.history, name


def test_async_faster_than_sequential_hierarchy(setup):
    """Sanity on the simulator: FedHiSyn's sequential rings cost more
    simulated time per round than FedAsync (the paper's Table III shape)."""
    backend, client_data, splits, cfg, profiles = setup
    cost = CostModel(local_epoch=2.0)
    r_async = ALGORITHMS["fedasync"](backend, client_data, splits["test"],
                                     cfg, cost, profiles)
    r_hi = ALGORITHMS["fedhisyn"](backend, client_data, splits["test"],
                                  cfg, cost, profiles)
    per_round_async = r_async.sim_time / max(r_async.rounds, 1)
    per_round_hi = r_hi.sim_time / max(r_hi.rounds, 1)
    assert per_round_hi > per_round_async
