"""All 10 FL algorithms run end-to-end on the shared simulator (tiny)."""
import numpy as np
import pytest

from repro.configs.cnn import vgg_for
from repro.core.simulator import CostModel, make_profiles
from repro.data import make_benchmark_dataset, partition_dirichlet, split_811
from repro.fl import ALGORITHMS, CNNBackend, FLConfig


@pytest.fixture(scope="module")
def setup():
    ds = make_benchmark_dataset("mnist", n_samples=900, seed=0)
    splits = split_811(ds)
    parts = partition_dirichlet(splits["train"], 3, beta=0.5, seed=0)
    client_data = []
    for p in parts:
        s = split_811(p, seed=1)
        client_data.append({"train": s["train"], "val": s["val"],
                            "test": s["test"]})
    backend = CNNBackend(vgg_for("mnist"), local_epochs=1, batch_size=32)
    cfg = FLConfig(n_clients=3, max_rounds=2, local_epochs=1, seed=0)
    profiles = make_profiles(3, 0.5, 0)
    return backend, client_data, splits, cfg, profiles


@pytest.mark.parametrize("name", sorted(ALGORITHMS))
def test_algorithm_runs(name, setup):
    backend, client_data, splits, cfg, profiles = setup
    kw = {"pooled_train": splits["train"]} if name == "centralized" else {}
    res = ALGORITHMS[name](backend, client_data, splits["test"], cfg,
                           CostModel(local_epoch=2.0), profiles, **kw)
    assert 0.0 <= res.final_accuracy <= 1.0
    assert res.sim_time > 0
    assert res.rounds >= 1
    assert res.history, name


def test_async_faster_than_sequential_hierarchy(setup):
    """Sanity on the simulator: FedHiSyn's sequential rings cost more
    simulated time per round than FedAsync (the paper's Table III shape)."""
    backend, client_data, splits, cfg, profiles = setup
    cost = CostModel(local_epoch=2.0)
    r_async = ALGORITHMS["fedasync"](backend, client_data, splits["test"],
                                     cfg, cost, profiles)
    r_hi = ALGORITHMS["fedhisyn"](backend, client_data, splits["test"],
                                  cfg, cost, profiles)
    per_round_async = r_async.sim_time / max(r_async.rounds, 1)
    per_round_hi = r_hi.sim_time / max(r_hi.rounds, 1)
    assert per_round_hi > per_round_async


def test_fedat_tier_weights_pinned_values():
    """FedAT cross-tier weights (Chai et al. 2021, Eq. 4): the comment in
    run_fedat promises straggler tiers (fewer updates) get MORE weight —
    pin the inverse-frequency form so a refactor can't silently flip it."""
    from repro.fl import fedat_tier_weights
    assert fedat_tier_weights([2, 5, 4], [0, 1, 2]) == [0.5, 0.2, 0.25]
    # ready subset indexes tier_updates, preserving ready order
    assert fedat_tier_weights([2, 5, 4], [2, 0]) == [0.25, 0.5]


def test_fedat_straggler_tier_outweighs_fast_tier():
    from repro.fl import fedat_tier_weights
    updates = [9, 3, 1]          # tier 0 fast, tier 2 straggler
    w = fedat_tier_weights(updates, [0, 1, 2])
    assert w[2] > w[1] > w[0]
    # strictly decreasing in update count, pairwise
    for i in range(3):
        for j in range(3):
            if updates[i] < updates[j]:
                assert w[i] > w[j]


def test_fedat_exposes_tier_updates(setup):
    backend, client_data, splits, cfg, profiles = setup
    res = ALGORITHMS["fedat"](backend, client_data, splits["test"], cfg,
                              CostModel(local_epoch=2.0), profiles)
    ups = res.extra["tier_updates"]
    assert len(ups) == len(res.extra["tiers"])
    # counts start at 1 (init model) so weights stay finite
    assert all(u >= 1 for u in ups)
