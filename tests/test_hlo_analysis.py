"""HLO analyzer: trip-count-aware flops vs hand-computed ground truth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_plain_matmul_flops():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    cost = analyze_hlo(_compiled_text(lambda x, y: x @ y, a, b))
    assert cost.flops == pytest.approx(2 * 64 * 128 * 32, rel=0.01)


def test_scan_multiplies_trip_count():
    """The whole point: a scanned matmul counts body x trips."""
    W = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)
    X = jax.ShapeDtypeStruct((16, 64), jnp.float32)

    def scanned(x, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    cost = analyze_hlo(_compiled_text(scanned, X, W))
    expect = 8 * 2 * 16 * 64 * 64
    assert cost.flops == pytest.approx(expect, rel=0.05)
    # XLA's own cost_analysis undercounts by the trip count
    xla = jax.jit(scanned).lower(X, W).compile().cost_analysis()
    if isinstance(xla, (list, tuple)):      # older jaxlib: one dict per device
        xla = xla[0]
    assert xla["flops"] < cost.flops / 4


def test_nested_scan():
    W = jax.ShapeDtypeStruct((4, 3, 32, 32), jnp.float32)
    X = jax.ShapeDtypeStruct((8, 32), jnp.float32)

    def nested(x, ws):
        def outer(x, ws_o):
            def inner(x, w):
                return x @ w, None
            y, _ = jax.lax.scan(inner, x, ws_o)
            return y, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y.sum()

    cost = analyze_hlo(_compiled_text(nested, X, W))
    assert cost.flops == pytest.approx(12 * 2 * 8 * 32 * 32, rel=0.05)


def test_bytes_counts_dot_operands():
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    cost = analyze_hlo(_compiled_text(lambda x: x @ x, a))
    # 2 operand reads (same buffer counted per use) + result write
    assert cost.bytes >= 3 * 256 * 256 * 4


def test_no_collectives_single_device():
    a = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    cost = analyze_hlo(_compiled_text(lambda x: (x @ x).sum(), a))
    assert cost.collective_bytes == 0.0
