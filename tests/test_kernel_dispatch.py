"""Kernel dispatch layer: policy resolution + hot-path swap parity.

The swaps under test route the Eq. 3 signatures (CNN exact-zero rows, LM
threshold-zero buckets) and the LM attention through ``repro.kernels.ops``.
Signatures feed tip selection through the similarity contract, so the
signature swaps must be BIT-identical to the incumbent jnp math — not
merely allclose — on every policy, shape, and execution discipline (eager,
jit, vmap, 1-D and 2-D shard_map).  Attention is ordinary floating-point
kernel work and gets an allclose budget.

Multi-device cases skip on single-device hosts; CI's multi-device job
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) runs them on the
8x1 and 4x2 meshes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.kernels.dispatch import (POLICY_ENV, policy_from_runtime,
                                    resolve_interpret, resolve_policy)
from repro.models.layers import activation_signature
from repro.runtime import Runtime

N_DEV = len(jax.devices())


def _bit_equal(a, b, msg=""):
    a, b = np.asarray(a), np.asarray(b)
    assert a.dtype == b.dtype and a.shape == b.shape
    assert np.array_equal(a, b), (
        f"{msg}: max |diff| {np.max(np.abs(a - b))} over "
        f"{np.sum(a != b)}/{a.size} mismatched entries")


# ---------------------------------------------------------------------------
# policy resolution
# ---------------------------------------------------------------------------


def test_resolve_policy_platform_default(monkeypatch):
    monkeypatch.delenv(POLICY_ENV, raising=False)
    expected = "compiled" if jax.default_backend() == "tpu" else "interpret"
    assert resolve_policy(None) == expected
    assert resolve_policy("auto") == expected


def test_resolve_policy_explicit_beats_env(monkeypatch):
    monkeypatch.setenv(POLICY_ENV, "compiled")
    assert resolve_policy("reference") == "reference"
    assert resolve_policy(None) == "compiled"
    assert resolve_policy("auto") == "compiled"


def test_resolve_policy_env_auto_falls_through(monkeypatch):
    monkeypatch.setenv(POLICY_ENV, "auto")
    expected = "compiled" if jax.default_backend() == "tpu" else "interpret"
    assert resolve_policy(None) == expected


def test_resolve_policy_rejects_unknown(monkeypatch):
    with pytest.raises(ValueError, match="unknown kernel policy"):
        resolve_policy("vectorized")
    monkeypatch.setenv(POLICY_ENV, "turbo")
    with pytest.raises(ValueError, match="REPRO_KERNEL_POLICY"):
        resolve_policy(None)


def test_resolve_interpret_explicit_wins():
    assert resolve_interpret(True, "compiled") is True
    assert resolve_interpret(False, "interpret") is False
    assert resolve_interpret(None, "compiled") is False
    assert resolve_interpret(None, "interpret") is True
    assert resolve_interpret(None, "reference") is True


def test_policy_from_runtime():
    assert policy_from_runtime(None) == "reference"
    assert policy_from_runtime(Runtime()) == "reference"
    assert policy_from_runtime(
        Runtime(use_pallas=True, kernel_policy="interpret")) == "interpret"
    assert policy_from_runtime(
        Runtime(use_pallas=True, kernel_policy="reference")) == "reference"
    # legacy pallas_interpret still forces the mode when set explicitly
    assert policy_from_runtime(
        Runtime(use_pallas=True, pallas_interpret=True)) == "interpret"
    assert policy_from_runtime(
        Runtime(use_pallas=True, pallas_interpret=False)) == "compiled"


def test_policy_from_runtime_env_override(monkeypatch):
    monkeypatch.setenv(POLICY_ENV, "reference")
    assert policy_from_runtime(
        Runtime(use_pallas=True, kernel_policy="auto")) == "reference"


# ---------------------------------------------------------------------------
# ops.signature: bit-consistency with models.layers.activation_signature
# ---------------------------------------------------------------------------


def _activations(shape, seed=0, kill=0.3):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape)
    return jnp.where(jnp.abs(x) < kill, 0.0, x)


# d=100/n_sig=64 is the regression case for the bucket-padding bias: with
# d % n_sig != 0 the buckets must see zero-padded tail channels, exactly
# like activation_signature's zero-padded flag columns — NOT a truncated
# or rescaled bucket width.
@pytest.mark.parametrize("T,d,n_sig", [(12, 128, 64), (7, 100, 64),
                                       (30, 64, 64), (5, 65, 64),
                                       (16, 33, 8), (1, 64, 64)])
@pytest.mark.parametrize("policy", ["reference", "interpret"])
def test_signature_bit_matches_activation_signature(T, d, n_sig, policy):
    x = _activations((T, d), seed=d)
    expect = activation_signature(x, n_sig=n_sig, tau=0.05)
    got = kops.signature(x, tau=0.05, n_sig=n_sig, policy=policy)
    _bit_equal(got, expect, f"policy={policy} d={d} n_sig={n_sig}")


@pytest.mark.parametrize("policy", ["reference", "interpret"])
def test_signature_bit_stable_under_jit_and_vmap(policy):
    x = _activations((4, 9, 100), seed=3)
    flat = x.reshape(4, -1)          # per-sample rows, d=900? no: (4, 900)
    f = lambda row: kops.signature(row, tau=0.05, n_sig=64, policy=policy)
    eager = jnp.stack([f(r) for r in flat])
    vmapped = jax.vmap(f)(flat)
    jitted = jax.jit(jax.vmap(f))(flat)
    expect = jnp.stack([activation_signature(r, n_sig=64, tau=0.05)
                        for r in flat])
    _bit_equal(eager, expect, f"eager policy={policy}")
    _bit_equal(vmapped, expect, f"vmap policy={policy}")
    _bit_equal(jitted, expect, f"jit(vmap) policy={policy}")


def test_signature_tau_zero_counts_exact_zeros():
    x = jnp.asarray([[0.0, 1.0, 0.02, 0.0], [0.0, 0.0, 3.0, -0.01]])
    got = kops.signature(x, tau=0.0, n_sig=4, policy="interpret")
    expect = jnp.mean((x == 0.0).astype(jnp.float32), axis=0)
    _bit_equal(got, expect, "tau=0 exact-zero semantics")


# ---------------------------------------------------------------------------
# ops.signature_per_channel: bit-consistency with the CNN incumbent
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(3, 8, 8, 16), (2, 7, 7, 10),
                                   (1, 28, 28, 32), (5, 3, 3, 1)])
@pytest.mark.parametrize("policy", ["reference", "interpret"])
def test_signature_per_channel_bit_matches_jnp(shape, policy):
    x = jax.nn.relu(_activations(shape, seed=shape[-1], kill=0.0) - 0.4)
    expect = jnp.mean((x == 0.0).astype(jnp.float32), axis=(1, 2))
    got = kops.signature_per_channel(x, tau=0.0, policy=policy)
    _bit_equal(got, expect, f"policy={policy} shape={shape}")


def test_signature_per_channel_bit_stable_under_jit():
    x = jax.nn.relu(_activations((4, 14, 14, 20), seed=9, kill=0.0) - 0.3)
    expect = jnp.mean((x == 0.0).astype(jnp.float32), axis=(1, 2))
    for policy in ("reference", "interpret"):
        got = jax.jit(lambda a: kops.signature_per_channel(
            a, tau=0.0, policy=policy))(x)
        _bit_equal(got, expect, f"jit policy={policy}")


# ---------------------------------------------------------------------------
# model hot paths: cnn_forward / per_sample_signature policy on vs off
# ---------------------------------------------------------------------------


def _cnn_world():
    from repro.configs.cnn import vgg_for
    from repro.models import cnn as cnn_mod
    cfg = vgg_for("mnist")
    params = cnn_mod.init_cnn(jax.random.PRNGKey(0), cfg)
    x = jax.random.uniform(
        jax.random.PRNGKey(1), (6, cfg.image_size, cfg.image_size,
                                cfg.in_channels))
    return cnn_mod, cfg, params, x


def test_cnn_forward_signature_policy_bit_equal():
    cnn_mod, cfg, params, x = _cnn_world()
    _, sig_ref = cnn_mod.cnn_forward(params, x, cfg, want_signature=True)
    _, sig_int = cnn_mod.cnn_forward(params, x, cfg, want_signature=True,
                                     kernel_policy="interpret")
    assert sig_ref is not None and sig_int is not None
    _bit_equal(sig_int, sig_ref, "cnn_forward kernel_policy on vs off")


def test_per_sample_signature_policy_bit_equal():
    from repro.models import transformer as tfm
    h = _activations((3, 17, 100), seed=7)
    off = tfm.per_sample_signature(h, Runtime(want_signature=True))
    on = tfm.per_sample_signature(
        h, Runtime(want_signature=True, use_pallas=True,
                   kernel_policy="interpret"))
    _bit_equal(on, off, "per_sample_signature use_pallas on vs off")


# ---------------------------------------------------------------------------
# LM attention swap: allclose vs the stock-XLA path
# ---------------------------------------------------------------------------


def test_lm_forward_hidden_pallas_attention_close():
    import dataclasses

    from repro.configs import get_config, reduced
    from repro.models import transformer as tfm
    cfg = dataclasses.replace(reduced(get_config("internlm2-1.8b")),
                              compute_dtype="float32", d_model=64,
                              vocab_size=128)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    rt_off = Runtime(want_signature=False)
    rt_on = Runtime(want_signature=False, use_pallas=True,
                    kernel_policy="interpret")
    h_off, _, _ = tfm.forward_hidden(params, {"tokens": toks}, cfg, rt_off,
                                     mode="prefill")
    h_on, _, _ = tfm.forward_hidden(params, {"tokens": toks}, cfg, rt_on,
                                    mode="prefill")
    np.testing.assert_allclose(np.asarray(h_on), np.asarray(h_off),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# cohort engine parity: the full Eq. 3 path, single-device + meshes
# ---------------------------------------------------------------------------


def _cohort_engines(mesh_spec, kernel_policy, cohort=4):
    from repro.configs.cnn import vgg_for
    from repro.data import make_benchmark_dataset, split_811
    from repro.data.synthetic import Dataset
    from repro.fl.backend import CNNBackend
    from repro.fl.cohort import build_cohort_engine

    ds = make_benchmark_dataset("mnist", n_samples=400, seed=5)
    train = split_811(ds)["train"]
    rng = np.random.default_rng(0)
    shards = []
    for s in (70, 50, 64, 33):
        idx = rng.choice(len(train), size=s, replace=False)
        shards.append(Dataset(train.x[idx], train.y[idx]))
    backend = CNNBackend(vgg_for("mnist"), local_epochs=1, batch_size=32)
    engine = build_cohort_engine(backend, shards, cohort_size=cohort,
                                 mesh=mesh_spec, epochs=1,
                                 kernel_policy=kernel_policy)
    assert engine is not None
    params = [backend.init(jax.random.PRNGKey(c)) for c in range(cohort)]
    return engine, params, shards


@pytest.mark.parametrize("mesh_spec", [
    None,
    pytest.param("auto", marks=pytest.mark.skipif(
        N_DEV < 2, reason="needs >=2 devices for a real clients mesh")),
    pytest.param("4x2", marks=pytest.mark.skipif(
        N_DEV < 8, reason="needs 8 devices for the 4x2 (clients, data) mesh")),
])
def test_cohort_signature_kernel_policy_bit_equal(mesh_spec):
    from repro.core.aggregate import tree_stack
    engine_ref, params, shards = _cohort_engines(mesh_spec, None)
    engine_int, _, _ = _cohort_engines(mesh_spec, "interpret")
    assert engine_ref.programs.kernel_policy == "reference"
    assert engine_int.programs.kernel_policy == "interpret"
    stacked = tree_stack(params)
    sig_ref = engine_ref.signature_cohort_stacked(stacked, shards, limit=48)
    sig_int = engine_int.signature_cohort_stacked(stacked, shards, limit=48)
    _bit_equal(sig_int, sig_ref,
               f"cohort signatures, mesh={mesh_spec}")


@pytest.mark.skipif(N_DEV < 2, reason="needs >=2 devices")
def test_cohort_signature_mesh_matches_single_device():
    """Same policy, mesh vs no mesh: the sharded kernel path must agree
    with the single-device kernel path bit-for-bit (counts are exact)."""
    from repro.core.aggregate import tree_stack
    engine_one, params, shards = _cohort_engines(None, "interpret")
    engine_mesh, _, _ = _cohort_engines("auto", "interpret")
    stacked = tree_stack(params)
    a = engine_one.signature_cohort_stacked(stacked, shards, limit=48)
    b = engine_mesh.signature_cohort_stacked(stacked, shards, limit=48)
    _bit_equal(b, a, "interpret kernel, mesh vs single-device")
