"""Per-kernel allclose vs the pure-jnp oracles: shape/dtype sweeps +
hypothesis properties (interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.selective_scan import selective_scan_bsd
from repro.kernels.signature import signature_td


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # B, H, K, S, hd, causal, window, softcap, dtype
    (2, 4, 2, 256, 64, True, -1, 0.0, jnp.float32),
    (1, 4, 4, 300, 32, True, 48, 0.0, jnp.float32),
    (2, 2, 1, 128, 64, True, -1, 30.0, jnp.float32),
    (1, 2, 2, 200, 64, False, -1, 0.0, jnp.float32),
    (1, 8, 2, 256, 128, True, 128, 50.0, jnp.float32),
    (2, 4, 2, 192, 64, True, -1, 0.0, jnp.bfloat16),
]


@pytest.mark.parametrize("B,H,K,S,hd,causal,window,cap,dtype", FLASH_CASES)
def test_flash_attention_matches_oracle(B, H, K, S, hd, causal, window, cap,
                                        dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, K, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, K, S, hd), dtype)
    out = flash_attention_bhsd(q, k, v, causal=causal, window=window,
                               softcap=cap, block_q=64, block_k=64,
                               interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                     softcap=cap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


def test_flash_block_shape_invariance():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    outs = [flash_attention_bhsd(q, k, v, block_q=bq, block_k=bk,
                                 interpret=True)
            for bq, bk in [(32, 32), (64, 128), (128, 64), (256, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-5)


def test_flash_bshd_wrapper_layout():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (2, 130, 4, 32))       # (B,S,H,hd)
    k = jax.random.normal(ks[1], (2, 130, 2, 32))
    v = jax.random.normal(ks[2], (2, 130, 2, 32))
    out = ops.flash_attention(q, k, v, interpret=True)
    expect = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# selective scan
# ---------------------------------------------------------------------------

SCAN_CASES = [
    (1, 64, 8, 4, 64),
    (2, 100, 16, 8, 32),
    (3, 37, 4, 2, 16),
]


@pytest.mark.parametrize("B,S,d_in,N,chunk", SCAN_CASES)
def test_selective_scan_matches_oracle(B, S, d_in, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    x = jax.random.normal(ks[0], (B, S, d_in))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, d_in)))
    A = -jnp.exp(jax.random.normal(ks[2], (d_in, N)) * 0.5)
    Bc = jax.random.normal(ks[3], (B, S, N))
    Cc = jax.random.normal(ks[4], (B, S, N))
    h0 = jax.random.normal(ks[5], (B, d_in, N)) * 0.1
    y, h = selective_scan_bsd(x, dt, A, Bc, Cc, h0, chunk=chunk,
                              interpret=True)
    ye, he = ref.selective_scan_seq_ref(x, dt, A, Bc, Cc, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(he),
                               rtol=1e-5, atol=1e-5)


def test_selective_scan_state_continuation():
    """Scanning two halves with carried state == scanning the whole."""
    ks = jax.random.split(jax.random.PRNGKey(4), 6)
    B, S, d_in, N = 1, 80, 8, 4
    x = jax.random.normal(ks[0], (B, S, d_in))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, d_in)))
    A = -jnp.exp(jax.random.normal(ks[2], (d_in, N)) * 0.5)
    Bc = jax.random.normal(ks[3], (B, S, N))
    Cc = jax.random.normal(ks[4], (B, S, N))
    h0 = jnp.zeros((B, d_in, N))
    y_full, h_full = selective_scan_bsd(x, dt, A, Bc, Cc, h0, chunk=16,
                                        interpret=True)
    y1, h1 = selective_scan_bsd(x[:, :40], dt[:, :40], A, Bc[:, :40],
                                Cc[:, :40], h0, chunk=16, interpret=True)
    y2, h2 = selective_scan_bsd(x[:, 40:], dt[:, 40:], A, Bc[:, 40:],
                                Cc[:, 40:], h1, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# signature
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 200), st.integers(1, 48),
       st.floats(0.0, 0.5), st.integers(0, 2 ** 31 - 1))
def test_signature_matches_oracle_property(T, d, tau, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (T, d))
    x = jnp.where(jnp.abs(x) < 0.2, 0.0, x)
    out = signature_td(x, tau=tau, block_t=32, interpret=True)
    expect = ref.signature_ref(x, tau)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-6, atol=1e-6)
    assert out.shape == (d,)
    assert float(out.min()) >= 0.0 and float(out.max()) <= 1.0


def test_signature_bucketing():
    x = jnp.concatenate([jnp.zeros((10, 8)), jnp.ones((10, 8))], axis=1)
    sig = ops.signature(x, tau=0.0, n_sig=2, interpret=True)
    np.testing.assert_allclose(np.asarray(sig), [1.0, 0.0], atol=1e-6)


# ragged T x odd d, tau=0 (exact zeros) and tau>0 (threshold band)
SIG_COUNT_CASES = [(1, 1, 0.0), (7, 13, 0.0), (100, 100, 0.05),
                   (33, 257, 0.1), (256, 64, 0.0), (5, 300, 0.05)]


@pytest.mark.parametrize("T,d,tau", SIG_COUNT_CASES)
def test_signature_td_count_mode_is_exact(T, d, tau):
    """mean=False emits EXACT integer per-channel counts — the invariant
    the dispatch layer's bit-stable bucketing is built on."""
    x = jax.random.normal(jax.random.PRNGKey(T * d), (T, d))
    x = jnp.where(jnp.abs(x) < 0.2, 0.0, x)
    counts = signature_td(x, tau=tau, block_t=32, mean=False, interpret=True)
    xn = np.asarray(x)
    expect = ((xn == 0.0) if tau <= 0.0
              else (np.abs(xn) < tau)).sum(axis=0).astype(np.float32)
    assert np.array_equal(np.asarray(counts), expect)


def test_signature_td_padding_tail_rows_excluded():
    """T not divisible by block_t: padded rows must not count as zeros."""
    x = jnp.ones((33, 8)) * 5.0           # no zeros anywhere
    out = signature_td(x, tau=0.0, block_t=32, mean=False, interpret=True)
    assert np.array_equal(np.asarray(out), np.zeros(8, np.float32))


# ---------------------------------------------------------------------------
# dispatch-layer parity: every ops wrapper, interpret vs reference policy
# ---------------------------------------------------------------------------


def _op_pair(name):
    """Build inputs + a runner f(policy) for one ops wrapper; shapes are
    deliberately ragged (odd S/d, GQA K<H, padding tails)."""
    if name == "flash_attention":
        ks = jax.random.split(jax.random.PRNGKey(31), 3)
        q = jax.random.normal(ks[0], (2, 130, 4, 32))        # (B,S,H,hd)
        k = jax.random.normal(ks[1], (2, 130, 2, 32))        # GQA K=2
        v = jax.random.normal(ks[2], (2, 130, 2, 32))
        return lambda p: ops.flash_attention(q, k, v, window=48, policy=p)
    if name == "selective_scan":
        ks = jax.random.split(jax.random.PRNGKey(32), 6)
        B, S, d_in, N = 2, 77, 8, 4
        x = jax.random.normal(ks[0], (B, S, d_in))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, d_in)))
        A = -jnp.exp(jax.random.normal(ks[2], (d_in, N)) * 0.5)
        Bc = jax.random.normal(ks[3], (B, S, N))
        Cc = jax.random.normal(ks[4], (B, S, N))
        h0 = jax.random.normal(ks[5], (B, d_in, N)) * 0.1
        return lambda p: ops.selective_scan(x, dt, A, Bc, Cc, h0, chunk=32,
                                            policy=p)
    if name == "signature":
        x = jax.random.normal(jax.random.PRNGKey(33), (45, 100))  # d%64 != 0
        x = jnp.where(jnp.abs(x) < 0.2, 0.0, x)
        return lambda p: ops.signature(x, tau=0.05, n_sig=64, policy=p)
    if name == "signature_per_channel":
        x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(34),
                                          (3, 9, 9, 11)) - 0.3)
        return lambda p: ops.signature_per_channel(x, tau=0.0, policy=p)
    if name == "slstm_scan":
        ks = jax.random.split(jax.random.PRNGKey(35), 2)
        B, S, d = 1, 50, 16
        gx = jax.random.normal(ks[0], (B, S, 4 * d))
        R = jax.random.normal(ks[1], (d, 4 * d)) * 0.05
        z = jnp.zeros((B, d))
        m0 = jnp.full((B, d), -1e30)
        return lambda p: ops.slstm_scan(gx, R, z, z, z, m0, chunk=16,
                                        policy=p)
    assert name == "mlstm_chunkwise"
    ks = jax.random.split(jax.random.PRNGKey(36), 5)
    B, S, H, dk, dv = 1, 70, 2, 16, 24
    q = jax.random.normal(ks[0], (B, S, H, dk))
    k = jax.random.normal(ks[1], (B, S, H, dk))
    v = jax.random.normal(ks[2], (B, S, H, dv))
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = jax.random.normal(ks[4], (B, S, H)) + 2.0
    return lambda p: ops.mlstm_chunkwise(q, k, v, ig, fg, chunk=32,
                                         policy=p)[0]


OP_NAMES = ["flash_attention", "selective_scan", "signature",
            "signature_per_channel", "slstm_scan", "mlstm_chunkwise"]


@pytest.mark.parametrize("name", OP_NAMES)
def test_ops_interpret_policy_matches_reference(name):
    run = _op_pair(name)
    got = run("interpret")
    expect = run("reference")
    tol = 1e-4 if name == "mlstm_chunkwise" else 1e-5
    for g, e in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(expect)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(e),
                                   rtol=tol, atol=tol)
    if name.startswith("signature"):    # Eq. 3 paths must be BIT-equal
        for g, e in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(expect)):
            assert np.array_equal(np.asarray(g), np.asarray(e))


# ---------------------------------------------------------------------------
# sLSTM recurrence kernel (R-resident, inference path)
# ---------------------------------------------------------------------------

SLSTM_CASES = [(2, 100, 32, 16), (1, 64, 16, 64), (3, 50, 8, 7)]


@pytest.mark.parametrize("B,S,d,chunk", SLSTM_CASES)
def test_slstm_kernel_matches_oracle(B, S, d, chunk):
    from repro.kernels.slstm import slstm_scan_bsd
    ks = jax.random.split(jax.random.PRNGKey(11), 2)
    gx = jax.random.normal(ks[0], (B, S, 4 * d))
    R = jax.random.normal(ks[1], (d, 4 * d)) * 0.05
    zeros = jnp.zeros((B, d))
    m0 = jnp.full((B, d), -1e30)
    hs, st = slstm_scan_bsd(gx, R, zeros, zeros, zeros, m0, chunk=chunk,
                            interpret=True)
    hs_e, st_e = ref.slstm_scan_ref(gx, R, zeros, zeros, zeros, m0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hs_e),
                               rtol=1e-5, atol=1e-5)
    for a, b in zip(st, st_e):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_slstm_kernel_state_continuation():
    from repro.kernels.slstm import slstm_scan_bsd
    ks = jax.random.split(jax.random.PRNGKey(12), 2)
    B, S, d = 1, 80, 16
    gx = jax.random.normal(ks[0], (B, S, 4 * d))
    R = jax.random.normal(ks[1], (d, 4 * d)) * 0.05
    zeros = jnp.zeros((B, d))
    m0 = jnp.full((B, d), -1e30)
    hs_full, st_full = slstm_scan_bsd(gx, R, zeros, zeros, zeros, m0,
                                      chunk=16, interpret=True)
    h1, st1 = slstm_scan_bsd(gx[:, :40], R, zeros, zeros, zeros, m0,
                             chunk=16, interpret=True)
    h2, st2 = slstm_scan_bsd(gx[:, 40:], R, *st1, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 1)),
                               np.asarray(hs_full), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# chunkwise mLSTM kernel (matrix memory in VMEM)
# ---------------------------------------------------------------------------

MLSTM_CASES = [(2, 100, 2, 16, 24, 16), (1, 64, 4, 32, 32, 64),
               (2, 50, 1, 8, 8, 13)]


@pytest.mark.parametrize("B,S,H,dk,dv,chunk", MLSTM_CASES)
def test_mlstm_kernel_matches_recurrent_oracle(B, S, H, dk, dv, chunk):
    from repro.kernels.mlstm import mlstm_chunkwise_bshd
    from repro.models.xlstm import mlstm_recurrent_ref
    ks = jax.random.split(jax.random.PRNGKey(21), 5)
    q = jax.random.normal(ks[0], (B, S, H, dk))
    k = jax.random.normal(ks[1], (B, S, H, dk))
    v = jax.random.normal(ks[2], (B, S, H, dv))
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = jax.random.normal(ks[4], (B, S, H)) + 2.0
    st0 = {"C": jnp.zeros((B, H, dk, dv)), "n": jnp.zeros((B, H, dk)),
           "m": jnp.full((B, H), -1e30)}
    h1, _ = mlstm_chunkwise_bshd(q, k, v, ig, fg, chunk=chunk,
                                 interpret=True)
    h2, _ = mlstm_recurrent_ref(q, k, v, ig, fg, st0)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-4, atol=1e-4)


def test_mlstm_kernel_matches_jax_chunkwise():
    """Kernel == the model's lax.scan chunkwise path (same formulation)."""
    from repro.kernels.mlstm import mlstm_chunkwise_bshd
    from repro.models.xlstm import mlstm_chunkwise
    ks = jax.random.split(jax.random.PRNGKey(22), 5)
    B, S, H, dk, dv = 1, 96, 2, 16, 16
    q = jax.random.normal(ks[0], (B, S, H, dk))
    k = jax.random.normal(ks[1], (B, S, H, dk))
    v = jax.random.normal(ks[2], (B, S, H, dv))
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = jax.random.normal(ks[4], (B, S, H)) + 2.0
    st0 = {"C": jnp.zeros((B, H, dk, dv)), "n": jnp.zeros((B, H, dk)),
           "m": jnp.full((B, H), -1e30)}
    h1, _ = mlstm_chunkwise_bshd(q, k, v, ig, fg, chunk=32, interpret=True)
    h2, _ = mlstm_chunkwise(q, k, v, ig, fg, st0, chunk=32)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-4, atol=1e-4)
