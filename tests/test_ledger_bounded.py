"""BoundedDAGLedger: checkpoint+prune equivalence, indexes, verification.

The load-bearing property (DESIGN.md): folding confirmed ancestry into a
checkpoint and evicting its bodies must be INVISIBLE to every consumer —
tips, reachability splits, tip selection, and path-verification verdicts
all agree with the append-only reference ledger, at any checkpoint cadence.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.dag import (GENESIS_ROOT, BoundedDAGLedger, CheckpointRecord,
                            DAGLedger, LedgerView, TxMetadata)
from repro.core.tip_selection import (FnTipEvaluator, TipSelectionConfig,
                                      TipSelectionRequest, TipSelector)
from repro.core.verify import (IncrementalVerifier, extract_path,
                               verify_checkpoints, verify_full_dag,
                               verify_path)


def meta(cid=0, epoch=0):
    return TxMetadata(client_id=cid, signature=(0.1, 0.2),
                      model_accuracy=0.5, current_epoch=epoch,
                      validation_node_id=cid)


N_CLIENTS = 6


def twin_drive(ops, seed=0, **bounded_kw):
    """Apply one append sequence to a full and a bounded ledger; ``ops`` is
    [(client_id, extra_parents, ckpt_gate)] — ckpt_gate == 0 checkpoints the
    bounded ledger after that append.  Returns (full, bounded, evicted_ids).
    """
    evicted = []
    full = DAGLedger()
    bnd = BoundedDAGLedger(evict_fn=lambda tx: evicted.append(tx.tx_id),
                           **bounded_kw)
    full.add_genesis(meta(-1, 0))
    bnd.add_genesis(meta(-1, 0))
    rng = np.random.default_rng(seed)
    t = 0.0
    for cid, extra, ck in ops:
        t += 1.0
        tips = full.tips()
        k = min(len(tips), 1 + extra)
        parents = [str(p) for p in rng.choice(tips, size=k, replace=False)]
        m = meta(cid, int(t))
        full.add_transaction(m, parents, t)
        bnd.add_transaction(m, parents, t)
        if ck == 0:
            bnd.maybe_checkpoint(now=t)
    return full, bnd, evicted


def _eval_fn(tx_id):
    return (int(tx_id[2:]) % 11) / 11.0 + 0.01


OPS = st.lists(st.tuples(st.integers(0, N_CLIENTS - 1), st.integers(0, 1),
                         st.integers(0, 3)), min_size=1, max_size=60)


# -- pruning-equivalence properties ------------------------------------------


@settings(max_examples=25, deadline=None)
@given(OPS)
def test_prune_preserves_tips(ops):
    full, bnd, evicted = twin_drive(ops)
    assert bnd.tips() == full.tips()
    assert bnd.tips_by_freshness(3) == full.tips_by_freshness(3)
    # pruned bodies are really gone, and exactly the evicted ones
    assert len(bnd) + bnd.n_pruned == len(full)
    assert set(evicted) == {tx.tx_id for tx in full.transactions()
                            if not bnd.has_tx(tx.tx_id)}


@settings(max_examples=25, deadline=None)
@given(OPS)
def test_prune_preserves_reachability_split(ops):
    """Alg. 1 parity for every client start — including starts whose body
    was pruned (confirmed => every tip transitively approves them)."""
    full, bnd, _ = twin_drive(ops)
    for cid in range(-1, N_CLIENTS):
        start = full.latest_of(cid)
        assert bnd.latest_of(cid) == start
        assert bnd.reachable_tips(start) == full.reachable_tips(start)


@settings(max_examples=20, deadline=None)
@given(OPS)
def test_prune_preserves_selection(ops):
    full, bnd, _ = twin_drive(ops)
    cfg = TipSelectionConfig(n_select=2, use_similarity=False)
    for cid in range(N_CLIENTS):
        req = TipSelectionRequest(client_id=cid, cur_epoch=3, now=100.0)
        a = TipSelector(full, None, cfg).select(req, FnTipEvaluator(_eval_fn))
        b = TipSelector(bnd, None, cfg).select(req, FnTipEvaluator(_eval_fn))
        assert [(s.tx_id, s.reachable, s.score) for s in a] == \
            [(s.tx_id, s.reachable, s.score) for s in b]


@settings(max_examples=20, deadline=None)
@given(OPS)
def test_prune_preserves_verification_verdicts(ops):
    """A trainer's stored path (extracted pre-prune, from the full ledger)
    still verifies against the pruned publisher state, and both full-DAG
    audits pass."""
    full, bnd, _ = twin_drive(ops)
    assert verify_full_dag(full) == (True, "ok")
    assert verify_full_dag(bnd) == (True, "ok")
    for tip in full.tips():
        path = extract_path(full, tip)        # crosses the pruned region
        assert verify_path(full, path) == (True, "ok")
        assert verify_path(bnd, path) == (True, "ok")


@settings(max_examples=15, deadline=None)
@given(OPS)
def test_bfs_fallback_matches_summaries(ops):
    """max_summaries=0 disables the incremental index entirely; the BFS
    fallback must produce identical splits."""
    full, bnd, _ = twin_drive(ops, max_summaries=0)
    assert bnd.stat_reach_bfs == 0            # nothing queried yet
    for cid in range(N_CLIENTS):
        start = full.latest_of(cid)
        assert bnd.reachable_tips(start) == full.reachable_tips(start)
    if any(full.latest_of(c) and bnd.has_tx(full.latest_of(c))
           for c in range(N_CLIENTS)):
        assert bnd.stat_reach_bfs > 0         # fallback actually exercised


@settings(max_examples=15, deadline=None)
@given(OPS)
def test_summary_cap_overflow_still_correct(ops):
    """summary_cap=1 drops every summary after first use; correctness must
    not depend on the cache."""
    full, bnd, _ = twin_drive(ops, summary_cap=1)
    for _ in range(2):                        # second pass hits dropped state
        for cid in range(N_CLIENTS):
            start = full.latest_of(cid)
            assert bnd.reachable_tips(start) == full.reachable_tips(start)


# -- checkpoint structure -----------------------------------------------------


def chain(led, n, cid_mod=3):
    prev = led.genesis_id
    for i in range(n):
        prev = led.add_transaction(meta(i % cid_mod, i), [prev],
                                   float(i + 1)).tx_id
    return prev


def test_checkpoint_folds_confirmed_ancestry():
    bnd = BoundedDAGLedger()
    bnd.add_genesis(meta(-1))
    tip = chain(bnd, 10)
    rec = bnd.checkpoint(now=10.0)
    # a 1-wide chain: everything but the single tip is confirmed
    assert rec is not None and rec.n_pruned == 10
    assert bnd.tips() == [tip]
    assert len(bnd) == 1 and bnd.n_pruned == 10
    assert bnd.is_pruned(bnd.genesis_id)
    assert rec.prev_root == GENESIS_ROOT
    assert verify_checkpoints(bnd) == (True, "ok")
    # second fold chains onto the first root
    prev = tip
    for i in range(3):
        prev = bnd.add_transaction(meta(i, 10 + i), [prev],
                                   float(11 + i)).tx_id
    rec2 = bnd.checkpoint(now=14.0)
    assert rec2.prev_root == rec.root
    assert [r.seq for r in bnd.checkpoints] == [0, 1]


def test_checkpoint_noop_when_nothing_confirmed():
    bnd = BoundedDAGLedger()
    bnd.add_genesis(meta(-1))
    # genesis is itself a tip: it has no PROPER ancestors, nothing confirms
    assert bnd.checkpoint(now=1.0) is None
    assert bnd.checkpoints == ()


def test_genesis_is_confirmed_once_all_tips_approve_it():
    bnd = BoundedDAGLedger()
    bnd.add_genesis(meta(-1))
    g = bnd.genesis_id
    bnd.add_transaction(meta(0, 1), [g], 1.0)
    bnd.add_transaction(meta(1, 1), [g], 1.0)
    rec = bnd.checkpoint(now=2.0)
    assert rec is not None and rec.leaf_ids == (g,)
    assert bnd.is_pruned(g)


def test_auto_checkpoint_interval():
    bnd = BoundedDAGLedger(checkpoint_interval=4)
    bnd.add_genesis(meta(-1))
    chain(bnd, 12)
    assert bnd.checkpoints                       # fired without manual calls
    assert bnd.n_pruned > 0


def test_maybe_checkpoint_min_appends():
    bnd = BoundedDAGLedger()
    bnd.add_genesis(meta(-1))
    chain(bnd, 3)
    assert bnd.maybe_checkpoint(now=1.0) is not None
    assert bnd.maybe_checkpoint(now=2.0) is None      # nothing appended since


def test_pruned_parent_still_approvable():
    """Async publish lag: a client may publish approving a tip that was
    confirmed+pruned in between selection and publish."""
    bnd = BoundedDAGLedger()
    bnd.add_genesis(meta(-1))
    tip = chain(bnd, 4)
    pruned_parent = bnd.get_tx(tip).parents[0]
    bnd.checkpoint(now=5.0)
    assert bnd.is_pruned(pruned_parent)
    tx = bnd.add_transaction(meta(5, 9), [pruned_parent], 6.0)
    assert tx.tx_id in bnd.tips()
    assert verify_full_dag(bnd) == (True, "ok")


# -- tamper detection across the pruned boundary ------------------------------


def _pruned_setup():
    bnd = BoundedDAGLedger()
    bnd.add_genesis(meta(-1))
    tip = chain(bnd, 8)
    path = extract_path(bnd, tip)                # stored BEFORE the prune
    bnd.checkpoint(now=9.0)
    victim = path.records[-2].tx_id              # deep in the pruned region
    assert bnd.is_pruned(victim)
    return bnd, path, victim


def test_tampered_checkpoint_hash_detected_by_path():
    bnd, path, victim = _pruned_setup()
    assert verify_path(bnd, path) == (True, "ok")
    bnd._tamper_pruned_hash(victim, "f" * 64)
    ok, reason = verify_path(bnd, path)
    # surfaces at the victim or at its child (whose Eq. 7 recompute pulls
    # the tampered retained parent hash) — either way the path is rejected
    assert not ok and "hash mismatch" in reason


def test_tampered_checkpoint_hash_detected_by_audit():
    bnd, _, victim = _pruned_setup()
    assert verify_checkpoints(bnd) == (True, "ok")
    bnd._tamper_pruned_hash(victim, "f" * 64)
    ok, reason = verify_checkpoints(bnd)
    assert not ok and "re-derive" in reason
    assert verify_full_dag(bnd)[0] is False


def test_forged_path_record_detected():
    """A path record claiming different metadata for a pruned tx cannot
    re-derive its own recorded hash."""
    import dataclasses
    bnd, path, _ = _pruned_setup()
    i = len(path.records) - 2
    path.records[i] = dataclasses.replace(path.records[i],
                                          metadata_digest="00" * 32)
    ok, reason = verify_path(bnd, path)
    assert not ok


# -- incremental verifier -----------------------------------------------------


def test_incremental_verifier_audits_only_new():
    led = DAGLedger()
    led.add_genesis(meta(-1))
    chain(led, 5)
    v = IncrementalVerifier(led)
    assert v.audit() == (True, "ok")
    assert v.txs_checked == 6                  # genesis + 5
    assert v.audit() == (True, "ok")
    assert v.txs_checked == 6                  # steady state: nothing new
    chain(led, 2, cid_mod=2)
    assert v.audit() == (True, "ok")
    assert v.txs_checked == 8                  # only the two appends


def test_incremental_verifier_detects_new_tamper():
    led = DAGLedger()
    led.add_genesis(meta(-1))
    chain(led, 3)
    v = IncrementalVerifier(led)
    assert v.audit() == (True, "ok")
    tip = chain(led, 1)
    led.get_tx(tip).tx_hash = "0" * 64    # tamper with the live Eq.7 hash
    ok, _ = v.audit()
    assert not ok


def test_incremental_verifier_covers_checkpoints():
    bnd = BoundedDAGLedger()
    bnd.add_genesis(meta(-1))
    chain(bnd, 6)
    v = IncrementalVerifier(bnd)
    assert v.audit() == (True, "ok")
    bnd.checkpoint(now=7.0)
    chain(bnd, 2, cid_mod=2)
    assert v.audit() == (True, "ok")
    assert v.checkpoints_checked == 1
    chain(bnd, 2, cid_mod=2)
    bnd.checkpoint(now=12.0)
    leaf = bnd.checkpoints[-1].leaf_ids[0]
    bnd._tamper_pruned_hash(leaf, "e" * 64)
    ok, _ = v.audit()
    assert not ok


# -- tx-id ordering regression ------------------------------------------------


def test_tx_ids_keep_lexicographic_order_past_one_million():
    """Regression: 6-digit padding made tx1000000 sort BEFORE tx999999,
    breaking every sorted-id iteration at the boundary."""
    led = DAGLedger()
    led.add_genesis(meta(-1))
    led._counter = 999_999
    a = led.add_transaction(meta(0, 1), [led.genesis_id], 1.0)
    b = led.add_transaction(meta(1, 1), [led.genesis_id], 2.0)
    c = led.add_transaction(meta(2, 1), [led.genesis_id], 3.0)
    assert a.tx_id < b.tx_id < c.tx_id          # lexicographic == insertion
    assert [a.tx_id, b.tx_id, c.tx_id] == sorted([b.tx_id, a.tx_id, c.tx_id])
    assert led.tips() == [a.tx_id, b.tx_id, c.tx_id]
    assert a.seq == 999_999 and b.seq == 1_000_000


# -- LedgerView conformance ---------------------------------------------------


@pytest.mark.parametrize("cls", [DAGLedger, BoundedDAGLedger])
def test_ledger_view_conformance(cls):
    led = cls()
    led.add_genesis(meta(-1))
    assert isinstance(led, LedgerView)
    g = led.genesis_id
    assert led.has_tx(g) and led.get_tx(g).tx_id == g
    assert led.hash_of(g) == led.get_tx(g).tx_hash
    assert not led.is_pruned(g)
    assert [tx.tx_id for tx in led.transactions()] == [g]
    assert isinstance(led.checkpoints, tuple)
    assert len(led) == 1


def test_checkpoint_record_is_immutable():
    with pytest.raises(Exception):
        rec = CheckpointRecord("c", 0, 0.0, 1, "r", "p", ("t",))
        rec.root = "x"
