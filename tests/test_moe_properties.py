"""MoE dispatch invariants + RoPE properties (hypothesis)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced
from repro.models.layers import apply_rope
from repro.models.moe import _topk_dispatch, init_moe, moe_forward


def _moe_cfg():
    return dataclasses.replace(reduced(get_config("deepseek-v2-236b")),
                               compute_dtype="float32")


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(2, 8), st.integers(1, 3),
       st.integers(0, 2 ** 31 - 1))
def test_dispatch_capacity_and_gates(Sg, E, k, seed):
    k = min(k, E)
    probs = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(seed), (2, Sg, E)), -1)
    cap = max(1, Sg * k // E)
    gates, dispatch = _topk_dispatch(probs, k, cap)
    d = np.asarray(dispatch)
    g = np.asarray(gates)
    # each (expert, slot) holds at most one token
    assert (d.sum(axis=1) <= 1 + 1e-6).all()
    # each token occupies at most k slots total
    assert (d.sum(axis=(2, 3)) <= k + 1e-6).all()
    # gates are a sub-probability distribution supported on dispatched experts
    assert (g >= -1e-6).all() and (g.sum(-1) <= 1 + 1e-5).all()
    assert ((g > 1e-9) <= (d.any(axis=-1))).all()


def test_dropped_tokens_produce_zero_output():
    """With capacity 0 slots available (cap tiny, forced collisions), the
    combine of a dropped token is exactly zero — not garbage."""
    cfg = _moe_cfg()
    params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    # identical tokens => identical routing => guaranteed capacity overflow
    x = jnp.ones((1, 64, cfg.d_model)) * 0.3
    out, aux = moe_forward(params, x, cfg=cfg)
    assert bool(jnp.all(jnp.isfinite(out)))
    # tokens beyond capacity get only the shared-expert contribution: all
    # rows are identical inputs, so rows are either full or shared-only
    norms = jnp.linalg.norm(out[0], axis=-1)
    assert bool(jnp.all(jnp.isfinite(norms)))


def test_load_balance_aux_penalises_collapse():
    cfg = _moe_cfg()
    E = cfg.moe.n_experts
    collapsed = jnp.zeros((1, 64, E)).at[..., 0].set(10.0)
    uniform = jnp.zeros((1, 64, E))
    from repro.models.moe import _topk_dispatch
    import repro.models.moe as M
    # construct aux manually via the same formula
    def aux_of(logits):
        probs = jax.nn.softmax(logits, -1)
        gates, dispatch = _topk_dispatch(probs, cfg.moe.top_k,
                                         max(64 * cfg.moe.top_k // E, 1))
        me = jnp.mean(probs.reshape(-1, E), axis=0)
        ce = jnp.mean(jnp.max(dispatch, -1).reshape(-1, E).astype(jnp.float32),
                      axis=0)
        return float(E * jnp.sum(me * ce))
    assert aux_of(collapsed) > aux_of(uniform)


# ---------------------------------------------------------------------------
# RoPE properties
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.integers(2, 16), st.integers(1, 4),
       st.sampled_from([32, 64, 128]), st.integers(0, 2 ** 31 - 1))
def test_rope_preserves_norm(B, S, H, hd, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    y = apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-4, atol=1e-4)


def test_rope_relative_position_property():
    """q_m . k_n depends only on (m - n) after RoPE."""
    hd = 64
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))

    def dot_at(m, n):
        pm = jnp.asarray([[m]], jnp.int32)
        pn = jnp.asarray([[n]], jnp.int32)
        qr = apply_rope(q, pm, 10000.0)
        kr = apply_rope(k, pn, 10000.0)
        return float(jnp.sum(qr * kr))

    assert dot_at(5, 3) == pytest.approx(dot_at(12, 10), rel=1e-4)
    assert dot_at(5, 3) != pytest.approx(dot_at(5, 0), rel=1e-2)


def test_mrope_text_equals_plain_rope():
    """For text streams (t=h=w), M-RoPE must reduce to plain RoPE."""
    hd = 128
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 4, hd))
    pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32)[None], (2, 8))
    plain = apply_rope(x, pos, 10000.0)
    mrope = apply_rope(x, jnp.broadcast_to(pos[None], (3, 2, 8)), 10000.0,
                       mrope_sections=(16, 24, 24))
    np.testing.assert_allclose(np.asarray(plain), np.asarray(mrope),
                               rtol=1e-5, atol=1e-5)
