"""Optimizers, schedules, checkpointing, data pipeline."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data import (make_benchmark_dataset, partition_dirichlet,
                        partition_iid, split_811, label_distribution)
from repro.optim.optimizers import (adamw, apply_updates, clip_by_global_norm,
                                    cosine_schedule, sgd, warmup_cosine)
from repro.train.checkpoint import load_checkpoint, save_checkpoint


def _optimize(opt, steps=200):
    params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(1.5)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"])) + jnp.square(p["b"])

    for _ in range(steps):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    return float(loss(params))


def test_sgd_momentum_converges():
    assert _optimize(sgd(0.05, momentum=0.9)) < 1e-3


def test_adamw_converges():
    assert _optimize(adamw(0.1)) < 1e-3


def test_adamw_bf16_moments():
    opt = adamw(0.1, moment_dtype=jnp.bfloat16)
    params = {"w": jnp.ones((4,), jnp.float32)}
    state = opt.init(params)
    assert state["m"]["w"].dtype == jnp.bfloat16
    assert _optimize(opt) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == 20.0
    total = jnp.sqrt(jnp.sum(jnp.square(clipped["a"])))
    assert float(total) <= 1.0 + 1e-5


def test_schedules():
    cos = cosine_schedule(1.0, 100, final_frac=0.1)
    assert float(cos(0)) == 1.0
    assert abs(float(cos(100)) - 0.1) < 1e-5
    wc = warmup_cosine(1.0, 10, 100)
    assert float(wc(5)) == 0.5
    assert float(wc(10)) == 1.0


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nest": {"b": jnp.asarray([1, 2], jnp.int32)},
            "lst": [jnp.asarray(2.5, jnp.float32)]}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save_checkpoint(path, tree, step=42)
        restored, step = load_checkpoint(path, tree)
    assert step == 42
    assert np.allclose(restored["a"], tree["a"])
    assert restored["nest"]["b"].dtype == jnp.int32
    assert float(restored["lst"][0]) == 2.5


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_split_811():
    ds = make_benchmark_dataset("mnist", n_samples=1000)
    s = split_811(ds)
    assert len(s["train"]) == 800 and len(s["val"]) == 100
    assert len(s["test"]) == 100


def test_iid_partition_balanced():
    ds = make_benchmark_dataset("mnist", n_samples=1000)
    parts = partition_iid(ds, 10)
    sizes = [len(p) for p in parts]
    assert max(sizes) - min(sizes) <= 1


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 1000))
def test_dirichlet_skew_increases_as_beta_drops(seed):
    ds = make_benchmark_dataset("cifar10", n_samples=2000, seed=seed % 7)
    n_classes = int(ds.y.max()) + 1

    def skew(beta):
        parts = partition_dirichlet(ds, 8, beta, seed=seed)
        dist = label_distribution(parts, n_classes)
        dist = dist / np.maximum(dist.sum(axis=1, keepdims=True), 1)
        # mean max-class share: 1/n_classes (uniform) .. 1.0 (one class)
        return float(np.mean(dist.max(axis=1)))

    assert skew(0.05) > skew(100.0) - 0.05


def test_dirichlet_no_empty_clients():
    ds = make_benchmark_dataset("mnist", n_samples=500)
    parts = partition_dirichlet(ds, 10, beta=0.05, seed=3)
    assert all(len(p) >= 8 for p in parts)


def test_dirichlet_topup_never_duplicates_within_client():
    """Regression (ISSUE 4): the min_per_client top-up used to sample
    global indices WITH replacement, so a starved client could hold the
    same row twice.  Skewed tiny worlds force the top-up for many clients;
    every client's rows must be unique (cross-client overlap from the
    top-up pool remains legal — see the docstring)."""
    for seed in range(4):
        ds = make_benchmark_dataset("mnist", n_samples=60, seed=seed)
        parts = partition_dirichlet(ds, 12, beta=0.05, seed=seed)
        assert all(len(p) >= 5 for p in parts)    # small pool: best effort
        for k, p in enumerate(parts):
            rows = p.x.reshape(len(p), -1)
            uniq = np.unique(np.round(rows, 6), axis=0)
            assert len(uniq) == len(rows), \
                f"client {k} holds duplicate rows (seed {seed})"


def test_datasets_are_learnable_and_distinct():
    easy = make_benchmark_dataset("mnist", n_samples=400)
    hard = make_benchmark_dataset("cifar100", n_samples=400)
    assert int(hard.y.max()) + 1 > int(easy.y.max()) + 1
    assert easy.x.shape[-1] == 1 and hard.x.shape[-1] == 3
