"""Tests for the repro-lint static analyzer (tools/repro_lint).

Three layers:

* per-rule fixture pairs: every registered rule fires on its ``*_flagged.py``
  fixture and stays silent on ``*_clean.py``;
* engine behaviour: suppression comments, rule selection, syntax-error
  reporting, output formats, CLI exit codes;
* the meta-test: the analyzer runs clean over the whole repo
  (``src tests benchmarks``), which is the invariant CI enforces.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from tools.repro_lint import all_rules, lint_paths, lint_source
from tools.repro_lint.output import format_findings

REPO_ROOT = Path(__file__).resolve().parent.parent
TESTDATA = REPO_ROOT / "tools" / "repro_lint" / "testdata"

# Path-scoped rules are linted as-if the fixture lived at this relative path.
VIRTUAL_PATHS = {"DET003": "src/repro/core/fixture.py",
                 "KER001": "src/repro/fl/fixture.py",
                 "SRV001": "src/repro/fl/fixture.py"}

RULES = all_rules()
RULE_IDS = [r.id for r in RULES]


def _fixture(rule, kind):
    path = TESTDATA / f"{rule.name.replace('-', '_')}_{kind}.py"
    assert path.exists(), f"missing fixture for {rule.id}: {path}"
    return path


def _lint_fixture(rule, kind):
    path = _fixture(rule, kind)
    rel = VIRTUAL_PATHS.get(rule.id, str(path.relative_to(REPO_ROOT)))
    return lint_source(path.read_text(), path=str(path), rel_path=rel,
                       select={rule.id.lower()})


# ---------------------------------------------------------------------------
# registry shape
# ---------------------------------------------------------------------------


def test_registry_has_required_coverage():
    assert len(RULES) >= 9
    assert len(set(RULE_IDS)) == len(RULE_IDS), "duplicate rule ids"
    families = {r.family for r in RULES}
    # Determinism, JAX purity/perf, and API hygiene must all be represented.
    assert "determinism" in families
    assert families & {"jax-purity", "jax-perf"}
    assert "api-hygiene" in families


# ---------------------------------------------------------------------------
# per-rule fixture pairs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rule", RULES, ids=RULE_IDS)
def test_rule_fires_on_flagged_fixture(rule):
    findings = _lint_fixture(rule, "flagged")
    assert findings, f"{rule.id} did not fire on its flagged fixture"
    assert all(f.rule_id == rule.id for f in findings)
    assert all(f.line >= 1 and f.col >= 0 for f in findings)


@pytest.mark.parametrize("rule", RULES, ids=RULE_IDS)
def test_rule_silent_on_clean_fixture(rule):
    findings = _lint_fixture(rule, "clean")
    assert findings == [], (
        f"{rule.id} false-positived on its clean fixture: "
        + "; ".join(f.render() for f in findings)
    )


# ---------------------------------------------------------------------------
# suppression comments
# ---------------------------------------------------------------------------


def test_same_line_suppression_by_name_and_id():
    base = "x = hash('dataset-name')"
    assert lint_source(base, path="<t>", select={"det001"})
    for tag in ("builtin-hash", "DET001", "all"):
        src = f"{base}  # repro-lint: disable={tag}"
        assert lint_source(src, path="<t>", select={"det001"}) == [], tag


def test_suppression_only_covers_its_line():
    src = (
        "a = hash('one')  # repro-lint: disable=builtin-hash\n"
        "b = hash('two')\n"
    )
    findings = lint_source(src, path="<t>", select={"det001"})
    assert [f.line for f in findings] == [2]


def test_file_level_suppression():
    src = (
        "# repro-lint: disable-file=builtin-hash\n"
        "a = hash('one')\n"
        "b = hash('two')\n"
    )
    assert lint_source(src, path="<t>", select={"det001"}) == []


# ---------------------------------------------------------------------------
# engine behaviour
# ---------------------------------------------------------------------------


def test_syntax_error_becomes_finding():
    findings = lint_source("def broken(:\n", path="<t>")
    assert len(findings) == 1
    assert findings[0].rule_id == "E000"


def test_select_limits_rules():
    src = "import numpy as np\nx = np.random.rand(3)\ny = hash('k')\n"
    only_hash = lint_source(src, path="<t>", select={"det001"})
    assert {f.rule_id for f in only_hash} == {"DET001"}
    both = lint_source(src, path="<t>")
    assert {"DET001", "DET002"} <= {f.rule_id for f in both}


def test_output_formats():
    findings = lint_source("x = hash('k')\n", path="tools/x.py",
                           rel_path="tools/x.py", select={"det001"})
    text = format_findings(findings, "text", n_files=1)
    assert "DET001" in text and "tools/x.py:1:" in text
    payload = json.loads(format_findings(findings, "json", n_files=1))
    assert payload["checked_files"] == 1
    assert len(payload["findings"]) == 1
    assert payload["findings"][0]["rule"] == "DET001"
    gh = format_findings(findings, "github", n_files=1)
    assert gh.startswith("::error file=tools/x.py,line=1,")


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _run_cli(*argv):
    env = dict(os.environ)
    return subprocess.run(
        [sys.executable, "-m", "tools.repro_lint", *argv],
        cwd=REPO_ROOT, env=env, capture_output=True, text=True)


def test_cli_exit_codes_and_json():
    flagged = TESTDATA / "builtin_hash_flagged.py"
    clean = TESTDATA / "builtin_hash_clean.py"
    bad = _run_cli("--select", "det001", "--format", "json", str(flagged))
    assert bad.returncode == 1, bad.stderr
    payload = json.loads(bad.stdout)
    assert len(payload["findings"]) >= 1
    good = _run_cli("--select", "det001", str(clean))
    assert good.returncode == 0, good.stderr


def test_cli_list_rules():
    out = _run_cli("--list-rules")
    assert out.returncode == 0
    for rid in RULE_IDS:
        assert rid in out.stdout


# ---------------------------------------------------------------------------
# meta-test: the repo itself lints clean
# ---------------------------------------------------------------------------


def test_repo_lints_clean():
    roots = [REPO_ROOT / d for d in ("src", "tests", "benchmarks")]
    findings, n_files = lint_paths([str(r) for r in roots])
    assert n_files >= 80, f"unexpectedly few files linted: {n_files}"
    assert findings == [], "repo must lint clean:\n" + "\n".join(
        f.render() for f in findings)
