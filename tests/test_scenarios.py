"""Fault-injection scenario layer (repro/fl/scenarios.py).

The load-bearing property: a scenario whose rates are all zero is
BIT-IDENTICAL to the honest run (scenario=None) on both the coordinator and
the baselines, and fault-event counts at a fixed seed are deterministic —
independent of the execution engine (sequential vs cohort-batched).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.cnn import vgg_for
from repro.core.coordinator import DagAflConfig, DagAflCoordinator
from repro.core.simulator import CostModel, make_profiles
from repro.data import make_benchmark_dataset, partition_dirichlet, split_811
from repro.fl import (CNNBackend, FLConfig, SCENARIOS, Scenario,
                      ScenarioConfig, run_fedavg, run_fedasync)
from repro.fl.cohort import perturb_cohort_stacked_trees, perturb_update

ZERO = ScenarioConfig(name="zero", seed=0)


# -- unit level ---------------------------------------------------------------


def test_roles_deterministic_and_disjoint():
    cfg = ScenarioConfig(name="x", seed=3, malicious_frac=0.25,
                         lazy_frac=0.25, straggler_frac=0.25)
    a, b = Scenario(cfg, 8), Scenario(cfg, 8)
    assert a.malicious == b.malicious and a.lazy == b.lazy
    assert a.stragglers == b.stragglers
    assert len(a.malicious) == len(a.lazy) == len(a.stragglers) == 2
    assert not (a.malicious & a.lazy)
    other = Scenario(dataclasses.replace(cfg, seed=4), 8)
    assert (other.malicious, other.lazy) != (a.malicious, a.lazy)


def test_update_plan_none_when_honest():
    sc = Scenario(ZERO, 4)
    assert sc.update_plan([0, 1, 2, 3]) is None
    assert sc.counts()["updates_scaled"] == 0


def test_update_plan_coefficients():
    cfg = ScenarioConfig(name="mix", seed=0, malicious_frac=0.25,
                         attack="scale", scale_gamma=-3.0,
                         lazy_frac=0.25, lazy_mode="copy", dp_sigma=0.01)
    sc = Scenario(cfg, 8)
    clients = list(range(8))
    plan = sc.update_plan(clients)
    assert plan is not None and plan["affected"].all()   # dp hits everyone
    for k, c in enumerate(clients):
        assert plan["sigmas"][k] == np.float32(0.01)
        if c in sc.malicious:
            assert plan["gammas"][k] == np.float32(-3.0)
        elif c in sc.lazy:
            assert plan["gammas"][k] == 0.0
        else:
            assert plan["gammas"][k] == 1.0
    # per-client seq advances across dispatches
    plan2 = sc.update_plan(clients)
    assert (plan2["seqs"] == plan["seqs"] + 1).all()


def test_poison_data_flips_only_malicious():
    cfg = ScenarioConfig(name="p", seed=0, malicious_frac=0.5,
                         attack="label_flip")
    sc = Scenario(cfg, 4)
    data = []
    for c in range(4):
        ds = make_benchmark_dataset("mnist", n_samples=40, seed=c)
        data.append({"train": ds, "val": ds, "test": ds})
    out = sc.poison_data(data)
    n_classes = 1 + max(int(np.asarray(d["train"].y).max()) for d in data)
    for c in range(4):
        if c in sc.malicious:
            assert (np.asarray(out[c]["train"].y)
                    == n_classes - 1 - np.asarray(data[c]["train"].y)).all()
            assert (np.asarray(out[c]["val"].y)
                    == n_classes - 1 - np.asarray(data[c]["val"].y)).all()
        else:
            assert out[c] is data[c]       # honest shards untouched objects
    assert sc.counts()["clients_poisoned"] == len(sc.malicious)


def test_duration_multiplier_and_dropout_streams():
    cfg = ScenarioConfig(name="s", seed=1, straggler_frac=0.5,
                         dropout_rate=0.5)
    a, b = Scenario(cfg, 4), Scenario(cfg, 4)
    for c in range(4):
        for _ in range(5):
            mult = a.duration_multiplier(c)
            assert mult == b.duration_multiplier(c)
            assert a.drops_publish(c) == b.drops_publish(c)
            if c not in a.stragglers:
                assert mult == 1.0
            else:
                assert mult > 1.0
    assert a.counts() == b.counts()
    assert a.counts()["publishes_dropped"] > 0


# -- perturb programs (cohort engine) ----------------------------------------


def _toy_trees(k=3, seed=0):
    rng = np.random.default_rng(seed)
    def tree(i):
        return {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
                "b": jnp.asarray(rng.normal(size=(3,)), jnp.float32)}
    news = [tree(i) for i in range(k)]
    aggs = [tree(i + 10) for i in range(k)]
    return news, aggs


def test_perturb_single_vs_stacked_bitwise_parity():
    news, aggs = _toy_trees(3)
    plan = {"seed": 7, "clients": np.array([2, 0, 5]),
            "seqs": np.array([0, 3, 1]),
            "gammas": np.array([-4.0, 0.0, 1.0], np.float32),
            "sigmas": np.array([0.0, 0.02, 0.05], np.float32),
            "affected": np.array([True, True, True])}
    from repro.core.aggregate import tree_stack, tree_unstack
    stacked = perturb_cohort_stacked_trees(tree_stack(aggs),
                                           tree_stack(news), plan)
    rows = tree_unstack(stacked)
    for k in range(3):
        single = perturb_update(aggs[k], news[k], plan, k)
        for a, b in zip(jax.tree_util.tree_leaves(single),
                        jax.tree_util.tree_leaves(rows[k])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_perturb_unaffected_rows_keep_exact_bits():
    news, aggs = _toy_trees(3)
    plan = {"seed": 0, "clients": np.array([0, 1, 2]),
            "seqs": np.zeros(3, np.int64),
            "gammas": np.array([-4.0, 1.0, 1.0], np.float32),
            "sigmas": np.zeros(3, np.float32),
            "affected": np.array([True, False, False])}
    from repro.core.aggregate import tree_stack, tree_unstack
    rows = tree_unstack(perturb_cohort_stacked_trees(
        tree_stack(aggs), tree_stack(news), plan))
    for k in (1, 2):
        for a, b in zip(jax.tree_util.tree_leaves(news[k]),
                        jax.tree_util.tree_leaves(rows[k])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    changed = jax.tree_util.tree_leaves(rows[0])
    orig = jax.tree_util.tree_leaves(news[0])
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(changed, orig))


# -- end-to-end: zero-rate bit-identity + engine-independent counts ----------


@pytest.fixture(scope="module")
def world():
    ds = make_benchmark_dataset("mnist", n_samples=900, seed=0)
    splits = split_811(ds)
    parts = partition_dirichlet(splits["train"], 3, beta=0.5, seed=0)
    client_data = []
    for p in parts:
        s = split_811(p, seed=1)
        client_data.append({"train": s["train"], "val": s["val"],
                            "test": s["test"]})
    backend = CNNBackend(vgg_for("mnist"), local_epochs=1, batch_size=32)
    return backend, client_data, splits


def _run_dagafl(world, scenario, cohort_size=1):
    backend, client_data, splits = world
    cfg = DagAflConfig(n_clients=3, max_rounds=2, local_epochs=1, seed=0,
                       cohort_size=cohort_size, scenario=scenario,
                       target_accuracy=None, patience=100)
    coord = DagAflCoordinator(backend, client_data, splits["test"], cfg,
                              CostModel(local_epoch=2.0),
                              make_profiles(3, 0.5, 0))
    return coord, coord.run()


def test_zero_rate_scenario_bit_identical_dagafl(world):
    _, honest = _run_dagafl(world, None)
    _, zeroed = _run_dagafl(world, ZERO)
    assert zeroed.final_accuracy == honest.final_accuracy
    assert zeroed.sim_time == honest.sim_time
    assert zeroed.extra["chain_len"] == honest.extra["chain_len"]
    assert zeroed.extra["scenario_counts"] == {
        k: 0 for k in zeroed.extra["scenario_counts"]}


def test_zero_rate_scenario_bit_identical_baselines(world):
    backend, client_data, splits = world
    cost, profiles = CostModel(local_epoch=2.0), make_profiles(3, 0.5, 0)
    for algo in (run_fedavg, run_fedasync):
        honest = algo(backend, client_data, splits["test"],
                      FLConfig(n_clients=3, max_rounds=2, local_epochs=1,
                               seed=0), cost, profiles)
        zeroed = algo(backend, client_data, splits["test"],
                      FLConfig(n_clients=3, max_rounds=2, local_epochs=1,
                               seed=0, scenario=ZERO), cost, profiles)
        assert zeroed.final_accuracy == honest.final_accuracy
        assert zeroed.sim_time == honest.sim_time


def test_poison_counts_engine_independent(world):
    """Per-client RNG sequencing makes fault-event counts a function of the
    seed only — the cohort engine must report the same counts as the
    sequential path (trajectories may differ; counts may not)."""
    cfg = dataclasses.replace(SCENARIOS["poison"], seed=0)
    sc_seq = Scenario(cfg, 3)
    _run_dagafl(world, sc_seq)
    sc_coh = Scenario(cfg, 3)
    _run_dagafl(world, sc_coh, cohort_size=3)
    assert sc_seq.counts() == sc_coh.counts()
    assert sc_seq.counts()["updates_scaled"] > 0


def test_dropout_aborts_publishes(world):
    sc = Scenario(ScenarioConfig(name="d", seed=0, dropout_rate=1.0), 3)
    coord, res = _run_dagafl(world, sc)
    # every publish dropped: only genesis on the ledger, all attempts spent
    assert res.extra["chain_len"] == 1
    assert sc.counts()["publishes_dropped"] == 3 * 2     # clients x rounds
    assert res.rounds == 0


def test_lazy_stale_republishes_previous_model(world):
    sc = Scenario(ScenarioConfig(name="l", seed=0, lazy_frac=1.0,
                                 lazy_mode="stale"), 3)
    coord, res = _run_dagafl(world, sc)
    # round 1 has nothing to replay; round 2 republishes round 1's model
    assert sc.counts()["updates_lazy"] == 3
    for c in range(3):
        txs = [t for t in coord.ledger.transactions()
               if t.metadata.client_id == c]
        assert len(txs) == 2
        m0 = coord.store.get(txs[0].model_ref)
        m1 = coord.store.get(txs[1].model_ref)
        for a, b in zip(jax.tree_util.tree_leaves(m0),
                        jax.tree_util.tree_leaves(m1)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- interaction with live-traffic serving (repro/fl/serving.py) -------------


def _run_dagafl_serving(world, scenario, query_rate=1.0):
    from repro.fl.serving import ServingConfig
    backend, client_data, splits = world
    cfg = DagAflConfig(n_clients=3, max_rounds=2, local_epochs=1, seed=0,
                       scenario=scenario, target_accuracy=None, patience=100,
                       serving=ServingConfig(every=2.0, query_rate=query_rate,
                                             query_batch=8, backend="cnn",
                                             seed=99))
    coord = DagAflCoordinator(backend, client_data, splits["test"], cfg,
                              CostModel(local_epoch=2.0),
                              make_profiles(3, 0.5, 0))
    return coord, coord.run()


def test_poison_replicas_preserve_honest_floor(world):
    """A poisoning minority must not collapse what the serving layer hands
    out: the final replica stays a faithful Eq. 6 aggregate and its
    test accuracy stays within the robustness-gate floor of the honest
    run's replica."""
    from repro.fl.serving import replica_parity
    backend, _, splits = world
    coord_h, res_h = _run_dagafl_serving(world, None)
    sc = Scenario(dataclasses.replace(SCENARIOS["poison"], seed=0), 3)
    coord_p, res_p = _run_dagafl_serving(world, sc)
    assert sc.counts()["updates_scaled"] > 0      # the attack actually ran
    for coord, res in ((coord_h, res_h), (coord_p, res_p)):
        serving = res.extra["serving"]
        assert serving["queries"] > 0 and serving["skipped"] == 0
        assert replica_parity(coord.publisher.replica(), coord.store)
    acc_h = backend.evaluate(coord_h.publisher.replica().params,
                             splits["test"])
    acc_p = backend.evaluate(coord_p.publisher.replica().params,
                             splits["test"])
    # mirror of the robustness benchmark's poison accuracy-floor gate
    assert acc_h - acc_p <= 0.6, (acc_h, acc_p)


def test_dropout_never_stalls_publication(world):
    """Total dropout leaves only genesis on the ledger — the publisher must
    still bring up replica v0 and keep serving it (noop ticks), with no
    query ever finding an absent replica."""
    sc = Scenario(ScenarioConfig(name="d", seed=0, dropout_rate=1.0), 3)
    coord, res = _run_dagafl_serving(world, sc)
    assert res.extra["chain_len"] == 1            # genesis only
    serving = res.extra["serving"]
    assert serving["replica_versions"] == 1       # v0, never superseded
    assert serving["publishes_noop"] >= 1         # cadence kept ticking
    assert serving["queries"] > 0
    assert serving["skipped"] == 0
    assert serving["replica_version_hist"] == {"0": serving["queries"]}
    assert serving["max_seq_lag"] == 0            # frontier never moved
    rep = coord.publisher.replica()
    assert rep.version == 0
    assert rep.frontier == (coord.ledger.genesis_id,)


def test_straggler_never_stalls_publication(world):
    """Heavy-tailed round durations stretch simulated time but must not
    delay or starve publication: queries keep landing on live replicas."""
    sc = Scenario(dataclasses.replace(SCENARIOS["straggler"], seed=0,
                                      straggler_frac=0.5), 3)
    coord, res = _run_dagafl_serving(world, sc, query_rate=0.5)
    assert sc.stragglers                          # at least one straggler
    assert res.rounds > 0
    serving = res.extra["serving"]
    assert serving["queries"] > 0 and serving["skipped"] == 0
    assert serving["replica_versions"] >= 1
    from repro.fl.serving import replica_parity
    assert replica_parity(coord.publisher.replica(), coord.store)
