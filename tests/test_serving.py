"""Live-traffic consensus serving (repro/fl/serving.py).

The load-bearing properties:

* a query NEVER observes a half-written replica — whatever the interleaving
  of publish cadence and round arrivals, the replica's params always equal
  a fresh Eq. 6 aggregate over its OWN pinned refs (double-buffered swap);
* same seed + config => identical replica-version sequence, frontier
  tx-id sets and staleness counters (the serve gate pins these);
* serving is read-only: the training trajectory is bit-identical with the
  publisher + query stream on or off;
* refs pinned by a live replica survive bounded-ledger pruning and are
  evicted on the first swap that unpins them;
* concurrent recurring streams (publisher cadence + query stream +
  checkpoint cadence) never keep a drained simulation alive.

Most tests run against a synthetic ledger world (tiny numpy pytrees, no
training) so the event-loop logic is exercised densely and fast; the
read-only bit-identity test runs the real CNN coordinator.
"""
import numpy as np
import pytest

from tests._hypothesis_fallback import install as _install_hypothesis

_install_hypothesis()

from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.dag import (BoundedDAGLedger, DAGLedger, ModelStore,
                            TxMetadata)  # noqa: E402
from repro.core.simulator import EventLoop  # noqa: E402
from repro.fl.serving import (ConsensusPublisher, QueryStream,
                              ServingConfig, consensus_over_refs,
                              make_query_driver, replica_parity,
                              trees_bitwise_equal)  # noqa: E402


def _meta(cid, epoch=0):
    return TxMetadata(client_id=cid, signature=(0.0,) * 16,
                      model_accuracy=0.5, current_epoch=epoch,
                      validation_node_id=cid)


def _model(v: float):
    return {"w": np.full(3, float(v), np.float32),
            "b": np.array([float(v) * 2.0], np.float32)}


class _World:
    """Synthetic training world: appends distinct-valued models on a
    schedule, no JAX, no backend."""

    def __init__(self, bounded=False, checkpoint_interval=0):
        self.loop = EventLoop()
        self.store = ModelStore()
        self.evicted = []
        if bounded:
            self.ledger = BoundedDAGLedger(
                checkpoint_interval=checkpoint_interval,
                evict_fn=self._on_prune)
        else:
            self.ledger = DAGLedger()
        self.publisher = None
        ref = self.store.put("genesis", _model(0.0))
        self.ledger.add_genesis(_meta(-1), 0.0, ref)
        self._next_val = 1.0

    def _on_prune(self, tx):
        # the coordinator's _evict_model chokepoint, miniaturized
        if self.publisher is not None and \
                self.publisher.guard_evict(tx.model_ref):
            return
        self.store.evict(tx.model_ref)
        self.evicted.append(tx.model_ref)

    def append(self, client: int, parents=None) -> str:
        """One 'round completion': publish a fresh distinct model approving
        ``parents`` (default: every current tip)."""
        v = self._next_val
        self._next_val += 1.0
        ref = self.store.put(f"m{int(v):06d}", _model(v))
        if parents is None:
            parents = tuple(self.ledger.tips()) or (self.ledger.genesis_id,)
        tx = self.ledger.add_transaction(_meta(client), tuple(parents),
                                         self.loop.now, ref)
        return tx.tx_id

    def schedule_appends(self, times, clients=None):
        for i, t in enumerate(times):
            c = clients[i] if clients is not None else i % 3
            self.loop.schedule(t, lambda c=c: self.append(c))


class _ProbeDriver:
    """Query driver that asserts replica integrity on every serve."""

    def __init__(self, store):
        self.store = store
        self.queries = 0
        self.versions = []

    def serve(self, replica):
        # params must be the Eq. 6 aggregate over the replica's OWN refs —
        # a half-written or mixed-frontier replica fails this bitwise check
        assert trees_bitwise_equal(
            replica.params, consensus_over_refs(self.store,
                                                replica.model_refs))
        assert len(replica.frontier) == len(replica.model_refs) > 0
        self.versions.append(replica.version)
        self.queries += 1
        return {}

    def report(self):
        return {"driver": "probe"}


# -- event-loop stream plumbing ----------------------------------------------


def test_schedule_stream_draws_one_gap_at_a_time():
    loop = EventLoop()
    rng = np.random.default_rng(0)
    fired = []
    loop.schedule(10.0, lambda: None)          # real work keeping it alive
    loop.schedule_stream(lambda: rng.exponential(2.0),
                         lambda: fired.append(loop.now))
    loop.run()
    # gaps must equal the rng's sequential draws exactly
    ref = np.random.default_rng(0)
    t, expect = 0.0, []
    while True:
        t += ref.exponential(2.0)
        if t > 10.0 and expect:
            # stream events after the last real event do fire once armed,
            # but no re-arm happens once only stream ticks remain
            break
        expect.append(t)
    assert fired[:len(expect)] == pytest.approx(expect)


def test_two_streams_do_not_keep_drained_loop_alive():
    """Publisher cadence + query stream must not ping-pong forever after
    the last real event."""
    loop = EventLoop()
    a, b = [], []
    loop.schedule(5.0, lambda: None)           # the only real work
    loop.schedule_every(1.0, lambda: a.append(loop.now))
    loop.schedule_every(1.3, lambda: b.append(loop.now))
    loop.run(max_events=10_000)
    # both streams stop shortly after the real event drains
    assert loop.now < 10.0
    assert all(t <= loop.now for t in a + b)
    assert len(a) + len(b) < 20


def test_schedule_every_still_rejects_nonpositive_interval():
    loop = EventLoop()
    with pytest.raises(ValueError):
        loop.schedule_every(0.0, lambda: None)


def test_head_seq_advances_once_per_append_and_survives_pruning():
    w = _World(bounded=True)
    assert w.ledger.head_seq() == 0            # genesis
    ids = [w.append(c) for c in (0, 1, 2, 0, 1, 2)]
    assert w.ledger.head_seq() == 6
    w.ledger.checkpoint(now=1.0)
    assert w.ledger.n_pruned > 0
    assert w.ledger.head_seq() == 6            # monotone across pruning
    w.append(0)
    assert w.ledger.head_seq() == 7
    assert ids[0] == "tx000000000001"


# -- publisher ---------------------------------------------------------------


def test_publish_noop_when_frontier_unchanged():
    w = _World()
    pub = ConsensusPublisher(w.ledger, w.store, w.loop, every=1.0)
    assert pub.publish() is not None           # v0: genesis frontier
    assert pub.publish() is None               # nothing appended
    assert (pub.publishes, pub.publishes_noop) == (1, 1)
    rep = pub.replica()
    assert rep.version == 0 and rep.frontier == (w.ledger.genesis_id,)
    w.append(0)
    rep2 = pub.publish()
    assert rep2 is not None and rep2.version == 1
    assert pub.replica() is rep2               # swap flipped the buffer
    assert rep.params is not None              # old replica left intact


def test_replica_is_exact_eq6_aggregate():
    w = _World()
    g = w.ledger.genesis_id
    for c in (0, 1, 2):                        # three branches off genesis
        w.append(c, parents=(g,))
    pub = ConsensusPublisher(w.ledger, w.store, w.loop, every=1.0)
    rep = pub.publish()
    assert set(rep.frontier) == set(w.ledger.tips())
    assert replica_parity(rep, w.store)
    # distinct models 1..3 at the tips: the aggregate is their plain mean
    np.testing.assert_array_equal(np.asarray(rep.params["w"]),
                                  np.full(3, 2.0, np.float32))


def test_eviction_protection_pins_replica_refs_until_swap():
    w = _World(bounded=True)
    pub = ConsensusPublisher(w.ledger, w.store, w.loop, every=1.0)
    w.publisher = pub
    g = w.ledger.genesis_id
    for c in (0, 1, 2):                        # three branches off genesis
        w.append(c, parents=(g,))
    rep1 = pub.publish()                       # pins the 3-tip frontier
    # two more generations confirm the old frontier; pruning now hits refs
    # rep1 still pins
    for c in (0, 1, 2, 0, 1, 2):
        w.append(c)
    w.ledger.checkpoint(now=2.0)
    assert w.ledger.n_pruned > 0
    pinned = set(rep1.model_refs) & set(pub._deferred)
    assert pinned, "checkpoint never tried to evict a pinned replica ref"
    for r in rep1.model_refs:
        assert r in w.store                    # protected while live
    pub.publish()                              # swap 1: rep1 in back buffer
    for r in rep1.model_refs:
        assert r in w.store                    # back slot still pins
    w.append(0)
    pub.publish()                              # swap 2: rep1 fully unpinned
    for r in pinned:
        assert r not in w.store                # released and evicted
    assert pub.evictions_released >= len(pinned)
    assert pub.evictions_deferred >= len(pinned)


def test_publisher_start_publishes_v0_immediately():
    w = _World()
    pub = ConsensusPublisher(w.ledger, w.store, w.loop, every=5.0)
    w.schedule_appends([1.0, 2.0, 9.0])
    probe = _ProbeDriver(w.store)
    qs = QueryStream(pub, probe, w.loop, w.ledger, query_rate=1.0, seed=7)
    pub.start()
    qs.start()
    assert pub.replica() is not None           # before any event ran
    w.loop.run()
    assert qs.skipped == 0
    assert probe.queries == qs.queries > 0
    assert probe.versions == sorted(probe.versions)  # versions monotone


def test_publisher_rejects_nonpositive_cadence():
    w = _World()
    with pytest.raises(ValueError):
        ConsensusPublisher(w.ledger, w.store, w.loop, every=0.0)
    with pytest.raises(ValueError):
        QueryStream(ConsensusPublisher(w.ledger, w.store, w.loop, 1.0),
                    _ProbeDriver(w.store), w.loop, w.ledger,
                    query_rate=0.0, seed=0)


# -- atomicity under randomized interleavings (satellite 2) ------------------


@settings(max_examples=15, deadline=None)
@given(st.floats(0.3, 4.0),
       st.lists(st.floats(0.1, 12.0), min_size=1, max_size=14),
       st.integers(0, 2 ** 20),
       st.booleans())
def test_replica_never_mixes_frontiers(every, arrival_times, seed, bounded):
    """Whatever the publish-cadence / round-arrival interleaving, every
    query sees a replica whose params are EXACTLY the Eq. 6 aggregate of
    its own frontier refs — never a mixture of two frontiers."""
    w = _World(bounded=bounded, checkpoint_interval=4 if bounded else 0)
    pub = ConsensusPublisher(w.ledger, w.store, w.loop, every=every)
    w.publisher = pub
    w.schedule_appends(sorted(arrival_times))
    probe = _ProbeDriver(w.store)
    qs = QueryStream(pub, probe, w.loop, w.ledger, query_rate=2.0, seed=seed)
    pub.start()
    qs.start()
    w.loop.run(max_events=50_000)
    assert qs.skipped == 0
    assert probe.versions == sorted(probe.versions)
    # staleness lags are measured at arrival and never negative
    assert all(l >= 0 for l in qs.seq_lags)
    assert all(t >= 0.0 for t in qs.time_lags)
    # version accounting closes: every served version was published
    assert set(qs.version_hist) <= set(range(pub.publishes))


# -- determinism (satellite 2) ----------------------------------------------


def _run_synthetic(seed: int, every=1.7, rate=1.5, bounded=True):
    w = _World(bounded=bounded, checkpoint_interval=0)
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(1.0, size=12))
    swaps = []
    pub = ConsensusPublisher(
        w.ledger, w.store, w.loop, every=every,
        on_swap=lambda r: swaps.append((r.version, r.frontier,
                                        r.ledger_seq, r.published_at)))
    w.publisher = pub
    if bounded:
        w.loop.schedule_every(
            2.5, lambda: w.ledger.maybe_checkpoint(now=w.loop.now))
    w.schedule_appends(times.tolist())
    probe = _ProbeDriver(w.store)
    qs = QueryStream(pub, probe, w.loop, w.ledger, query_rate=rate,
                     seed=seed + 1)
    pub.start()
    qs.start()
    w.loop.run(max_events=50_000)
    return swaps, qs.report(), pub.report()


def test_same_seed_same_replica_sequence_and_counters():
    swaps_a, qrep_a, prep_a = _run_synthetic(3)
    swaps_b, qrep_b, prep_b = _run_synthetic(3)
    assert swaps_a == swaps_b                  # versions, frontiers, seqs
    assert prep_a == prep_b
    drop = ("query_wall_s", "queries_per_s")
    assert {k: v for k, v in qrep_a.items() if k not in drop} == \
           {k: v for k, v in qrep_b.items() if k not in drop}


def test_different_seed_different_trace():
    _, qrep_a, _ = _run_synthetic(3)
    _, qrep_b, _ = _run_synthetic(4)
    assert (qrep_a["arrivals"] != qrep_b["arrivals"]
            or qrep_a["replica_version_hist"]
            != qrep_b["replica_version_hist"])


# -- driver construction -----------------------------------------------------


def test_make_query_driver_auto_detects_backend():
    import dataclasses

    from repro.configs import get_config, reduced
    from repro.configs.cnn import vgg_for
    from repro.fl.backend import CNNBackend, LMBackend
    from repro.fl.serving import CNNQueryDriver, LMQueryDriver

    from repro.data import make_benchmark_dataset
    ds = make_benchmark_dataset("mnist", n_samples=64, seed=0)
    cnn = CNNBackend(vgg_for("mnist"))
    scfg = ServingConfig(backend="auto")
    assert isinstance(make_query_driver(scfg, cnn, ds), CNNQueryDriver)

    lm_cfg = dataclasses.replace(reduced(get_config("internlm2-1.8b"),
                                         d_model=32), vocab_size=64)
    lm = LMBackend(lm_cfg)
    drv = make_query_driver(scfg, lm, None)
    assert isinstance(drv, LMQueryDriver)
    with pytest.raises(ValueError):
        make_query_driver(ServingConfig(backend="nope"), cnn, ds)


# -- serving is read-only: training bit-identity (real coordinator) ----------


@pytest.fixture(scope="module")
def cnn_world():
    from repro.configs.cnn import vgg_for
    from repro.data import (make_benchmark_dataset, partition_dirichlet,
                            split_811)
    from repro.fl.backend import CNNBackend
    ds = make_benchmark_dataset("mnist", n_samples=900, seed=0)
    splits = split_811(ds)
    parts = partition_dirichlet(splits["train"], 3, beta=0.5, seed=0)
    client_data = []
    for p in parts:
        s = split_811(p, seed=1)
        client_data.append({"train": s["train"], "val": s["val"],
                            "test": s["test"]})
    backend = CNNBackend(vgg_for("mnist"), local_epochs=1, batch_size=32)
    return backend, client_data, splits


def _run_coord(cnn_world, **over):
    import jax

    from repro.core.coordinator import DagAflConfig, DagAflCoordinator
    from repro.core.simulator import CostModel, make_profiles
    backend, client_data, splits = cnn_world
    cfg = DagAflConfig(n_clients=3, max_rounds=2, local_epochs=1, seed=0,
                       target_accuracy=None, patience=10 ** 6, **over)
    coord = DagAflCoordinator(backend, client_data, splits["test"], cfg,
                              CostModel(local_epoch=2.0),
                              make_profiles(3, 0.5, 0))
    res = coord.run(init_key=jax.random.PRNGKey(0))
    return coord, res


def test_serving_is_readonly_training_bit_identical(cnn_world):
    """The publisher + query stream ride the same event heap but mutate no
    training state: every published transaction's model must be
    bit-identical with serving on vs off."""
    coord_off, res_off = _run_coord(cnn_world)
    coord_on, res_on = _run_coord(
        cnn_world,
        serving=ServingConfig(every=2.0, query_rate=1.0, query_batch=8,
                              backend="cnn", seed=99))
    assert res_on.rounds == res_off.rounds
    assert res_on.sim_time == res_off.sim_time
    assert res_on.extra["chain_len"] == res_off.extra["chain_len"]
    txs_on = {t.tx_id: t for t in coord_on.ledger.transactions()}
    for t in coord_off.ledger.transactions():
        other = txs_on[t.tx_id]
        assert other.parents == t.parents
        assert trees_bitwise_equal(coord_off.store.get(t.model_ref),
                                   coord_on.store.get(other.model_ref))
    serving = res_on.extra["serving"]
    assert serving["queries"] > 0 and serving["replica_versions"] >= 1
    assert serving["skipped"] == 0
    assert replica_parity(coord_on.publisher.replica(), coord_on.store)


def test_serving_report_absent_when_off(cnn_world):
    _, res = _run_coord(cnn_world)
    assert "serving" not in res.extra
