"""Sharding rules: divisibility safety + layout intent, no devices needed."""
import dataclasses
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import transformer as T
from repro.sharding.rules import MeshPlan, param_pspec


class FakeMesh(SimpleNamespace):
    pass


MESH = FakeMesh(shape={"data": 16, "model": 16})
PLAN = MeshPlan()


def _pspecs(cfg):
    params = jax.eval_shape(
        lambda k: T.init_params(k, cfg), jax.random.PRNGKey(0))
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: (path, leaf,
                            param_pspec(path, leaf, cfg, MESH, PLAN)),
        params)


def _axis_size(entry):
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        return int(jnp.prod(jnp.asarray([MESH.shape[a] for a in entry])))
    return MESH.shape[entry]


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_pspecs_always_divisible(arch):
    cfg = get_config(arch)
    triples = jax.tree_util.tree_leaves(
        _pspecs(cfg), is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    assert triples
    for path, leaf, spec in triples:
        for dim, entry in enumerate(spec):
            size = _axis_size(entry)
            assert leaf.shape[dim] % size == 0, (arch, path, leaf.shape, spec)


def test_gqa_kv_replicated_when_not_divisible():
    cfg = get_config("qwen2-7b")                 # 4 kv heads < 16
    triples = jax.tree_util.tree_leaves(
        _pspecs(cfg), is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    for path, leaf, spec in triples:
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name in ("wk", "wv"):
            assert "model" not in [s for s in spec if isinstance(s, str)], \
                (path, spec)
        if name in ("wi", "wg"):                 # MLP still TP-sharded
            assert spec[-1] == "model"


def test_small_heads_replicate_attention():
    cfg = get_config("gemma2-2b")                # 8 q heads < 16
    triples = jax.tree_util.tree_leaves(
        _pspecs(cfg), is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    for path, leaf, spec in triples:
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name in ("wq", "wo"):
            flat = [s for s in spec if isinstance(s, str)]
            assert "model" not in flat


def test_experts_sharded_over_model():
    cfg = get_config("deepseek-v2-236b")         # 160 experts
    triples = jax.tree_util.tree_leaves(
        _pspecs(cfg), is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    seen = False
    for path, leaf, spec in triples:
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name.startswith("we_"):
            assert spec[-3] == "model", (path, spec)
            seen = True
    assert seen


def test_slstm_recurrent_weights_replicated():
    cfg = get_config("xlstm-125m")
    triples = jax.tree_util.tree_leaves(
        _pspecs(cfg), is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    for path, leaf, spec in triples:
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name in ("w_gates", "r_gates", "w_if"):
            assert all(s is None or s == "data" for s in spec), (path, spec)


def test_embedding_never_fsdp_on_d():
    cfg = get_config("internlm2-1.8b")
    triples = jax.tree_util.tree_leaves(
        _pspecs(cfg), is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    for path, leaf, spec in triples:
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name == "embedding":
            assert spec[-1] is None
