"""Eq. 3-5: signatures, cosine similarity, and the similarity contract."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.signature import (SimilarityContract, cosine_similarity,
                                  cosine_similarity_matrix)
from repro.models.layers import activation_signature


def test_cosine_similarity_basics():
    a = jnp.asarray([1.0, 0.0])
    assert float(cosine_similarity(a, a)) == pytest.approx(1.0)
    assert float(cosine_similarity(a, jnp.asarray([0.0, 1.0]))) == \
        pytest.approx(0.0, abs=1e-6)
    assert float(cosine_similarity(a, -a)) == pytest.approx(-1.0)


def test_similarity_matrix_symmetric_unit_diag():
    sigs = jnp.asarray([[1.0, 0.0], [0.5, 0.5], [0.0, 1.0]])
    m = np.asarray(cosine_similarity_matrix(sigs))
    assert np.allclose(m, m.T, atol=1e-6)
    assert np.allclose(np.diag(m), 1.0, atol=1e-6)


def test_contract_round_queries():
    c = SimilarityContract(4)
    c.post_signature(0, np.array([1.0, 0.0]))
    c.post_signature(1, np.array([0.9, 0.1]))
    c.post_signature(2, np.array([0.0, 1.0]))
    assert c.commit_round(0) is not None
    row = c.query(0, 0)
    assert row[1] > row[2]          # client 1 more similar to 0 than 2
    assert c.query(5, 0) is not None   # latest round <= 5
    assert c.most_similar(0, 0, [1, 2], p=1) == [1]


def test_contract_before_any_round():
    c = SimilarityContract(4)
    assert c.query(0, 0) is None
    assert c.most_similar(0, 0, [1, 2], p=1) == [1]   # passthrough


def test_activation_signature_properties():
    h = jnp.concatenate([jnp.zeros((5, 10, 32)),
                         jnp.ones((5, 10, 32))], axis=-1)
    sig = activation_signature(h, n_sig=2, tau=0.05)
    assert sig.shape == (2,)
    np.testing.assert_allclose(np.asarray(sig), [1.0, 0.0], atol=1e-6)
