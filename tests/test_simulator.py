"""Event loop ordering + convergence tracking + client profiles."""
import numpy as np

from repro.core.simulator import (ClientProfile, ConvergenceTracker, CostModel,
                                  EventLoop, make_profiles)


def test_event_order():
    loop = EventLoop()
    seen = []
    loop.schedule(2.0, lambda: seen.append("b"))
    loop.schedule(1.0, lambda: seen.append("a"))
    loop.schedule(3.0, lambda: seen.append("c"))
    loop.run()
    assert seen == ["a", "b", "c"]
    assert loop.now == 3.0


def test_nested_scheduling():
    loop = EventLoop()
    seen = []

    def first():
        seen.append(loop.now)
        loop.schedule(1.5, lambda: seen.append(loop.now))

    loop.schedule(1.0, first)
    loop.run()
    assert seen == [1.0, 2.5]


def test_negative_delay_clamps_to_now():
    """Scheduling into the past clamps to the present (the cohort path
    produces negative delays when a round finishes before its window
    flushes) — observable via the ``clamped`` counter, and simulated time
    never runs backwards."""
    loop = EventLoop()
    seen = []

    def late():
        # now == 5.0; this round "completed" at 3.0 — publish clamps to now
        loop.schedule(3.0 - loop.now, lambda: seen.append(loop.now))

    loop.schedule(5.0, late)
    loop.run()
    assert seen == [5.0]
    assert loop.clamped == 1
    assert loop.now == 5.0


def test_cohort_window_round_shorter_than_window():
    """A batch whose rounds all complete before the window closes: the
    flush still dispatches every request (via the close timer), and the
    completion callbacks scheduled into the past land AT the flush time in
    order."""
    from repro.core.simulator import CohortWindow

    loop = EventLoop()
    published = []

    def flush(batch):
        for item, t_start in batch:
            # each round took 0.1 simulated seconds — far less than the
            # 5.0 window, so every publish time precedes the flush
            loop.schedule(t_start + 0.1 - loop.now,
                          lambda item=item: published.append((item, loop.now)))

    window = CohortWindow(loop, capacity=10, window=5.0, flush_fn=flush,
                          stop_fn=lambda: False)
    for i, d in enumerate((0.0, 0.5, 1.0)):
        loop.schedule(d, lambda i=i: window.add(i))
    loop.run()
    # window opened at 0.0 -> flushed by the timer at 5.0; all three
    # publishes clamped to the flush instant
    assert [i for i, _ in published] == [0, 1, 2]
    assert all(t == 5.0 for _, t in published)
    assert loop.clamped == 3


def test_stop_predicate():
    loop = EventLoop()
    count = []
    for i in range(10):
        loop.schedule(float(i), lambda: count.append(1))
    loop.run(stop=lambda: len(count) >= 3)
    assert len(count) == 3


def test_tracker_patience():
    tr = ConvergenceTracker(patience=3)
    assert not tr.update(1.0, 0.5)
    assert not tr.update(2.0, 0.6)
    assert not tr.update(3.0, 0.6)      # stale 1
    assert not tr.update(4.0, 0.6)      # stale 2
    assert tr.update(5.0, 0.6)          # stale 3 -> converged
    assert tr.converged_at == 5.0
    assert tr.best == 0.6


def test_tracker_target():
    tr = ConvergenceTracker(target_accuracy=0.9, patience=50)
    assert not tr.update(1.0, 0.5)
    assert tr.update(2.0, 0.95)
    assert tr.converged_at == 2.0


def test_profiles_heterogeneity():
    fast = make_profiles(200, heterogeneity=0.1, seed=0)
    slow = make_profiles(200, heterogeneity=1.2, seed=0)
    assert np.std([p.speed for p in slow]) > np.std([p.speed for p in fast])


def test_cost_model_scales_with_profile():
    cm = CostModel()
    rng = np.random.default_rng(0)
    p_fast = ClientProfile(0, speed=0.5, bandwidth=1e8, latency=0.01)
    p_slow = ClientProfile(1, speed=2.0, bandwidth=1e6, latency=0.01)
    assert cm.train_time(p_slow, 5, rng) > cm.train_time(p_fast, 5, rng)
    assert cm.transfer_time(p_slow, 10**7) > cm.transfer_time(p_fast, 10**7)


def test_schedule_every_recurring_until_stop():
    loop = EventLoop()
    fired = []
    loop.schedule_every(2.0, lambda: fired.append(loop.now),
                        stop=lambda: len(fired) >= 3)
    loop.schedule(100.0, lambda: None)       # keep the heap alive past stop
    loop.run()
    assert fired == [2.0, 4.0, 6.0]          # 4th tick sees stop() and ends


def test_schedule_every_rejects_nonpositive_interval():
    import pytest
    loop = EventLoop()
    with pytest.raises(ValueError):
        loop.schedule_every(0.0, lambda: None)


def test_schedule_every_drains_with_the_heap():
    """The recurring tick must not keep an otherwise-finished simulation
    alive: once no other events remain, it stops re-arming."""
    loop = EventLoop()
    fired = []
    loop.schedule_every(1.0, lambda: fired.append(loop.now))
    loop.schedule(2.5, lambda: None)         # last piece of real work
    loop.run()
    assert fired == [1.0, 2.0, 3.0]          # tick at 3.0 sees an empty heap
    assert loop.now == 3.0
