"""End-to-end behaviour of the full system (coordinator + models + chain)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import (DagAflConfig, DagAflCoordinator, TipSelectionConfig,
                        verify_full_dag)
from repro.core.simulator import CostModel, make_profiles
from repro.data import make_lm_dataset
from repro.fl.backend import LMBackend
from repro.models import transformer as T
from repro.runtime import Runtime
from repro.train.step import make_train_step


def test_lm_dagafl_end_to_end():
    """DAG-AFL federates a reduced transformer (the framework path):
    3 clients with different Markov-chain dialects, loss improves and the
    ledger audits clean."""
    cfg = dataclasses.replace(reduced(get_config("internlm2-1.8b")),
                              compute_dtype="float32")
    backend = LMBackend(cfg, lr=5e-3, local_steps=4, batch_size=4, seq_len=32)
    streams = [make_lm_dataset(vocab=cfg.vocab_size, n_tokens=4000,
                               order=2.0, seed=s) for s in range(3)]
    client_data = [{"train": s, "val": s, "test": s} for s in streams]
    global_test = make_lm_dataset(vocab=cfg.vocab_size, n_tokens=4000, seed=9)

    dcfg = DagAflConfig(n_clients=3, max_rounds=2, local_epochs=4,
                        tip=TipSelectionConfig(n_select=2), seed=0)
    coord = DagAflCoordinator(backend, client_data, global_test, dcfg,
                              CostModel(local_epoch=1.0),
                              make_profiles(3, 0.4, 0))
    init_acc = backend.evaluate(backend.init(jax.random.PRNGKey(0)),
                                global_test)
    res = coord.run()
    assert res.final_accuracy >= init_acc      # next-token acc not worse
    assert verify_full_dag(coord.ledger)[0]
    assert res.extra["chain_len"] >= 4


def test_train_step_with_signature_metric():
    """The launcher's train step emits the DAG-AFL signature as a metric —
    the paper's technique integrated into the compiled step."""
    cfg = dataclasses.replace(reduced(get_config("qwen2-7b")),
                              compute_dtype="float32")
    step, opt = make_train_step(cfg, runtime=Runtime(want_signature=True))
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    opt_state = opt.init(params)
    toks = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    params2, opt_state2, metrics = jax.jit(step)(params, opt_state, batch)
    assert "signature" in metrics
    assert metrics["signature"].shape == (64,)
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), params, params2)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


def test_loss_decreases_over_steps():
    cfg = dataclasses.replace(reduced(get_config("internlm2-1.8b")),
                              compute_dtype="float32")
    step, opt = make_train_step(cfg, runtime=Runtime())
    key = jax.random.PRNGKey(1)
    params = T.init_params(key, cfg)
    opt_state = opt.init(params)
    toks = jax.random.randint(key, (4, 64), 0, 64)   # low-entropy tokens
    batch = {"tokens": toks, "labels": toks}
    jstep = jax.jit(step)
    losses = []
    for _ in range(8):
        params, opt_state, m = jstep(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
