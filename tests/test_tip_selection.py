"""Tip selection (paper §III-B): freshness, lambda split, similarity filter."""
import math

import numpy as np
import pytest

from repro.core.dag import DAGLedger, TxMetadata
from repro.core.signature import SimilarityContract
from repro.core.tip_selection import (FnTipEvaluator, TipSelectionConfig,
                                      TipSelectionRequest, TipSelector,
                                      freshness, select_tips, tipc,
                                      top_up_tips)


def meta(cid, epoch, sig=(1.0, 0.0)):
    return TxMetadata(client_id=cid, signature=sig, model_accuracy=0.5,
                      current_epoch=epoch, validation_node_id=cid)


def run_selection(led, client_id, cur_epoch, now, evaluate_fn, contract, cfg,
                  round_idx=0):
    """Select via the first-class TipSelector API.  The deprecated
    select_tips wrapper is exercised exactly once, in
    test_selector_matches_legacy_wrapper."""
    selector = TipSelector(led, contract, cfg)
    req = TipSelectionRequest(client_id=client_id, cur_epoch=cur_epoch,
                              now=now, round_idx=round_idx)
    return selector.select(req, FnTipEvaluator(evaluate_fn))


def test_tipc_eq1():
    assert tipc(3, 3) == 1.0
    assert tipc(5, 3) == pytest.approx(math.exp(-2))
    assert tipc(3, 5) == pytest.approx(math.exp(-2))


def test_freshness_prose_semantics():
    """Default Eq.2: decays with both epoch gap and dwell time."""
    f_now = freshness(2, 2, now=10.0, tip_time=10.0, alpha=0.1)
    f_old = freshness(2, 2, now=10.0, tip_time=0.0, alpha=0.1)
    f_gap = freshness(5, 2, now=10.0, tip_time=10.0, alpha=0.1)
    assert f_now == pytest.approx(1.0)
    assert f_old < f_now
    assert f_gap < f_now


def test_freshness_literal_eq2_is_inverted():
    """The printed formula increases with dwell time (the paper's typo)."""
    f_new = freshness(2, 2, 10.0, 10.0, 0.1, literal_eq2=True)
    f_old = freshness(2, 2, 10.0, 0.0, 0.1, literal_eq2=True)
    assert f_old > f_new


def _setup(n_other=4):
    led = DAGLedger()
    led.add_genesis(meta(-1, 0))
    g = led.genesis_id
    mine = led.add_transaction(meta(0, 1), [g], 1.0)
    reach_tip = led.add_transaction(meta(1, 2), [mine.tx_id], 2.0)
    unreach = [led.add_transaction(meta(2 + i, 2), [g], 2.0 + 0.1 * i)
               for i in range(n_other)]
    return led, mine, reach_tip, unreach


def test_lambda_split():
    led, mine, reach_tip, unreach = _setup()
    accs = {t.tx_id: 0.5 + 0.01 * i for i, t in enumerate(unreach)}
    accs[reach_tip.tx_id] = 0.9
    chosen = run_selection(led, 0, 2, 3.0, lambda t: accs.get(t, 0.1),
                           None, TipSelectionConfig(n_select=2, lam=0.5))
    kinds = sorted(c.reachable for c in chosen)
    assert kinds == [False, True]          # one reachable + one unreachable
    assert any(c.tx_id == reach_tip.tx_id for c in chosen)


def test_similarity_filter_reduces_evaluations():
    led, mine, reach_tip, unreach = _setup(n_other=6)
    contract = SimilarityContract(10)
    contract.post_signature(0, np.array([1.0, 0.0]))
    for i in range(6):
        sig = [1.0, 0.1 * i]               # client 2 most similar to client 0
        contract.post_signature(2 + i, np.array(sig))
    contract.commit_round(0)

    evals = []
    cfg = TipSelectionConfig(n_select=2, lam=0.5, p_similar=2)
    run_selection(led, 0, 2, 3.0, lambda t: (evals.append(t) or 0.5),
                  contract, cfg)
    # reachable side evaluates 1 tip; unreachable side only p=2 of 6
    assert len(evals) <= 3


def test_no_similarity_evaluates_all_candidates():
    led, mine, reach_tip, unreach = _setup(n_other=6)
    evals = []
    cfg = TipSelectionConfig(n_select=2, lam=0.5, use_similarity=False)
    run_selection(led, 0, 2, 3.0, lambda t: (evals.append(t) or 0.5),
                  None, cfg)
    assert len(evals) == 7                 # 1 reachable + all 6 unreachable


def test_small_dag_returns_everything():
    led = DAGLedger()
    led.add_genesis(meta(-1, 0))
    chosen = run_selection(led, 0, 0, 0.0, lambda t: 0.5, None,
                           TipSelectionConfig(n_select=2))
    assert len(chosen) == 1               # only genesis exists


def test_first_round_client_all_unreachable():
    led, mine, reach_tip, unreach = _setup()
    chosen = run_selection(led, 77, 0, 3.0, lambda t: 0.5, None,
                           TipSelectionConfig(n_select=2))
    assert len(chosen) == 2
    assert all(not c.reachable for c in chosen)


def test_never_selects_own_transactions():
    """A client's own tips are excluded (P2P-fetching yourself silos
    training; see tip_selection.py note)."""
    led = DAGLedger()
    led.add_genesis(meta(-1, 0))
    g = led.genesis_id
    mine = led.add_transaction(meta(0, 1), [g], 1.0)          # client 0's tip
    other = led.add_transaction(meta(1, 1), [g], 1.1)
    chosen = run_selection(led, 0, 1, 2.0, lambda t: 0.5, None,
                           TipSelectionConfig(n_select=2))
    assert mine.tx_id not in {c.tx_id for c in chosen}
    assert other.tx_id in {c.tx_id for c in chosen}


def test_own_tip_used_when_alone():
    led = DAGLedger()
    led.add_genesis(meta(-1, 0))
    mine = led.add_transaction(meta(0, 1), [led.genesis_id], 1.0)
    chosen = run_selection(led, 0, 1, 2.0, lambda t: 0.5, None,
                           TipSelectionConfig(n_select=2))
    assert chosen and chosen[0].tx_id == mine.tx_id


# -- top-up (small DAGs): freshness x accuracy rank, batched validation ------


def test_top_up_ranks_by_freshness_times_accuracy():
    """The top-up must rank by the paper's score, not freshness alone: a
    fresh-but-bad tip loses to a slightly staler accurate one."""
    fresh = {"stale_good": 0.8, "fresh_bad": 1.0, "mid": 0.9}.__getitem__
    accs = {"stale_good": 0.9, "fresh_bad": 0.1, "mid": 0.5}
    out = top_up_tips([], ["stale_good", "fresh_bad", "mid"], [],
                      fresh, accs.__getitem__, None, 2)
    assert [s.tx_id for s in out] == ["stale_good", "mid"]
    for s in out:
        assert s.score == pytest.approx(fresh(s.tx_id) * accs[s.tx_id])


def test_top_up_batch_eval_warms_cache_zero_sequential_evals():
    """With evaluate_batch provided, the per-tip evaluate_fn must serve
    every top-up candidate from the warmed cache: zero sequential
    (cache-missing) evaluations."""
    cache = {}
    sequential_evals = []

    def evaluate_batch(tx_ids):
        for t in tx_ids:                   # one vectorized dispatch
            cache[t] = 0.5

    def evaluate_fn(t):
        if t not in cache:                 # the bug: per-tip dispatch
            sequential_evals.append(t)
            cache[t] = 0.5
        return cache[t]

    out = top_up_tips([], ["a", "b", "c"], ["a"], lambda t: 1.0,
                      evaluate_fn, evaluate_batch, 2)
    assert len(out) == 2
    assert sequential_evals == []          # batch warmed everything
    assert {s.tx_id for s in out} <= {"a", "b", "c"}


def test_top_up_computes_freshness_once_per_candidate():
    calls = []

    def fresh(t):
        calls.append(t)
        return 1.0

    top_up_tips([], ["a", "b", "c"], [], fresh, lambda t: 0.5, None, 3)
    assert sorted(calls) == ["a", "b", "c"]      # exactly once each


def test_top_up_skips_already_chosen():
    from repro.core.tip_selection import TipScore
    chosen = [TipScore("a", True, 1.0, 0.9, 0.9)]
    out = top_up_tips(chosen, ["a", "b"], [], lambda t: 1.0,
                      lambda t: 0.5, None, 2)
    assert [s.tx_id for s in out] == ["b"]


# -- redesigned API: TipSelector / TipSelectionRequest / TipEvaluator --------


def test_selector_matches_legacy_wrapper():
    """The back-compat select_tips wrapper and the TipSelector engine must
    produce identical selections (the wrapper IS the engine).  This is the
    repo's ONE sanctioned wrapper call site — everything else goes through
    TipSelector (enforced by repro-lint's deprecated-select-tips rule)."""
    led, mine, reach_tip, unreach = _setup(n_other=5)
    accs = {t.tx_id: 0.4 + 0.05 * i for i, t in enumerate(unreach)}
    accs[reach_tip.tx_id] = 0.9
    fn = lambda t: accs.get(t, 0.1)  # noqa: E731
    cfg = TipSelectionConfig(n_select=2, lam=0.5, use_similarity=False)

    legacy = select_tips(  # repro-lint: disable=deprecated-select-tips
        led, 0, 2, 3.0, fn, None, cfg)
    sel = TipSelector(led, None, cfg)
    req = TipSelectionRequest(client_id=0, cur_epoch=2, now=3.0, round_idx=0)
    new = sel.select(req, FnTipEvaluator(fn))
    assert [(s.tx_id, s.reachable, s.score) for s in legacy] == \
        [(s.tx_id, s.reachable, s.score) for s in new]


def test_fn_evaluator_satisfies_protocol():
    from repro.core.tip_selection import FnTipEvaluator, TipEvaluator
    ev = FnTipEvaluator(lambda t: 0.5)
    assert isinstance(ev, TipEvaluator)
    ev.warm(["a"])                             # no batch fn: silently a no-op
    assert ev.evaluate("x") == 0.5


def test_fn_evaluator_routes_batch():
    from repro.core.tip_selection import FnTipEvaluator
    warmed = []
    ev = FnTipEvaluator(lambda t: 0.5, lambda ids: warmed.extend(ids))
    ev.warm([])                                # empty: batch not dispatched
    ev.warm(["a", "b"])
    assert warmed == ["a", "b"]


def test_max_tip_candidates_restricts_to_freshest():
    """The index-backed candidate cap considers only the k freshest tips;
    stale tips are invisible to selection."""
    from repro.core.tip_selection import (FnTipEvaluator, TipSelectionRequest,
                                          TipSelector)
    led = DAGLedger()
    led.add_genesis(meta(-1, 0))
    g = led.genesis_id
    stale = led.add_transaction(meta(1, 1), [g], 1.0)
    fresh_tips = [led.add_transaction(meta(2 + i, 1), [g], 10.0 + i)
                  for i in range(3)]
    cfg = TipSelectionConfig(n_select=2, use_similarity=False,
                             max_tip_candidates=2)
    sel = TipSelector(led, None, cfg)
    req = TipSelectionRequest(client_id=0, cur_epoch=1, now=20.0)
    chosen = sel.select(req, FnTipEvaluator(lambda t: 0.5))
    ids = {s.tx_id for s in chosen}
    assert stale.tx_id not in ids
    assert ids <= {t.tx_id for t in fresh_tips[-2:]}


def test_request_is_frozen():
    from repro.core.tip_selection import TipSelectionRequest
    req = TipSelectionRequest(client_id=0, cur_epoch=1, now=2.0)
    with pytest.raises(Exception):
        req.now = 5.0
