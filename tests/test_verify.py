"""Trustworthy verification (paper §III-C): Eq. 7 hashing + tamper detection."""
import dataclasses

from repro.core.dag import DAGLedger, TxMetadata, compute_tx_hash
from repro.core.verify import extract_path, verify_full_dag, verify_path


def meta(cid=0, epoch=0, acc=0.5):
    return TxMetadata(client_id=cid, signature=(0.1,), model_accuracy=acc,
                      current_epoch=epoch, validation_node_id=cid)


def chain(n=5):
    led = DAGLedger()
    led.add_genesis(meta(-1))
    prev = led.genesis_id
    for i in range(n):
        prev = led.add_transaction(meta(i % 3, i), [prev], float(i + 1)).tx_id
    return led, prev


def test_hash_binds_parents_and_metadata():
    h1 = compute_tx_hash(["aa"], meta(0, 1))
    assert h1 != compute_tx_hash(["bb"], meta(0, 1))
    assert h1 != compute_tx_hash(["aa"], meta(0, 2))
    assert h1 == compute_tx_hash(["aa"], meta(0, 1))


def test_clean_path_verifies():
    led, tip = chain()
    path = extract_path(led, tip)
    assert len(path.records) == 6          # 5 + genesis
    ok, reason = verify_path(led, path)
    assert ok, reason
    assert verify_full_dag(led) == (True, "ok")


def test_metadata_tamper_detected():
    led, tip = chain()
    path = extract_path(led, tip)
    victim = path.records[2].tx_id
    tx = led.get_tx(victim)
    tx.metadata = dataclasses.replace(tx.metadata, model_accuracy=0.99)
    ok, reason = verify_path(led, path)
    assert not ok and victim in reason


def test_edge_tamper_detected():
    led, tip = chain()
    path = extract_path(led, tip)
    victim = path.records[1].tx_id
    led.get_tx(victim).parents = (led.genesis_id,)
    ok, reason = verify_path(led, path)
    assert not ok


def test_hash_tamper_detected_by_full_audit():
    led, tip = chain()
    led.get_tx(tip).tx_hash = "0" * 64
    ok, _ = verify_full_dag(led)
    assert not ok


def test_deleted_tx_detected():
    led, tip = chain()
    path = extract_path(led, tip)
    # deliberate internals tampering: simulate a tx body vanishing
    del led.nodes[path.records[3].tx_id]  # repro-lint: disable=ledger-internals-access
    ok, reason = verify_path(led, path)
    assert not ok    # surfaced as missing-tx or as a child hash mismatch
