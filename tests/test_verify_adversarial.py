"""Eq. 7 verification under ACTIVE adversaries (robustness suite).

Negative tests: metadata tampered after publish must fail verify_path /
IncrementalVerifier / detect_tampered on both the append-only DAGLedger and
the BoundedDAGLedger (including paths crossing the pruned boundary), and the
counting sweep must return EXACTLY the tampered set — the robustness gate
pins its detection counts.
"""
import dataclasses

import pytest

from repro.core.dag import BoundedDAGLedger, DAGLedger, TxMetadata
from repro.core.verify import (IncrementalVerifier, detect_tampered,
                               extract_path, verify_full_dag, verify_path)
from repro.fl.scenarios import Scenario, ScenarioConfig


def meta(cid=0, epoch=0, acc=0.5):
    return TxMetadata(client_id=cid, signature=(0.1,), model_accuracy=acc,
                      current_epoch=epoch, validation_node_id=cid)


def chain(n=8, ledger=None):
    led = ledger if ledger is not None else DAGLedger()
    led.add_genesis(meta(-1))
    prev, ids = led.genesis_id, []
    for i in range(n):
        prev = led.add_transaction(meta(i % 3, i), [prev], float(i + 1)).tx_id
        ids.append(prev)
    return led, ids


def tamper(led, tx_id):
    tx = led.get_tx(tx_id)
    tx.metadata = dataclasses.replace(tx.metadata, model_accuracy=0.99)


@pytest.mark.parametrize("bounded", [False, True])
def test_detect_tampered_returns_exact_set(bounded):
    led, ids = chain(8, BoundedDAGLedger() if bounded else None)
    assert detect_tampered(led) == []
    victims = [ids[2], ids[5]]
    for v in victims:
        tamper(led, v)
    assert detect_tampered(led) == sorted(victims)
    ok, _ = verify_full_dag(led)
    assert not ok


def test_tampered_tx_fails_stored_path():
    led, ids = chain(6)
    path = extract_path(led, ids[-1])
    tamper(led, ids[3])
    ok, reason = verify_path(led, path)
    assert not ok and ids[3] in reason


def test_incremental_verifier_flags_tamper_between_audits():
    led, ids = chain(4)
    iv = IncrementalVerifier(led)
    assert iv.audit() == (True, "ok")
    nxt = led.add_transaction(meta(1, 9), [ids[-1]], 9.0).tx_id
    tamper(led, nxt)                     # tampered before the next audit
    ok, reason = iv.audit()
    assert not ok and nxt in reason


def test_tampered_live_tx_fails_across_pruned_boundary():
    """A stored path whose prefix was pruned still catches tampering of the
    (live) suffix — the checkpoint retains the pruned hashes."""
    led, ids = chain(8, BoundedDAGLedger())
    path = extract_path(led, ids[-1])
    led.checkpoint(now=100.0)
    assert any(led.is_pruned(i) for i in ids), "checkpoint pruned nothing"
    live = [i for i in ids if led.has_tx(i)]
    tamper(led, live[-1])
    ok, reason = verify_path(led, path)
    assert not ok and live[-1] in reason
    assert detect_tampered(led) == [live[-1]]


def test_tampered_retained_hash_fails_across_pruned_boundary():
    led, ids = chain(8, BoundedDAGLedger())
    path = extract_path(led, ids[-1])
    led.checkpoint(now=100.0)
    pruned = [i for i in ids if led.is_pruned(i)]
    led._tamper_pruned_hash(pruned[-1], "f" * 64)
    ok, _ = verify_path(led, path)
    assert not ok
    ok, _ = verify_full_dag(led)
    assert not ok


def test_scenario_tamper_is_detected_end_to_end():
    """Scenario.maybe_tamper (tamper_rate=1 on a malicious client) edits
    stored metadata without recomputing the hash; the sweep catches every
    such tx and nothing else."""
    led, ids = chain(9)        # client ids cycle 0,1,2
    cfg = ScenarioConfig(name="t", malicious_frac=0.4, tamper_rate=1.0)
    sc = Scenario(cfg, 3)
    assert sc.malicious, "scenario assigned no malicious clients"
    for i in ids:
        sc.maybe_tamper(led, i)
    expected = sorted(sc.tampered)
    assert expected, "tamper_rate=1.0 tampered nothing"
    assert detect_tampered(led) == expected
    ok, _ = IncrementalVerifier(led).audit()
    assert not ok


def test_zero_tamper_rate_touches_nothing():
    led, ids = chain(6)
    sc = Scenario(ScenarioConfig(name="z", malicious_frac=0.4), 3)
    for i in ids:
        assert not sc.maybe_tamper(led, i)
    assert detect_tampered(led) == []
    assert verify_full_dag(led) == (True, "ok")
