"""repro-lint: AST-based determinism & JAX-purity analyzer for DAG-AFL.

Three rule families protect the repo's reproducibility invariants:

* ``determinism`` (DET0xx) — PYTHONHASHSEED-dependent hashing, hidden
  global RNG state, wall-clock reads in the simulation core, hash-salted
  set iteration order;
* ``jax-purity`` / ``jax-perf`` (JAX0xx) — side effects and host I/O in
  traced functions, un-synced wall-clock timing of async dispatches,
  hazardous static_argnums, constant-folded array closures;
* ``api-hygiene`` (API0xx) — deprecated ``select_tips`` wrapper, ledger
  internals bypassing :class:`LedgerView`, ``CohortPrograms`` suites
  missing the 2-D engine's sum-form methods.

Run ``python -m tools.repro_lint src tests benchmarks``; see
``--list-rules`` and the README "Static analysis" section.
"""
from tools.repro_lint.engine import (Finding, ModuleContext, Rule,
                                     all_rules, lint_paths, lint_source,
                                     register)

__version__ = "0.1.0"

__all__ = ["Finding", "ModuleContext", "Rule", "all_rules", "lint_paths",
           "lint_source", "register", "__version__"]
