"""CLI: ``python -m tools.repro_lint [--format F] [--select R] paths...``

Exit status is 0 when every checked module is clean, 1 when there are
findings — CI runs this as a gate over ``src tests benchmarks``.
"""
from __future__ import annotations

import argparse
import sys

from tools.repro_lint.engine import all_rules, lint_paths
from tools.repro_lint.output import FORMATS, format_findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="AST-based determinism / JAX-purity / API-hygiene "
                    "analyzer for the DAG-AFL repo")
    ap.add_argument("paths", nargs="*",
                    help="files or directories to analyze")
    ap.add_argument("--format", choices=FORMATS, default="text",
                    dest="fmt", help="output format (default: text)")
    ap.add_argument("--select", default="",
                    help="comma-separated rule ids/names to run "
                         "(default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule registry and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in all_rules():
            print(f"{r.id}  {r.name:28s} [{r.family}] {r.description}")
        return 0
    if not args.paths:
        ap.error("no paths given (try: src tests benchmarks)")

    select = {s.strip() for s in args.select.split(",") if s.strip()} or None
    findings, n_files = lint_paths(args.paths, select=select)
    print(format_findings(findings, args.fmt, n_files))
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
