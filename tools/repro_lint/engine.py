"""repro-lint core: module contexts, the rule registry, and the runner.

The analyzer is deliberately stdlib-only (``ast`` + ``tokenize``): it must
run in hermetic CI containers before any heavy dependency is installed, and
it must never import the code it analyzes — every rule works on the parsed
syntax tree of one module at a time.

Suppression
-----------
A finding on line N is suppressed by a trailing comment on that line::

    x = hash(name)  # repro-lint: disable=builtin-hash

Rules can be named by id (``DET001``) or slug (``builtin-hash``), comma
separated; ``all`` suppresses every rule.  A ``# repro-lint:
disable-file=<rule>`` comment anywhere in the file suppresses the rule for
the whole module (reserve this for generated code).
"""
from __future__ import annotations

import ast
import io
import os
import re
import tokenize
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

_DIRECTIVE = re.compile(
    r"#\s*repro-lint:\s*(disable(?:-file)?)\s*=\s*([A-Za-z0-9_,\- ]+)")


@dataclass(frozen=True)
class Finding:
    rule_id: str
    rule_name: str
    path: str
    line: int
    col: int
    message: str

    def sort_key(self):
        return (self.path, self.line, self.col, self.rule_id)

    def to_dict(self) -> dict:
        return {"rule": self.rule_id, "name": self.rule_name,
                "path": self.path, "line": self.line, "col": self.col,
                "message": self.message}

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col + 1}: "
                f"{self.rule_id} [{self.rule_name}] {self.message}")


class Rule:
    """One analysis. Subclasses set the class attributes and yield findings
    from :meth:`check`; path scoping (rules that only apply under certain
    trees) is the rule's own responsibility via ``ctx.rel_path``."""

    id: str = ""
    name: str = ""
    family: str = ""
    description: str = ""

    def check(self, ctx: "ModuleContext") -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, ctx: "ModuleContext", node: ast.AST,
                message: str) -> Finding:
        return Finding(self.id, self.name, ctx.path,
                       getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0), message)


_REGISTRY: Dict[str, Rule] = {}
_RULES_LOADED = False


def register(cls):
    """Class decorator adding one Rule instance to the registry."""
    inst = cls()
    if not inst.id or not inst.name or not inst.family:
        raise ValueError(f"rule {cls.__name__} must set id/name/family")
    if inst.id in _REGISTRY:
        raise ValueError(f"duplicate rule id {inst.id}")
    _REGISTRY[inst.id] = inst
    return cls


def _ensure_rules_loaded():
    global _RULES_LOADED
    if not _RULES_LOADED:
        # imported for their @register side effects
        from tools.repro_lint import (rules_api,  # noqa: F401
                                      rules_determinism, rules_jax,
                                      rules_kernels, rules_serving)
        _RULES_LOADED = True


def all_rules() -> List[Rule]:
    _ensure_rules_loaded()
    return [_REGISTRY[k] for k in sorted(_REGISTRY)]


def _parse_suppressions(source: str) -> Tuple[Dict[int, Set[str]], Set[str]]:
    line_sup: Dict[int, Set[str]] = {}
    file_sup: Set[str] = set()
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type != tokenize.COMMENT:
                continue
            m = _DIRECTIVE.search(tok.string)
            if not m:
                continue
            names = {p.strip().lower() for p in m.group(2).split(",")
                     if p.strip()}
            if m.group(1) == "disable-file":
                file_sup |= names
            else:
                line_sup.setdefault(tok.start[0], set()).update(names)
    except tokenize.TokenError:
        pass
    return line_sup, file_sup


class ModuleContext:
    """One parsed module plus everything rules share: the tree, the
    normalized path used for scoping, and the suppression table."""

    def __init__(self, source: str, path: str = "<string>",
                 rel_path: Optional[str] = None):
        self.source = source
        self.path = path
        self.rel_path = (rel_path if rel_path is not None
                         else path).replace(os.sep, "/")
        self.tree = ast.parse(source, filename=path)
        self._line_sup, self._file_sup = _parse_suppressions(source)
        self._cache: Dict[str, object] = {}   # per-module rule scratch space

    def is_suppressed(self, rule: Rule, line: int) -> bool:
        keys = {rule.id.lower(), rule.name.lower(), "all"}
        if keys & self._file_sup:
            return True
        return bool(keys & self._line_sup.get(line, set()))


def qualname(node: ast.AST) -> Optional[str]:
    """Dotted source name of a Name/Attribute chain (``jax.jit``), or None
    for anything computed (calls, subscripts)."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = qualname(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


DEFAULT_EXCLUDED_DIRS = {"__pycache__", ".git", "testdata"}


def iter_python_files(paths: Sequence[str]) -> Iterator[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
        elif os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in DEFAULT_EXCLUDED_DIRS)
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)


def _normalize_select(select) -> Optional[Set[str]]:
    if not select:
        return None
    return {s.lower() for s in select}


def lint_source(source: str, path: str = "<string>",
                rel_path: Optional[str] = None,
                select=None) -> List[Finding]:
    """Run every (selected) rule over one module's source.  A module that
    does not parse yields a single ``E000`` finding instead of raising — a
    broken file must fail the gate, not hide from it."""
    sel = _normalize_select(select)
    try:
        ctx = ModuleContext(source, path=path, rel_path=rel_path)
    except SyntaxError as e:
        return [Finding("E000", "syntax-error", path, e.lineno or 1,
                        (e.offset or 1) - 1,
                        f"module does not parse: {e.msg}")]
    out: List[Finding] = []
    for rule in all_rules():
        if sel is not None and not ({rule.id.lower(), rule.name.lower()}
                                    & sel):
            continue
        for f in rule.check(ctx):
            if not ctx.is_suppressed(rule, f.line):
                out.append(f)
    return sorted(out, key=Finding.sort_key)


def lint_paths(paths: Sequence[str],
               select=None) -> Tuple[List[Finding], int]:
    """Lint files/trees; returns (findings, files_checked)."""
    findings: List[Finding] = []
    n_files = 0
    for fp in iter_python_files(paths):
        n_files += 1
        try:
            with open(fp, encoding="utf-8") as fh:
                src = fh.read()
        except (OSError, UnicodeDecodeError) as e:
            findings.append(Finding("E000", "unreadable-file", fp, 1, 0,
                                    f"cannot read file: {e}"))
            continue
        findings.extend(lint_source(src, path=fp, select=select))
    return sorted(findings, key=Finding.sort_key), n_files
