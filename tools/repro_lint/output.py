"""Finding renderers: human text, machine JSON, GitHub PR annotations."""
from __future__ import annotations

import json
from typing import List

from tools.repro_lint.engine import Finding

FORMATS = ("text", "json", "github")


def format_findings(findings: List[Finding], fmt: str,
                    n_files: int) -> str:
    if fmt == "json":
        return json.dumps({"checked_files": n_files,
                           "findings": [f.to_dict() for f in findings]},
                          indent=2)
    if fmt == "github":
        # workflow-command annotations: GitHub attaches them to the PR diff
        lines = [(f"::error file={f.path},line={f.line},col={f.col + 1},"
                  f"title=repro-lint {f.rule_id} ({f.rule_name})::"
                  f"{f.message}") for f in findings]
        lines.append(f"repro-lint: {len(findings)} finding(s) in "
                     f"{n_files} file(s)")
        return "\n".join(lines)
    lines = [f.render() for f in findings]
    lines.append(f"repro-lint: {len(findings)} finding(s) in "
                 f"{n_files} file(s) checked")
    return "\n".join(lines)
