"""API hygiene rules (API0xx).

The PR 5 ledger/tip-selection redesign left a deprecated wrapper and a
protocol boundary behind; the PR 4 cohort engine requires sum-form methods
of every program suite.  These rules keep new code off the legacy paths:

* ``select_tips(...)`` is a frozen 9-argument back-compat wrapper — new
  call sites construct a :class:`TipSelector`;
* ``.nodes`` / ``.children`` are ``DAGLedger`` internals: the
  :class:`LedgerView` protocol (``get_tx`` / ``has_tx`` / ``transactions``
  / ``tips`` ...) is the supported surface, and it is what keeps bounded
  and unbounded ledgers interchangeable;
* ``CohortPrograms`` subclasses must ship the sum-form loss/eval methods
  the 2-D (clients x data) engine psums over the data mesh axis.
"""
from __future__ import annotations

import ast
from typing import Iterator

from tools.repro_lint.engine import (Finding, ModuleContext, Rule, qualname,
                                     register)


@register
class DeprecatedSelectTipsRule(Rule):
    id = "API001"
    name = "deprecated-select-tips"
    family = "api-hygiene"
    description = ("select_tips() is a frozen back-compat wrapper; new "
                   "call sites use TipSelector.select(TipSelectionRequest, "
                   "evaluator)")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        if ctx.rel_path.endswith("repro/core/tip_selection.py"):
            return                      # the wrapper's own definition site
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            qn = qualname(node.func)
            if qn is not None and qn.split(".")[-1] == "select_tips":
                yield self.finding(
                    ctx, node,
                    "call to the deprecated select_tips wrapper: construct "
                    "TipSelector(ledger, contract, cfg) and call "
                    ".select(TipSelectionRequest(...), evaluator)")


_INTERNAL_ATTRS = {"nodes", "children"}


@register
class LedgerInternalsRule(Rule):
    id = "API002"
    name = "ledger-internals-access"
    family = "api-hygiene"
    description = (".nodes/.children are DAGLedger internals; go through "
                   "the LedgerView protocol so bounded and unbounded "
                   "ledgers stay interchangeable")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        if ctx.rel_path.endswith("repro/core/dag.py"):
            return                      # the ledger's own implementation
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Attribute) and \
                    node.attr in _INTERNAL_ATTRS:
                yield self.finding(
                    ctx, node,
                    f"'.{node.attr}' bypasses the LedgerView protocol — "
                    "use get_tx/has_tx/transactions/tips/latest_of (a "
                    "BoundedDAGLedger prunes these dicts out from under "
                    "you)")


_SUM_FORM_METHODS = ("sum_loss", "loss_denom", "eval_terms",
                     "eval_shared_terms")


@register
class CohortProgramsSumFormRule(Rule):
    id = "API003"
    name = "cohort-programs-sum-form"
    family = "api-hygiene"
    description = ("direct CohortPrograms subclasses must define the "
                   "sum-form methods the 2-D data-mesh engine psums")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            # only DIRECT subclasses of the protocol root are checkable
            # statically; deeper subclasses may inherit the sum-form suite
            if not any((qualname(b) or "").split(".")[-1] == "CohortPrograms"
                       for b in node.bases):
                continue
            defined = {n.name for n in node.body
                       if isinstance(n, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))}
            missing = [m for m in _SUM_FORM_METHODS if m not in defined]
            if missing:
                yield self.finding(
                    ctx, node,
                    f"'{node.name}' subclasses CohortPrograms but does not "
                    f"define {', '.join(missing)}: without the sum-form "
                    "terms the 2-D (clients x data) engine cannot psum "
                    "its loss/eval over the data mesh axis")
