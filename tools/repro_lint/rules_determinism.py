"""Determinism rules (DET0xx).

DAG-AFL's verification story (Eq. 7 hash chains, the robustness gate, the
bounded-ledger parity proofs) rests on bit-determinism: same seed -> same
DAG, same fault-event counts, same checkpoint roots across processes and CI
runs.  These rules prove the common hazard classes absent:

* builtin ``hash()`` is salted by ``PYTHONHASHSEED`` and varies per process;
* the legacy ``np.random.*`` module API shares hidden unseeded global state;
* wall-clock reads inside the simulation core leak host time into sim state
  where only ``sim_time`` is legal;
* ``set`` iteration order is hash-salted and must not reach outputs.
"""
from __future__ import annotations

import ast
from typing import Iterator

from tools.repro_lint.engine import (Finding, ModuleContext, Rule, qualname,
                                     register)


@register
class BuiltinHashRule(Rule):
    id = "DET001"
    name = "builtin-hash"
    family = "determinism"
    description = ("builtin hash() is salted by PYTHONHASHSEED; anything "
                   "derived from it (seeds, ordering keys, bucket ids) "
                   "differs across processes")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        # if the module rebinds the name `hash`, it is not the builtin
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name == "hash":
                return
            if isinstance(node, ast.Assign) and any(
                    isinstance(t, ast.Name) and t.id == "hash"
                    for t in node.targets):
                return
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Name) and \
                    node.func.id == "hash":
                yield self.finding(
                    ctx, node,
                    "builtin hash() varies with PYTHONHASHSEED; use a "
                    "stable digest (e.g. zlib.crc32(x.encode())) instead")


# legacy module-level numpy RNG entry points that share hidden global state;
# construction/seeding APIs are exempt
_NP_RANDOM_OK = {"default_rng", "Generator", "PCG64", "Philox", "MT19937",
                 "SFC64", "SeedSequence", "BitGenerator", "RandomState"}


@register
class LegacyNpRandomRule(Rule):
    id = "DET002"
    name = "legacy-np-random"
    family = "determinism"
    description = ("module-level np.random.* calls draw from hidden global "
                   "state; use a seeded np.random.default_rng(...)")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            qn = qualname(node.func)
            if qn is None:
                continue
            parts = qn.split(".")
            if len(parts) == 3 and parts[0] in ("np", "numpy") and \
                    parts[1] == "random" and parts[2] not in _NP_RANDOM_OK:
                yield self.finding(
                    ctx, node,
                    f"{qn}() uses numpy's hidden global RNG state; draw "
                    "from an explicitly seeded np.random.default_rng(seed)")


_WALLCLOCK = {"time.time", "time.time_ns", "time.monotonic",
              "time.monotonic_ns", "time.perf_counter",
              "time.perf_counter_ns", "datetime.now", "datetime.utcnow",
              "datetime.datetime.now", "datetime.datetime.utcnow"}

# simulation trees where transaction timestamps / event times must come from
# the event loop's sim_time, never the host clock
_SIM_TREES = ("repro/core/", "repro/fl/")


@register
class WallClockInSimRule(Rule):
    id = "DET003"
    name = "wallclock-in-sim"
    family = "determinism"
    description = ("host-clock reads inside src/repro/core|fl leak wall "
                   "time into simulation state; only sim_time is legal "
                   "there")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        if not any(t in ctx.rel_path for t in _SIM_TREES):
            return
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call) and \
                    qualname(node.func) in _WALLCLOCK:
                yield self.finding(
                    ctx, node,
                    f"{qualname(node.func)}() inside the simulation core: "
                    "timestamps and event times must derive from sim_time "
                    "so runs are bit-reproducible")


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    return (isinstance(node, ast.Call) and
            isinstance(node.func, ast.Name) and node.func.id in
            ("set", "frozenset"))


# materializers whose output order mirrors the iteration order of their arg
_ORDER_SINKS = ("list", "tuple", "iter", "enumerate", "reversed")


@register
class SetIterationRule(Rule):
    id = "DET004"
    name = "unordered-set-iteration"
    family = "determinism"
    description = ("set iteration order is hash-salted; any order that can "
                   "reach ledger/tip-selection/aggregation outputs must go "
                   "through sorted(...)")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        msg = ("iterating a set produces a PYTHONHASHSEED-dependent order; "
               "wrap it in sorted(...) before the order can escape")
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.For, ast.AsyncFor)) and \
                    _is_set_expr(node.iter):
                yield self.finding(ctx, node.iter, msg)
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                for gen in node.generators:
                    if _is_set_expr(gen.iter):
                        yield self.finding(ctx, gen.iter, msg)
            elif isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Name) and \
                    node.func.id in _ORDER_SINKS and \
                    node.args and _is_set_expr(node.args[0]):
                yield self.finding(ctx, node.args[0], msg)
