"""JAX purity & performance rules (JAX0xx).

All five rules share one per-module :class:`JitIndex` that resolves which
functions are traced: defs decorated with ``@jax.jit`` (directly or via
``functools.partial``), defs wrapped by a ``jax.jit(...)`` / ``shard_map``
call anywhere in the module (including ``self._x = jax.jit(self._x_impl)``
method binding), and the names such wrapped programs are assigned to (the
timing rule needs to know that ``jstep = jax.jit(step)`` makes ``jstep(...)``
an *asynchronous* dispatch).

The hazards:

* Python side effects inside traced code run once at trace time, then never
  again — mutation of nonlocal state and host I/O are silent correctness
  bugs (JAX001/JAX002).
* timing a jitted call with the host clock but without
  ``block_until_ready`` measures dispatch latency, not compute (JAX003).
* array-valued / non-literal ``static_argnums`` either crash (unhashable)
  or silently recompile per value (JAX004).
* a jitted function that closes over a module-level concrete array
  constant-folds it into the executable and recompiles when it is swapped
  (JAX005).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from tools.repro_lint.engine import (Finding, ModuleContext, Rule, qualname,
                                     register)

_JIT_WRAPPERS = {"jax.jit", "jit", "pjit", "jax.pjit"}
_SHARD_WRAPPERS = {"shard_map", "jax.shard_map",
                   "jax.experimental.shard_map.shard_map"}
_WRAPPERS = _JIT_WRAPPERS | _SHARD_WRAPPERS
_PARTIALS = {"partial", "functools.partial"}


class JitIndex:
    """Which defs are traced, which names are jit-bound, and every jit call
    spec — computed once per module and shared by the JAX rules."""

    def __init__(self, ctx: ModuleContext):
        tree = ctx.tree
        self.parents: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(tree):
            for ch in ast.iter_child_nodes(node):
                self.parents[ch] = node

        self.defs_by_name: Dict[str, List[ast.FunctionDef]] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.defs_by_name.setdefault(node.name, []).append(node)

        self.jitted_defs: Set[ast.AST] = set()
        self.jit_bound_names: Set[str] = set()
        # (jit-call node, wrapped def or None) for the static-args rule
        self.jit_specs: List[Tuple[ast.Call, Optional[ast.AST]]] = []

        for node in ast.walk(tree):
            if isinstance(node, ast.Call) and qualname(node.func) in _WRAPPERS:
                target = self._resolve_target(node)
                if target is not None:
                    self.jitted_defs.add(target)
                if qualname(node.func) in _JIT_WRAPPERS:
                    self.jit_specs.append((node, target))
                par = self.parents.get(node)
                if isinstance(par, ast.Assign):
                    for t in par.targets:
                        if isinstance(t, ast.Name):
                            self.jit_bound_names.add(t.id)
                        elif isinstance(t, ast.Attribute):
                            self.jit_bound_names.add(t.attr)

        for defs in self.defs_by_name.values():
            for fn in defs:
                spec = self._decorator_spec(fn)
                if spec is not None:
                    self.jitted_defs.add(fn)
                    self.jit_bound_names.add(fn.name)
                    if isinstance(spec, ast.Call):
                        self.jit_specs.append((spec, fn))

        # names of defs known traced: calling them directly is also an
        # async dispatch
        self.jit_bound_names |= {fn.name for fn in self.jitted_defs
                                 if hasattr(fn, "name")}

    def _resolve_target(self, call: ast.Call) -> Optional[ast.AST]:
        """The def a jit/shard_map call wraps, when visible in-module."""
        if not call.args:
            return None
        a0 = call.args[0]
        if isinstance(a0, ast.Call) and qualname(a0.func) in _WRAPPERS:
            return self._resolve_target(a0)          # jax.jit(shard_map(f))
        name = None
        if isinstance(a0, ast.Name):
            name = a0.id
        elif isinstance(a0, ast.Attribute) and \
                isinstance(a0.value, ast.Name) and a0.value.id == "self":
            name = a0.attr                           # jax.jit(self._impl)
        defs = self.defs_by_name.get(name or "", [])
        return defs[0] if len(defs) == 1 else None

    @staticmethod
    def _decorator_spec(fn) -> Optional[ast.AST]:
        """Truthy when ``fn`` is jit-decorated; the returned Call node (for
        ``@partial(jax.jit, ...)`` / ``@jax.jit(...)`` forms) carries the
        static-arg keywords."""
        for d in fn.decorator_list:
            if qualname(d) in _WRAPPERS:
                return d
            if isinstance(d, ast.Call):
                fq = qualname(d.func)
                if fq in _WRAPPERS:
                    return d
                if fq in _PARTIALS and d.args and \
                        qualname(d.args[0]) in _WRAPPERS:
                    return d
        return None


def _jit_index(ctx: ModuleContext) -> JitIndex:
    idx = ctx._cache.get("jit_index")
    if idx is None:
        idx = JitIndex(ctx)
        ctx._cache["jit_index"] = idx
    return idx


def _walk_body(fn, *, into_nested: bool = False) -> Iterator[ast.AST]:
    """Walk a def's body; by default stops at nested def/lambda/class
    boundaries (their locals and side effects belong to their own scope)."""
    stack = list(fn.body)
    while stack:
        node = stack.pop()
        yield node
        if not into_nested and isinstance(
                node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda,
                       ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def _target_names(t: ast.AST) -> Iterator[str]:
    if isinstance(t, ast.Name):
        yield t.id
    elif isinstance(t, (ast.Tuple, ast.List)):
        for e in t.elts:
            yield from _target_names(e)
    elif isinstance(t, ast.Starred):
        yield from _target_names(t.value)


def _local_names(fn) -> Set[str]:
    a = fn.args
    names = {p.arg for p in
             list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)}
    if a.vararg:
        names.add(a.vararg.arg)
    if a.kwarg:
        names.add(a.kwarg.arg)
    for node in _walk_body(fn):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                names.update(_target_names(t))
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign, ast.For,
                               ast.AsyncFor)):
            names.update(_target_names(node.target))
        elif isinstance(node, ast.NamedExpr):
            names.update(_target_names(node.target))
        elif isinstance(node, ast.comprehension):
            names.update(_target_names(node.target))
        elif isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                if item.optional_vars is not None:
                    names.update(_target_names(item.optional_vars))
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            names.add(node.name)
    return names


def _root_name(node: ast.AST) -> Optional[str]:
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


_MUTATORS = {"append", "extend", "add", "update", "insert", "remove",
             "discard", "pop", "popitem", "clear", "setdefault", "write"}


@register
class JitNonlocalMutationRule(Rule):
    id = "JAX001"
    name = "jit-nonlocal-mutation"
    family = "jax-purity"
    description = ("mutation of captured/global state inside a traced "
                   "function happens once at trace time, then never again")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        idx = _jit_index(ctx)
        for fn in idx.jitted_defs:
            locs = _local_names(fn)
            for node in _walk_body(fn):
                if isinstance(node, (ast.Global, ast.Nonlocal)):
                    yield self.finding(
                        ctx, node,
                        f"{'global' if isinstance(node, ast.Global) else 'nonlocal'} "
                        f"declaration inside traced '{fn.name}': traced "
                        "functions must be pure — thread state through "
                        "arguments and return values")
                    continue
                targets = []
                if isinstance(node, ast.Assign):
                    targets = node.targets
                elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                    targets = [node.target]
                for t in targets:
                    if isinstance(t, (ast.Attribute, ast.Subscript)):
                        root = _root_name(t)
                        if root == "self" or (root is not None
                                              and root not in locs):
                            yield self.finding(
                                ctx, t,
                                f"write to '{root}' (captured/shared "
                                f"object) inside traced '{fn.name}' runs "
                                "at trace time only")
                if isinstance(node, ast.Call) and \
                        isinstance(node.func, ast.Attribute) and \
                        node.func.attr in _MUTATORS:
                    root = _root_name(node.func.value)
                    if root == "self" or (root is not None
                                          and root not in locs):
                        yield self.finding(
                            ctx, node,
                            f"'{root}.{node.func.attr}(...)' mutates "
                            f"captured state inside traced '{fn.name}' — "
                            "it runs at trace time only")


_IO_NAMES = {"print", "input", "breakpoint", "open"}
_IO_PREFIXES = ("logging.", "sys.stdout.", "sys.stderr.", "warnings.warn")


@register
class JitPythonIoRule(Rule):
    id = "JAX002"
    name = "jit-python-io"
    family = "jax-purity"
    description = ("host I/O inside a traced function executes at trace "
                   "time only; use jax.debug.print / jax.debug.callback")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        idx = _jit_index(ctx)
        for fn in idx.jitted_defs:
            for node in _walk_body(fn, into_nested=True):
                if not isinstance(node, ast.Call):
                    continue
                qn = qualname(node.func)
                if qn in _IO_NAMES or (qn is not None and any(
                        qn.startswith(p) or qn == p.rstrip(".")
                        for p in _IO_PREFIXES)):
                    yield self.finding(
                        ctx, node,
                        f"'{qn}(...)' inside traced '{fn.name}': host I/O "
                        "runs at trace time only — use jax.debug.print / "
                        "jax.debug.callback for runtime effects")


_TIME_FNS = {"time.time", "time.perf_counter", "time.monotonic"}


@register
class JitTimingNoSyncRule(Rule):
    id = "JAX003"
    name = "jit-timing-no-sync"
    family = "jax-perf"
    description = ("a wall-clock span around an async jitted dispatch "
                   "without block_until_ready measures dispatch latency, "
                   "not compute")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        idx = _jit_index(ctx)
        if not idx.jit_bound_names:
            return
        scopes = [ctx.tree] + [fn for defs in idx.defs_by_name.values()
                               for fn in defs]
        for scope in scopes:
            yield from self._check_scope(ctx, idx, scope)

    def _check_scope(self, ctx, idx, scope) -> Iterator[Finding]:
        walker = (_walk_body(scope) if not isinstance(scope, ast.Module)
                  else self._walk_module(scope))
        starts: List[Tuple[int, str]] = []     # (line, clock var)
        elapsed: List[Tuple[int, str, ast.AST]] = []
        jit_calls: List[int] = []
        syncs: List[int] = []
        for node in walker:
            if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                    isinstance(node.targets[0], ast.Name) and \
                    isinstance(node.value, ast.Call) and \
                    qualname(node.value.func) in _TIME_FNS:
                starts.append((node.lineno, node.targets[0].id))
            elif isinstance(node, ast.BinOp) and \
                    isinstance(node.op, ast.Sub) and \
                    isinstance(node.left, ast.Call) and \
                    qualname(node.left.func) in _TIME_FNS and \
                    isinstance(node.right, ast.Name):
                elapsed.append((node.lineno, node.right.id, node))
            elif isinstance(node, ast.Call):
                qn = qualname(node.func)
                if isinstance(node.func, ast.Name) and \
                        node.func.id in idx.jit_bound_names:
                    jit_calls.append(node.lineno)
                elif qn is not None and \
                        qn.split(".")[-1] == "block_until_ready":
                    syncs.append(node.lineno)
        for eline, tvar, enode in elapsed:
            span_starts = [ln for ln, v in starts if v == tvar and ln < eline]
            if not span_starts:
                continue
            sline = max(span_starts)
            dispatched = [ln for ln in jit_calls if sline < ln < eline]
            synced = [ln for ln in syncs if sline < ln <= eline]
            if dispatched and not synced:
                yield self.finding(
                    ctx, enode,
                    f"span started at line {sline} times a jitted call "
                    f"(line {dispatched[0]}) without jax.block_until_ready"
                    " — async dispatch returns before the work finishes")

    @staticmethod
    def _walk_module(mod: ast.Module) -> Iterator[ast.AST]:
        stack = list(mod.body)
        while stack:
            node = stack.pop()
            yield node
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda, ast.ClassDef)):
                continue
            stack.extend(ast.iter_child_nodes(node))


# parameter names that (in this codebase's vocabulary) always carry arrays
_ARRAYISH_PARAMS = {"params", "batch", "x", "y", "xs", "ys", "tokens",
                    "grads", "state", "opt_state", "caches", "weights",
                    "arr", "inputs", "key", "keys", "data"}


def _literal_static_spec(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant):
        return isinstance(node.value, (int, str))
    if isinstance(node, (ast.Tuple, ast.List)):
        return all(isinstance(e, ast.Constant) and
                   isinstance(e.value, (int, str)) for e in node.elts)
    return False


@register
class StaticArgsRule(Rule):
    id = "JAX004"
    name = "suspicious-static-args"
    family = "jax-perf"
    description = ("non-literal static_argnums specs, and static args that "
                   "carry arrays (unhashable, recompile per value)")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        idx = _jit_index(ctx)
        for call, target in idx.jit_specs:
            for kw in call.keywords:
                if kw.arg not in ("static_argnums", "static_argnames"):
                    continue
                if not _literal_static_spec(kw.value):
                    yield self.finding(
                        ctx, kw.value,
                        f"{kw.arg} is not a literal int/str (tuple): a "
                        "computed static-arg spec hides which arguments "
                        "trigger recompilation")
                    continue
                if target is None:
                    continue
                yield from self._check_params(ctx, kw, target)

    def _check_params(self, ctx, kw, fn) -> Iterator[Finding]:
        params = [p.arg for p in fn.args.posonlyargs + fn.args.args]
        vals = ([kw.value] if isinstance(kw.value, ast.Constant)
                else list(kw.value.elts))
        for v in vals:
            pname = None
            if kw.arg == "static_argnums":
                i = v.value
                if not (0 <= i < len(params)):
                    yield self.finding(
                        ctx, v, f"static_argnums index {i} is out of range "
                        f"for '{fn.name}' ({len(params)} positional "
                        "parameters)")
                    continue
                pname = params[i]
            else:
                if v.value not in params:
                    yield self.finding(
                        ctx, v, f"static_argnames '{v.value}' is not a "
                        f"parameter of '{fn.name}'")
                    continue
                pname = v.value
            if pname in _ARRAYISH_PARAMS:
                yield self.finding(
                    ctx, v,
                    f"parameter '{pname}' of '{fn.name}' is marked static "
                    "but carries array data: arrays are unhashable under "
                    "static hashing and force a recompile per value")


_ARRAY_CTOR_BASES = {"np", "numpy", "jnp", "jax.numpy"}
_ARRAY_CTOR_FNS = {"array", "asarray", "zeros", "ones", "empty", "full",
                   "arange", "linspace", "eye", "identity"}


@register
class JitConstantClosureRule(Rule):
    id = "JAX005"
    name = "jit-constant-closure"
    family = "jax-perf"
    description = ("a traced function closing over a module-level concrete "
                   "array constant-folds it into the executable")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        idx = _jit_index(ctx)
        consts: Dict[str, int] = {}
        for node in ctx.tree.body:
            if not (isinstance(node, ast.Assign) and
                    isinstance(node.value, ast.Call)):
                continue
            qn = qualname(node.value.func) or ""
            base, _, attr = qn.rpartition(".")
            if base in _ARRAY_CTOR_BASES and attr in _ARRAY_CTOR_FNS:
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        consts[t.id] = node.lineno
        if not consts:
            return
        for fn in idx.jitted_defs:
            locs = _local_names(fn)
            for node in _walk_body(fn, into_nested=True):
                if isinstance(node, ast.Name) and \
                        isinstance(node.ctx, ast.Load) and \
                        node.id in consts and node.id not in locs:
                    yield self.finding(
                        ctx, node,
                        f"traced '{fn.name}' captures module-level array "
                        f"'{node.id}' (built at line {consts[node.id]}): "
                        "it constant-folds into the compiled executable — "
                        "pass it as an argument instead")
