"""Kernel-dispatch hygiene rules (KER0xx).

PR "Pallas kernels on the federated hot path" routed the Eq. 3
threshold-zero signatures and the LM attention softmax through the
platform-aware dispatch layer (``repro.kernels.ops`` +
``repro.kernels.dispatch``).  The hot paths stay routed only if nobody
reintroduces the raw-jnp math or hardcodes the interpreter flag:

* a ``jnp.mean``/``jnp.sum`` over an ``== 0.0`` comparison in
  ``src/repro/fl``/``src/repro/models`` is an Eq. 3 signature computed
  outside the dispatch layer — it silently forks the signature math the
  DAG's tip selection depends on (``models/layers.py`` is exempt: it
  holds the canonical oracle the kernels are parity-tested against);
* a ``jax.nn.softmax`` there is an attention/score path bypassing
  ``kernels.ops.flash_attention`` (``models/attention.py`` owns the
  XLA fallbacks and ``models/moe.py``'s router softmax is not an
  attention; both are exempt);
* a literal ``interpret=True/False`` outside ``src/repro/kernels``
  pins one platform's execution mode into shared code — call sites
  must pass ``policy=`` (or nothing) and let the dispatch layer
  resolve the flag per platform.
"""
from __future__ import annotations

import ast
from typing import Iterator

from tools.repro_lint.engine import (Finding, ModuleContext, Rule, qualname,
                                     register)

_HOT_TREES = ("src/repro/fl/", "src/repro/models/")
_REDUCERS = {"jnp.mean", "jnp.sum", "jax.numpy.mean", "jax.numpy.sum"}
_SIG_EXEMPT = ("src/repro/models/layers.py",)
_SOFTMAX_EXEMPT = ("src/repro/models/attention.py", "src/repro/models/moe.py")
_KERNEL_TREE = "src/repro/kernels/"


def _contains_zero_compare(node: ast.AST) -> bool:
    """True when the subtree holds an ``== 0.0`` comparison (either side)."""
    for sub in ast.walk(node):
        if not (isinstance(sub, ast.Compare) and len(sub.ops) == 1
                and isinstance(sub.ops[0], ast.Eq)):
            continue
        for side in (sub.left, sub.comparators[0]):
            if isinstance(side, ast.Constant) and side.value == 0.0 \
                    and isinstance(side.value, float):
                return True
    return False


@register
class HotPathKernelBypassRule(Rule):
    id = "KER001"
    name = "hot-path-kernel-bypass"
    family = "kernel-dispatch"
    description = ("Eq. 3 signatures / attention softmax computed with raw "
                   "jnp on the federated hot path, or a literal interpret= "
                   "flag outside the kernel package — route through "
                   "repro.kernels.ops and its dispatch policy")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        rel = ctx.rel_path
        in_src = "src/repro/" in rel and _KERNEL_TREE not in rel
        in_hot = any(t in rel for t in _HOT_TREES)
        if not in_src:
            return
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                qn = qualname(node.func)
                if in_hot and qn in _REDUCERS and \
                        not any(rel.endswith(p) for p in _SIG_EXEMPT) and \
                        any(_contains_zero_compare(a) for a in node.args):
                    yield self.finding(
                        ctx, node,
                        f"'{qn}' over an '== 0.0' comparison is an Eq. 3 "
                        "threshold-zero signature computed outside the "
                        "kernel dispatch layer — use kernels.ops.signature"
                        "/signature_per_channel so the policy (and the "
                        "bit-stable bucketing) stays in one place")
                elif in_hot and qn == "jax.nn.softmax" and \
                        not any(rel.endswith(p) for p in _SOFTMAX_EXEMPT):
                    yield self.finding(
                        ctx, node,
                        "'jax.nn.softmax' on the federated hot path "
                        "bypasses kernels.ops.flash_attention — score "
                        "paths belong behind the dispatch layer (XLA "
                        "fallbacks live in models/attention.py)")
                for kw in node.keywords:
                    if kw.arg == "interpret" and \
                            isinstance(kw.value, ast.Constant) and \
                            isinstance(kw.value.value, bool):
                        yield self.finding(
                            ctx, kw.value,
                            f"literal 'interpret={kw.value.value}' outside "
                            "src/repro/kernels pins one platform's "
                            "execution mode — pass policy= and let "
                            "kernels.dispatch resolve the flag")
