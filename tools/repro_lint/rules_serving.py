"""Serving-path hygiene rules (SRV0xx).

PR "Live-traffic consensus serving" made the frontier queryable through
versioned, double-buffered :class:`repro.fl.serving.ServingReplica`
snapshots.  The atomicity guarantee — a reader never observes a half-built
frontier, and replica refs are protected from bounded-ledger eviction —
only holds if consumers actually go through the publisher.  A direct
frontier read (``ledger.tips()`` / ``tips_by_freshness()`` or the
coordinator's ``global_model()``) outside the coordinator/ledger layer and
the serving module itself re-derives the consensus at an arbitrary instant:
it can straddle a publish, pin nothing against eviction, and silently fork
the staleness accounting the serve gate pins.
"""
from __future__ import annotations

import ast
from typing import Iterator

from tools.repro_lint.engine import (Finding, ModuleContext, Rule, qualname,
                                     register)

#: frontier-consensus reads that belong behind the publisher
_FRONTIER_READS = {"tips", "tips_by_freshness", "global_model"}

#: who may read the frontier directly: the ledger/coordinator layer (it
#: OWNS the frontier) and the serving module (the one sanctioned
#: materialization point)
_EXEMPT_TREES = ("src/repro/core/",)
_EXEMPT_FILES = ("src/repro/fl/serving.py",)


@register
class ServingFrontierBypassRule(Rule):
    id = "SRV001"
    name = "serving-frontier-bypass"
    family = "api-hygiene"
    description = ("direct frontier read (ledger.tips()/tips_by_freshness()/"
                   "coordinator.global_model()) outside core/ and "
                   "fl/serving.py — consume the published ServingReplica "
                   "(ConsensusPublisher.replica()) so queries stay atomic, "
                   "eviction-protected and staleness-accounted")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        rel = ctx.rel_path
        if "src/repro/" not in rel:
            return
        if any(t in rel for t in _EXEMPT_TREES) or \
                any(rel.endswith(f) for f in _EXEMPT_FILES):
            return
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            qn = qualname(node.func)
            if qn is None or "." not in qn:
                continue
            attr = qn.rsplit(".", 1)[1]
            if attr in _FRONTIER_READS:
                yield self.finding(
                    ctx, node,
                    f"'{qn}()' reads the tip frontier directly outside "
                    "src/repro/core/ and fl/serving.py — a raw read can "
                    "straddle a publish and pins nothing against bounded-"
                    "ledger eviction; query ConsensusPublisher.replica() "
                    "(an immutable Eq. 6 snapshot) instead")
