"""DET001 clean: stable digest instead of the salted builtin."""
import zlib

import numpy as np


def make_dataset(name, seed=0):
    salt = zlib.crc32(name.encode("utf-8")) % (2 ** 16)
    rng = np.random.default_rng(seed + salt)
    return rng.normal(size=4)
