"""DET001 flagged: builtin hash() feeding an RNG seed."""
import numpy as np


def make_dataset(name, seed=0):
    rng = np.random.default_rng(seed + hash(name) % (2 ** 16))
    return rng.normal(size=4)
