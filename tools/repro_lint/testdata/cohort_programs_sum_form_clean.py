"""API003 clean: subclass implements the full sum-form surface."""
from repro.fl.cohort import CohortPrograms


class MambaCohortPrograms(CohortPrograms):
    def sum_loss(self, params, batch):
        return 0.0

    def loss_denom(self, batch):
        return 1.0

    def eval_terms(self, params, batch):
        return {"acc": 0.0}

    def eval_shared_terms(self, params, batch):
        return {}
