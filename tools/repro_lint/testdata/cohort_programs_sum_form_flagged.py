"""API003 flagged: CohortPrograms subclass missing the sum-form surface.

The 2-D (clients x data) mesh engine reduces partial sums across the data
axis, so every programs bundle must expose sum_loss / loss_denom /
eval_terms / eval_shared_terms.  This subclass only overrides the legacy
mean-form entry points.
"""
from repro.fl.cohort import CohortPrograms


class MambaCohortPrograms(CohortPrograms):
    def loss(self, params, batch):
        return 0.0

    def evaluate(self, params, batch):
        return {"acc": 0.0}
