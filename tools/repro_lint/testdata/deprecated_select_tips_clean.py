"""API001 clean: request-object tip selection API."""
from repro.core.tip_selection import (
    FnTipEvaluator,
    TipSelectionRequest,
    TipSelector,
)


def pick(led, cfg, fn):
    selector = TipSelector(led, None, cfg)
    req = TipSelectionRequest(client_id=0, cur_epoch=2, now=3.0)
    return selector.select(req, FnTipEvaluator(fn))
