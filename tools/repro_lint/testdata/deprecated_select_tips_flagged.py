"""API001 flagged: call into the deprecated 9-arg wrapper."""
from repro.core.tip_selection import select_tips


def pick(led, cfg, fn):
    return select_tips(led, 0, 2, 3.0, fn, None, cfg)
