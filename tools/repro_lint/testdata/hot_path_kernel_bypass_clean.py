"""KER001 clean fixture — linted as-if at src/repro/fl/fixture.py."""
import jax.numpy as jnp

from repro.kernels import ops as kops


def sample_signature(params, x, policy):
    # Eq. 3 through the dispatch layer: no raw threshold-zero reduction
    return kops.signature_per_channel(x, tau=0.0, policy=policy)


def masked_mean(per_row, mask):
    # reductions without an == 0.0 comparison are ordinary math
    return jnp.sum(per_row * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def count_exact_epoch(epochs):
    # integer == 0 (not the float literal) is host control flow, not Eq. 3
    return jnp.sum(jnp.asarray(epochs) == 0)


def attention(q, k, v, runtime):
    # attention through the dispatch layer, platform resolved by policy
    return kops.flash_attention(q, k, v,
                                policy=kops.policy_from_runtime(runtime))


def interpreted_by_policy(x):
    # interpret resolved from the policy, not hardcoded
    return kops.signature(x, tau=0.0, policy="interpret")
