"""KER001 flagged fixture — linted as-if at src/repro/fl/fixture.py."""
import jax
import jax.numpy as jnp

from repro.kernels import signature_td


def sample_signature(params, x):
    # leg A: Eq. 3 zero-fraction computed with raw jnp on the hot path
    return jnp.mean((x == 0.0).astype(jnp.float32), axis=(1, 2))


def signature_sum_form(x):
    # leg A also covers the sum-form variant
    return jnp.sum((0.0 == x).astype(jnp.float32), axis=1)


def attention_scores(q, k, v):
    s = q @ k.T
    # leg B: softmax score path outside models/attention.py
    w = jax.nn.softmax(s, axis=-1)
    return w @ v


def forced_interpreter(x):
    # leg C: literal interpret= outside src/repro/kernels
    return signature_td(x, tau=0.0, interpret=True)
