"""JAX005 clean: the table is threaded through as an argument."""
import jax
import jax.numpy as jnp

TABLE = jnp.arange(1024)


@jax.jit
def lookup(table, i):
    return table[i]


def run(i):
    return lookup(TABLE, i)        # referenced outside the traced scope
