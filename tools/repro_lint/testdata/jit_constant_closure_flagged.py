"""JAX005 flagged: jitted function closing over a module-level array."""
import jax
import jax.numpy as jnp

TABLE = jnp.arange(1024)


@jax.jit
def lookup(i):
    return TABLE[i]                # baked into the jaxpr as a constant
