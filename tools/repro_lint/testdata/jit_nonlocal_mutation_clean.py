"""JAX001 clean: pure traced functions; state threads through args."""
import jax


@jax.jit
def step(params, grads):
    out = dict(params)
    out["w"] = params["w"] - 0.1 * grads
    return out


class Engine:
    def __init__(self):
        self._step = jax.jit(self._step_impl)

    def _step_impl(self, x, n_calls):
        return x * 2, n_calls + 1
