"""JAX001 flagged: traced function mutating captured state."""
import jax

TRACE_LOG = []


@jax.jit
def step(params, grads):
    TRACE_LOG.append(grads)        # runs once, at trace time
    params["w"] = params["w"] - 0.1 * grads
    return params


class Engine:
    def __init__(self):
        self.calls = 0
        self._step = jax.jit(self._step_impl)

    def _step_impl(self, x):
        self.calls += 1            # trace-time-only counter
        return x * 2
