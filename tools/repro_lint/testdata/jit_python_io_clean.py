"""JAX002 clean: runtime-safe debugging primitives only."""
import jax


@jax.jit
def debug_step(params, x):
    jax.debug.print("step on {x}", x=x)    # fires at run time, every call
    return params, x * 2
