"""JAX002 flagged: host I/O inside a traced function."""
import jax


@jax.jit
def debug_step(params, x):
    print("step on", x)            # prints once, at trace time
    with open("/tmp/trace.log", "a") as fh:
        fh.write("traced\n")
    return params, x * 2
