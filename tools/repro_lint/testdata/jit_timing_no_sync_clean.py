"""JAX003 clean: the span drains the async dispatch before the clock."""
import time

import jax


def bench(step, batch, iters=10):
    jstep = jax.jit(step)
    t0 = time.time()
    out = None
    for _ in range(iters):
        out = jstep(batch)
    jax.block_until_ready(out)
    return time.time() - t0, out
