"""JAX003 flagged: wall-clock span around an un-synced jitted call."""
import time

import jax


def bench(step, batch, iters=10):
    jstep = jax.jit(step)
    t0 = time.time()
    out = None
    for _ in range(iters):
        out = jstep(batch)
    return time.time() - t0, out       # measures dispatch, not compute
