"""API002 clean: everything goes through the LedgerView protocol."""


def audit(ledger, tx_id):
    n = sum(1 for _ in ledger.transactions())
    present = ledger.has_tx(tx_id)
    return n, present
