"""API002 flagged: reaching past LedgerView into dict internals."""


def audit(ledger, tx_id):
    n = len(ledger.nodes)                      # storage detail
    kids = ledger.children.get(tx_id, [])      # adjacency detail
    return n, kids
