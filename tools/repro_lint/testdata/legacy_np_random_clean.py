"""DET002 clean: explicitly seeded generator objects only."""
import numpy as np


def shuffle_clients(n, seed=0):
    rng = np.random.default_rng(seed)
    order = rng.permutation(n)
    noise = rng.normal(0.0, 1.0, size=n)
    return order, noise
