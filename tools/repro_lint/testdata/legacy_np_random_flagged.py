"""DET002 flagged: module-level legacy numpy RNG calls."""
import numpy as np


def shuffle_clients(n):
    np.random.seed(0)
    order = np.random.permutation(n)
    noise = np.random.normal(0.0, 1.0, size=n)
    return order, noise
