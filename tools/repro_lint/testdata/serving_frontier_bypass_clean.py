"""SRV001 clean: queries consume the published immutable replica."""


def answer_query(publisher):
    replica = publisher.replica()     # atomic, eviction-protected snapshot
    if replica is None:
        return None
    return replica.params, replica.frontier, replica.ledger_seq
