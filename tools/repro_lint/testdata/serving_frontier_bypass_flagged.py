"""SRV001 flagged: re-deriving frontier consensus outside the publisher."""


def answer_query(coordinator, ledger, store):
    tips = ledger.tips()                       # raw frontier read
    fresh = ledger.tips_by_freshness(limit=2)  # same, freshness-ordered
    model = coordinator.global_model()         # re-derives Eq. 6 mid-publish
    return tips, fresh, model
