"""JAX004 clean: static_argnums marks genuinely hashable config."""
import jax


def loss(params, batch, n_layers):
    return ((params - batch) ** 2).sum() * n_layers


jloss = jax.jit(loss, static_argnums=(2,))               # small hashable int
