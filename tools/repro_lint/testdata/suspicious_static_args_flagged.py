"""JAX004 flagged: array-valued / out-of-range static_argnums."""
import jax


def loss(params, batch, n_layers):
    return ((params - batch) ** 2).sum() * n_layers


jloss_bad_arg = jax.jit(loss, static_argnums=(1,))       # `batch` is array-ish
jloss_oob = jax.jit(loss, static_argnums=(7,))           # only 3 params exist
