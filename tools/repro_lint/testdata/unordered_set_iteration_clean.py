"""DET004 clean: every set is sorted before its order can escape."""


def approve_order(tips, seen):
    order = sorted(set(tips))
    for tip in sorted(set(tips) - set(seen)):
        order.append(tip)
    fresh = {x.strip() for x in order}        # membership only, no iteration
    return [t for t in order if t in fresh]
