"""DET004 flagged: hash-salted set order reaching outputs."""


def approve_order(tips, seen):
    order = list(set(tips))                   # materialized set order
    for tip in set(seen):                     # iterated set order
        order.append(tip)
    return [t for t in {x.strip() for x in order}]
