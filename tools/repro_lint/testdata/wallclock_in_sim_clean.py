"""DET003 clean: simulated time is threaded through explicitly."""


def publish(ledger, metadata, parents, sim_time):
    return ledger.add_transaction(metadata, parents, sim_time)
