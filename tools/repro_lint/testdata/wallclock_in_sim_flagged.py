"""DET003 flagged: host-clock reads in the simulation core.

Linted with a virtual path under ``src/repro/core/`` — the rule only
applies inside the simulation trees.
"""
import time


def publish(ledger, metadata, parents):
    return ledger.add_transaction(metadata, parents, time.time())
